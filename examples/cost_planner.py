"""Deployment cost planner (paper Figs 12-13 as a tool): given a workload,
rank confidential deployment options by $/Mtoken and show the CPU/GPU
crossover for your batch size.

    PYTHONPATH=src python examples/cost_planner.py --params 7e9 --batch 4
"""

import argparse
import dataclasses

from repro.costs.model import (Workload, best_cpu_cost, crossover_batch,
                               tokens_per_second, usd_per_mtok)
from repro.costs.pricing import SKUS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", type=float, default=6.7e9)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--in-tokens", type=int, default=128)
    ap.add_argument("--confidential-only", action="store_true", default=True)
    args = ap.parse_args()

    w = Workload(n_params=args.params, batch=args.batch,
                 in_tokens=args.in_tokens, out_tokens=128)

    print(f"workload: {args.params / 1e9:.1f}B params, batch {args.batch}, "
          f"{args.in_tokens} input tokens\n")
    options = []
    for name, sku in SKUS.items():
        if args.confidential_only and sku.tee_mode is None:
            continue
        cost = (best_cpu_cost(w, name) if sku.kind == "cpu"
                else usd_per_mtok(w, name))
        tps = tokens_per_second(w, sku, 32 if sku.kind == "cpu" else None)
        options.append((cost, name, tps, sku))
    options.sort()
    print(f"{'rank':4s} {'sku':14s} {'$/Mtok':>9s} {'tok/s':>10s}  security notes")
    for i, (cost, name, tps, sku) in enumerate(options):
        print(f"{i + 1:4d} {name:14s} {cost:9.2f} {tps:10.1f}  "
              f"tee={sku.tee_mode}")
    x = crossover_batch(dataclasses.replace(w, batch=1), "emr-amx-tdx",
                        "h100-cc", [1, 2, 4, 8, 16, 32, 64, 128, 256, 512])
    print(f"\nCPU-TEE -> cGPU crossover batch for this model: {x} "
          f"(paper reports ~128 for Llama2-7B)")
    print("recommendation:", options[0][1])


if __name__ == "__main__":
    main()
