"""End-to-end confidential serving driver (the paper's measured scenario).

Loads a small model from a sealed checkpoint, attests, then serves a stream
of batched requests with continuous batching — the request-object API over
a pluggable KV backend: bucketed batched prefill (no prompt truncation),
priority admission with sealed-KV preemption (page-granular on the paged
backend), per-request sampling, and streaming egress whose frame
granularity is a per-request policy. Serving is two-phase: prefill and
decode are independently scheduled, either interleaved step-by-step on one
plan (``continuous_batching=True``) or disaggregated onto a dedicated
prefill ``ComputePlan`` whose KV handoff is a sealed-channel transfer
(``prefill_plan="dedicated"``).

API in one glance (``repro.runtime``)::

    from repro.runtime import (Engine, GenerationRequest, SamplingParams,
                               FramePolicy, RequestOutput)

    engine = Engine(model, params, trust_domain=td,
                    kv_backend="paged", page_size=16,  # or "slot" (dense);
                                                     #  paged = page-charged
                                                     #  admission + per-page
                                                     #  sealed preemption
                    mesh="dp=4",                     # span a 4-device mesh
                                                     #  (batch sharded, params
                                                     #  FSDP-placed, measured
                                                     #  collective traffic in
                                                     #  ChannelStats; omit for
                                                     #  one device — launcher
                                                     #  flag: serve.py --mesh)
                    continuous_batching=True,        # step-level admission:
                    step_tokens=160,                 #  per-step token budget
                                                     #  split between prefill
                                                     #  chunks + decode rows,
                                                     #  shorts backfill budget
                                                     #  a long head can't use
                    prefill_plan="dedicated")        # or: disaggregate prefill
                                                     #  onto its own plan; KV
                                                     #  hands off to decode as
                                                     #  a sealed transfer
                                                     #  (mutually exclusive
                                                     #  with the two above)
    req = engine.submit(GenerationRequest(
        prompt=tok.encode("confidential inference"),
        max_new_tokens=32,
        priority=5,                                  # preempts lower classes
        params=SamplingParams(temperature=0.8,       # 0.0 = greedy default
                              top_k=40, top_p=0.9,   # nucleus: 1.0 = off
                              repetition_penalty=1.2,  # >1, count-weighted:
                                                     #  compounds per repeat
                              presence_penalty=0.5,  # flat per-seen-token tax
                              logit_bias={50: 4.0},  # per-request additive
                                                     #  bias (ban with -1e9)
                              seed=7),               # seeded => reproducible,
                                                     #  even across preemption
        frame=FramePolicy(coalesce=4),               # 4 tokens per encrypted
                                                     #  egress frame (Insight 10)
        deadline_s=2.0, on_deadline="abort"))        # SLO: "drop" (queued
                                                     #  only) or "abort"
                                                     #  (mid-flight too);
                                                     #  admission queues order
                                                     #  by slack (EDF) so
                                                     #  aborts stay rare
    engine.run()
    out: RequestOutput = req.result()
    out.tokens, out.finish_reason        # "length"|"stop"|"dropped"|"aborted"
    out.ttft_s, out.e2e_s                # per-request timing
    out.egress_frames, out.egress_tokens # boundary crossings this request paid
    out.sealed_bytes                     # eviction ciphertext it cost

``engine.stream(request)`` yields tokens as they cross the trust boundary
(in bursts of ``coalesce``); ``engine.run()`` returns ``ServeStats`` with
p50/mean/p99 latency + TTFT and the SLO counters (dropped_requests,
aborted_requests, deadline_misses, preemptions, sealed_bytes), plus the
two-phase counters (handoffs, handoff_bytes, backfilled_requests),
admission control (rejected_infeasible — with ``reject_infeasible=True``
a deadline no step-time lower bound can meet is refused BEFORE the prompt
crosses the boundary) and migration pricing (migrations, migrated_bytes).

Scaling past one enclave, the fleet tier (``repro.fleet``) wraps N engines,
each in its own TrustDomain, behind an attested gateway + orchestrator::

    from repro.fleet import EngineWorker, Gateway, Orchestrator

    workers = [EngineWorker(f"w{i}", model, params,   # own TrustDomain each;
                            engine_kw=dict(...))      #  kwargs as above
               for i in range(2)]
    gateway = Gateway()                  # quote-verifies each worker, then
    gateway.register_tenant("acme")      #  releases per-tenant KEY DOMAINS
                                         #  (derived labels: tenant A's
                                         #  sealed KV fails MAC under B's)
    orch = Orchestrator(gateway, workers,
                        placement="tenant_affinity",  # or "least_loaded"
                        tenant_budgets={"acme": 500}) # tokens/s, held at
                                                      #  the gateway side
    req = orch.submit(GenerationRequest(..., tenant="acme"))
    orch.kill("w0")                      # enclave loss: sealed KV migrates
    orch.run()                           #  to survivors; req finishes
                                         #  byte-identically elsewhere

Prompts travel gateway->worker as envelopes (fresh content key, wrapped to
the one attested worker's transport key); a worker failure's in-flight KV
re-seals under the fleet-shared tenant domain in a ``kvmigrate/{worker}``
nonce namespace and restores on a survivor — ``examples/fleet_rag.py`` is
the end-to-end demo, ``serve.py --workers N`` the launcher form.

Reports the paper's user-perceived metrics (throughput, next-token latency,
TTFT) plus the modeled overhead of running the same deployment on each TEE
platform.

    PYTHONPATH=src:. python examples/serve_confidential.py [--requests 8]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import RooflineTerms, TrustDomain
from repro.data.tokenizer import ByteTokenizer
from repro.models import build_model
from repro.runtime import (Engine, FramePolicy, GenerationRequest,
                           SamplingParams)
from benchmarks.common import bench_model_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--coalesce", type=int, default=4,
                    help="tokens per encrypted egress frame for the batch")
    ap.add_argument("--tee", default="tdx",
                    choices=["none", "vm", "sgx", "tdx", "cgpu", "tpu_cc"])
    args = ap.parse_args()

    cfg = bench_model_config(d_model=128, num_layers=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    tok = ByteTokenizer()

    td = TrustDomain(args.tee)
    if td.confidential:
        sealed = td.seal_params(params)
        params = td.load_sealed(sealed, params)
        v = td.make_verifier(cfg.name)
        td_quote = td.quote(v.challenge(), cfg.name)
        v.verify(td_quote)
        print(f"[attested {args.tee}] digest={td_quote.measurement[:16]}...")

    engine = Engine(model, params, max_slots=4, max_len=256,
                    prefill_buckets=(16, 32, 64, 128), trust_domain=td)

    # background batch: low priority, coalesced egress frames, seeded sampling
    prompts = [f"confidential inference request number {i}" for i in
               range(args.requests)]
    t0 = time.monotonic()
    reqs = [engine.submit(GenerationRequest(
                prompt=tok.encode(p), max_new_tokens=args.max_new_tokens,
                params=SamplingParams(temperature=0.7, top_k=40, seed=100 + i),
                frame=FramePolicy(coalesce=args.coalesce)))
            for i, p in enumerate(prompts)]
    # one interactive high-priority request streams token-by-token (its own
    # FramePolicy: per-token frames) while the batch shares the decode loop;
    # if slots run out, a background request is sealed out (encrypted KV)
    # and transparently restored.
    print("streaming (priority=5): ", end="", flush=True)
    for t in engine.stream(GenerationRequest(
            prompt=tok.encode("interactive confidential session"),
            max_new_tokens=args.max_new_tokens, priority=5)):
        print(t, end=" ", flush=True)
    print()
    stats = engine.run()
    wall = time.monotonic() - t0

    print(f"\nserved {stats.total_requests} requests / "
          f"{stats.total_tokens} tokens in {wall:.2f}s")
    print(f"throughput: {stats.throughput_tps:.1f} tok/s   "
          f"next-token latency: p50 {stats.p50_latency_s * 1e3:.1f}ms "
          f"mean {stats.mean_latency_s * 1e3:.1f}ms "
          f"p99 {stats.p99_latency_s * 1e3:.1f}ms   "
          f"TTFT: mean {stats.mean_ttft_s * 1e3:.1f}ms")
    outs = [r.result() for r in reqs]
    if stats.preemptions:
        print(f"sealed-KV preemptions: {stats.preemptions} "
              f"(outputs unchanged; seeded sampling survives restore)")
    if td.confidential:
        ch = td.channel.stats
        print(f"boundary traffic: {ch}")
        print(f"frame coalescing: batch at {args.coalesce} tokens/frame, "
              f"stream at 1 -> {ch.crossings_per_token:.3f} crossings/token; "
              f"per-request frames: "
              f"{[o.egress_frames for o in outs]}")
        # what this deployment would cost on each platform (modeled)
        step = stats.mean_latency_s or 1e-3
        terms = RooflineTerms(compute_s=0.25 * step, memory_s=0.7 * step,
                              collective_s=0.05 * step)
        print("\nmodeled TEE overheads for this operating point:")
        from repro.core import PROFILES, predict
        for prof in PROFILES:
            print(f"  {predict(terms, prof).as_row()}")


if __name__ == "__main__":
    main()
