"""End-to-end confidential serving driver (the paper's measured scenario).

Loads a small model from a sealed checkpoint, attests, then serves a stream
of batched requests with continuous batching — engine v2: bucketed batched
prefill (no prompt truncation), priority admission with sealed-KV
preemption, and per-token streaming egress: every sampled token leaves the
trust domain immediately as its own encrypted frame (the boundary-crossing
pattern the paper's cgpu overhead model prices, Insight 10).

Reports the paper's user-perceived metrics (throughput, next-token latency,
TTFT) plus the modeled overhead of running the same deployment on each TEE
platform.

    PYTHONPATH=src:. python examples/serve_confidential.py [--requests 8]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import RooflineTerms, TrustDomain
from repro.data.tokenizer import ByteTokenizer
from repro.models import build_model
from repro.runtime.engine import Engine
from benchmarks.common import bench_model_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--tee", default="tdx",
                    choices=["none", "vm", "sgx", "tdx", "cgpu", "tpu_cc"])
    args = ap.parse_args()

    cfg = bench_model_config(d_model=128, num_layers=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    tok = ByteTokenizer()

    td = TrustDomain(args.tee)
    if td.confidential:
        sealed = td.seal_params(params)
        params = td.load_sealed(sealed, params)
        v = td.make_verifier(cfg.name)
        td_quote = td.quote(v.challenge(), cfg.name)
        v.verify(td_quote)
        print(f"[attested {args.tee}] digest={td_quote.measurement[:16]}...")

    engine = Engine(model, params, max_slots=4, max_len=256,
                    prefill_buckets=(16, 32, 64, 128), trust_domain=td)

    # one interactive high-priority request streams token-by-token while the
    # background batch (lower priority) shares the decode loop; if slots run
    # out, a background request is sealed out (encrypted KV) and restored.
    prompts = [f"confidential inference request number {i}" for i in
               range(args.requests)]
    t0 = time.monotonic()
    reqs = [engine.submit(tok.encode(p), args.max_new_tokens) for p in prompts]
    print("streaming (priority=5): ", end="", flush=True)
    for t in engine.stream(tok.encode("interactive confidential session"),
                           args.max_new_tokens, priority=5):
        print(t, end=" ", flush=True)
    print()
    stats = engine.run()
    wall = time.monotonic() - t0

    print(f"\nserved {stats.total_requests} requests / "
          f"{stats.total_tokens} tokens in {wall:.2f}s")
    print(f"throughput: {stats.throughput_tps:.1f} tok/s   "
          f"next-token latency: mean {stats.mean_latency_s * 1e3:.1f}ms "
          f"p99 {stats.p99_latency_s * 1e3:.1f}ms   "
          f"TTFT: mean {stats.mean_ttft_s * 1e3:.1f}ms")
    preempted = sum(r.n_preemptions for r in reqs)
    if preempted:
        print(f"sealed-KV preemptions: {preempted}")
    if td.confidential:
        print(f"boundary traffic (one egress frame per token): "
              f"{td.channel.stats}")
        # what this deployment would cost on each platform (modeled)
        step = stats.mean_latency_s or 1e-3
        terms = RooflineTerms(compute_s=0.25 * step, memory_s=0.7 * step,
                              collective_s=0.05 * step)
        print("\nmodeled TEE overheads for this operating point:")
        from repro.core import PROFILES, predict
        for prof in PROFILES:
            print(f"  {predict(terms, prof).as_row()}")


if __name__ == "__main__":
    main()
