"""Quickstart: confidential LLM inference in ~40 lines.

Builds a tiny Llama-family model, seals its weights, attests the trust
domain, and serves a prompt — the full paper pipeline at toy scale.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.models import build_model
from repro.runtime import Engine, GenerationRequest

def main():
    # 1. model
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    # 2. trust domain: seal weights at rest, then load them back inside
    td = TrustDomain("tdx")
    sealed = td.seal_params(params)
    params_in_domain = td.load_sealed(sealed, params)
    print(f"sealed {len(sealed)} tensors; model digest bound to attestation")

    # 3. attestation: client verifies the domain before releasing anything
    verifier = td.make_verifier(config_repr=cfg.name)
    nonce = verifier.challenge()
    quote = td.quote(nonce, config_repr=cfg.name)
    verifier.verify(quote)
    print(f"attestation OK (measurement {quote.measurement[:16]}...)")

    # 4. serve — prompts cross the boundary encrypted
    engine = Engine(model, params_in_domain, max_slots=2, max_len=64,
                    prefill_len=8, trust_domain=td)
    out = engine.generate(GenerationRequest(
        prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=8))
    print(f"generated tokens: {out.tokens} ({out.finish_reason}, "
          f"{out.egress_frames} egress frames)")
    print(f"boundary traffic: {td.channel.stats}")
    print(f"audit log: {[e.kind for e in td.audit]}")

if __name__ == "__main__":
    main()
