"""Train a small LM end to end with the full fault-tolerance stack:
sealed checkpoints every N steps, an injected failure, and a restart that
resumes to the bitwise-identical loss curve.

    PYTHONPATH=src python examples/train_tiny.py [--steps 60] [--d-model 128]
    (--d-model 512 --layers 12 approximates the ~100M-param configuration;
     defaults are CPU-demo sized)
"""

import argparse
import time

import jax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import TrustDomain
from repro.data.pipeline import PackedLMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.distributed.fault_tolerance import FailureInjector, run_with_restarts
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-tiny", family="dense", num_layers=args.layers,
        d_model=args.d_model, num_heads=4, num_kv_heads=4,
        head_dim=args.d_model // 4, d_ff=4 * args.d_model,
        vocab_size=ByteTokenizer.vocab_size, dtype="float32",
        parallel=ParallelConfig(remat="none"))
    model = build_model(cfg)
    total, _ = cfg.params_count()
    print(f"model: {total / 1e6:.1f}M params, {args.steps} steps")

    opt = AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps)
    state = init_train_state(model, opt, jax.random.key(0))
    step_fn = make_train_step(model, opt, microbatches=2)

    def data_factory(cursor):
        ds = PackedLMDataset(batch_size=args.batch, seq_len=args.seq, seed=0)
        it = iter(ds)
        for _ in range(cursor):
            next(it)
        return it

    td = TrustDomain("tdx")  # sealed checkpoints
    mgr = CheckpointManager(args.ckpt_dir, keep_n=2, trust_domain=td)
    injector = FailureInjector(fail_at={args.steps // 2})

    t0 = time.monotonic()
    state, losses, restarts = run_with_restarts(
        state=state, train_step=step_fn, data_factory=data_factory,
        num_steps=args.steps, manager=mgr, checkpoint_every=10,
        injector=injector)
    wall = time.monotonic() - t0

    print(f"survived {restarts} injected failure(s); {wall:.1f}s total")
    for i in range(0, len(losses), max(1, len(losses) // 10)):
        print(f"  step {i:4d}  loss {losses[i]:.4f}")
    print(f"  final loss {losses[-1]:.4f} "
          f"(start {losses[0]:.4f} -> {'improved' if losses[-1] < losses[0] else 'check'})")


if __name__ == "__main__":
    main()
