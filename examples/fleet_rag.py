"""Multi-tenant confidential fleet: two tenants' RAG traffic over two
attested workers, with a mid-serve worker failure.

The single-engine examples trust ONE enclave; a real privacy-sensitive
deployment multiplexes mutually-distrusting tenants over a worker fleet.
This demo drives the whole `repro.fleet` tier:

  * the gateway attests each worker (quote verify -> transport key ->
    per-tenant key domains, one fresh quote per release) and envelope-
    encrypts every prompt to exactly the worker it routes to;
  * tenant-affinity placement steers each tenant's questions to the worker
    already holding that tenant's shared retrieval context resident, so the
    context pages are physical-page-shared instead of re-stored;
  * mid-serve, one worker is killed. Its sealed KV — ciphertext under the
    per-tenant key domains, the at-rest property the paper prices — is the
    only thing that survives, and it migrates to the other worker, where
    every in-flight answer completes byte-identically (seeded sampling
    travels with the request).

    PYTHONPATH=src python examples/fleet_rag.py
"""

import jax
import numpy as np

from repro.configs import smoke_config
from repro.data.tokenizer import ByteTokenizer
from repro.fleet import EngineWorker, Gateway, Orchestrator
from repro.models import build_model
from repro.runtime import GenerationRequest, SamplingParams

ENGINE_KW = dict(max_slots=2, max_len=128, prefill_buckets=(64,),
                 kv_backend="paged", page_size=16, prefix_sharing=True)

TENANT_CONTEXT = {
    "hospital": "context: enclave attestation protects patient records ",
    "bank": "context: sealed ledgers keep account balances private ",
}
QUESTIONS = ["summarize the policy", "who can read the data",
             "what is sealed at rest", "is the channel encrypted"]


def main():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    tok = ByteTokenizer()

    workers = [EngineWorker(f"w{i}", model, params, engine_kw=ENGINE_KW)
               for i in range(2)]
    gateway = Gateway(config_repr=cfg.name)
    for tenant in TENANT_CONTEXT:
        gateway.register_tenant(tenant)
    orch = Orchestrator(gateway, workers, placement="tenant_affinity")
    print(f"fleet: {gateway.stats.attested_workers} workers attested, "
          f"{gateway.stats.keys_released} tenant key-domain releases "
          f"(each on its own fresh quote)")

    # same-length prompts per tenant: shared context head + padded question
    # tail, so the head lands page-aligned and shares physically
    handles = []
    for tenant, ctx in TENANT_CONTEXT.items():
        width = 64 - len(tok.encode(ctx))
        for i, q in enumerate(QUESTIONS):
            prompt = np.asarray(
                tok.encode(ctx + q.ljust(width)[:width]), np.int32)
            handles.append(orch.submit(GenerationRequest(
                prompt=prompt, max_new_tokens=8,
                params=SamplingParams(temperature=0.7, top_k=20,
                                      seed=10 * len(handles)),
                tenant=tenant)))
    for _ in range(4):                  # both workers mid-decode
        orch.step()
    by_worker = {w.name: [t for t in TENANT_CONTEXT if w.serves_tenant(t)]
                 for w in orch.ready_workers()}
    print(f"tenant affinity: {by_worker}")

    victim = max(orch.ready_workers(), key=lambda w: w.load())
    orch.kill(victim.name)
    print(f"killed {victim.name} mid-decode; its sealed KV migrated under "
          f"the tenant key domains")
    stats = orch.run()

    assert all(h.finished for h in handles)
    print(f"served {stats.total_requests} requests / {stats.total_tokens} "
          f"tokens across the failure")
    print(f"migration: {orch.stats.migrations} sealed moves / "
          f"{orch.stats.migrated_bytes} B "
          f"(priced per request in ServeStats: {stats.migrations} moves)")
    shared = sum(w.engine.kv.shared_page_maps for w in workers)
    print(f"prefix sharing across the fleet: {shared} shared page maps "
          f"(each tenant's context stored once per worker, not per request)")
    print(f"fleet boundary totals: {orch.channel_totals()}")


if __name__ == "__main__":
    main()
