"""Confidential RAG (paper §VI): the corpus, index, retrieval, and generation
all live inside the trust domain; queries arrive encrypted.

    PYTHONPATH=src python examples/rag_confidential.py
"""

import jax

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.data.pipeline import synthetic_text
from repro.models import build_model
from repro.rag.pipeline import RAGPipeline
from repro.runtime.engine import Engine


def main():
    docs = {f"doc{i}": synthetic_text(i, 10) for i in range(25)}
    docs["policy"] = ("confidential enclave attestation protects llama "
                      "inference and patient record throughput")

    td = TrustDomain("tdx")
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    engine = Engine(model, params, max_slots=2, max_len=96, prefill_len=16,
                    trust_domain=td)

    for mode in ("bm25", "bm25+rerank"):
        rag = RAGPipeline(docs, mode=mode, engine=engine, trust_domain=td)
        res = rag.query("which enclave protects patient records?",
                        top_k=2, max_new_tokens=8)
        print(f"[{mode}] top docs: {[d for d, _ in res.retrieved]} "
              f"(retrieval {res.retrieval_s * 1e3:.1f}ms, "
              f"generation {res.generation_s * 1e3:.0f}ms)")
    print(f"boundary traffic: {td.channel.stats}")


if __name__ == "__main__":
    main()
