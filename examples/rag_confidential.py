"""Confidential RAG (paper §VI): the corpus, index, retrieval, and generation
all live inside the trust domain; queries arrive encrypted.

The second half demos *shared context pages*: several questions over the
same retrieved context served on a prefix-sharing paged engine. The context
prefix is tokenized once per physical page pool — every request past the
first maps the resident pages instead of storing (and, under preemption,
sealing) its own copy, which is exactly the memory the paper identifies as
the scarce attested resource in a TEE.

    PYTHONPATH=src python examples/rag_confidential.py
"""

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.data.pipeline import synthetic_text
from repro.data.tokenizer import ByteTokenizer
from repro.models import build_model
from repro.rag.pipeline import RAGPipeline
from repro.runtime import GenerationRequest
from repro.runtime.engine import Engine


def retrieval_demo(model, params, docs):
    td = TrustDomain("tdx")
    engine = Engine(model, params, max_slots=2, max_len=96, prefill_len=16,
                    trust_domain=td)
    for mode in ("bm25", "bm25+rerank"):
        rag = RAGPipeline(docs, mode=mode, engine=engine, trust_domain=td)
        res = rag.query("which enclave protects patient records?",
                        top_k=2, max_new_tokens=8)
        print(f"[{mode}] top docs: {[d for d, _ in res.retrieved]} "
              f"(retrieval {res.retrieval_s * 1e3:.1f}ms, "
              f"generation {res.generation_s * 1e3:.0f}ms)")
    print(f"boundary traffic: {td.channel.stats}")


def shared_context_demo(model, params, docs):
    """Many questions over ONE retrieved context: the context pages are
    physical-page-shared across the batch (position-aligned because every
    prompt has the same length and the questions ride at the tail)."""
    tok = ByteTokenizer()
    td = TrustDomain("tdx")
    bucket, page_size = 128, 16
    engine = Engine(model, params, max_slots=4, max_len=192,
                    prefill_buckets=(bucket,), trust_domain=td,
                    kv_backend="paged", page_size=page_size,
                    prefix_sharing=True)
    context = "context: " + docs["policy"]
    questions = ["which enclave protects records?",
                 "what throughput is achievable?",
                 "who attests the llama model?",
                 "is patient data sealed at rest?"]
    # same-length prompts: context head + space-padded question tail, so the
    # shared head lands on identical (page-aligned) positions in every slot
    width = bucket - len(tok.encode(context + " question: "))
    reqs = []
    for i, q in enumerate(questions):
        prompt = np.asarray(tok.encode(
            context + " question: " + q.ljust(width)[:width]), np.int32)
        assert len(prompt) == bucket
        need, eff = engine.effective_kv_need(prompt, 8)
        if i > 0:
            # the context pages went resident with the first request, so
            # later ones charge only their private tail against the pool
            assert eff < need
        reqs.append(engine.submit(GenerationRequest(prompt=prompt,
                                                    max_new_tokens=8)))
        if i == 0:
            engine.step()   # prefill the first: its context pages go resident
    stats = engine.run()
    shared_tokens = stats.shared_pages * page_size
    print(f"[shared-context] {len(questions)} questions over one "
          f"{len(tok.encode(context))}-token context: "
          f"{stats.shared_pages} page mappings shared "
          f"(~{shared_tokens} context tokens never re-stored), "
          f"{stats.cow_copies} CoW copies, "
          f"{engine.kv.pages_written} pages written")
    assert all(r.finished for r in reqs)
    assert stats.shared_pages > 0


def main():
    docs = {f"doc{i}": synthetic_text(i, 10) for i in range(25)}
    docs["policy"] = ("confidential enclave attestation protects llama "
                      "inference and patient record throughput")

    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    retrieval_demo(model, params, docs)
    shared_context_demo(model, params, docs)


if __name__ == "__main__":
    main()
