"""One benchmark per paper table/figure (DESIGN.md §6 index).

Measured benchmarks exercise OUR confidential substrate for real on this
CPU (crypto on the data path); modeled benchmarks evaluate the calibrated
TEE overhead model. Every function returns (and prints) Row records:
``name,us_per_call,derived``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, build_bench_model, emit, time_fn
from repro.core import PROFILES, RooflineTerms, TrustDomain, predict
from repro.core.overheads import sweep_batch
from repro.costs.model import (Workload, best_cpu_cost, crossover_batch,
                               usd_per_mtok, vcpu_sweep)
from repro.data.pipeline import synthetic_text
from repro.models import layers
from repro.quant import quantize_int8, qmatmul_ref
from repro.rag.pipeline import RAGPipeline
from repro.runtime import Engine, GenerationRequest


# ---------------------------------------------------------------------------
# Fig 3: backend comparison (HF vs vLLM vs IPEX analogue)
# ---------------------------------------------------------------------------

def fig03_frameworks() -> List[Row]:
    """Three inference backends for the same decode step:
    naive-f32 (HF analogue), fused-scan (IPEX-bf16 analogue),
    int8-weights (IPEX-int8/AMX analogue)."""
    rows = []
    cfg, model, params = build_bench_model(dtype="float32")
    b, s = 4, 64
    cache = model.init_cache(b, s + 8)
    pf = {"tokens": jnp.ones((b, s), jnp.int32)}
    _, cache = jax.jit(model.prefill)(params, pf, cache)
    tok = jnp.ones((b, 1), jnp.int32)

    # naive: python-loop layers (no scan), f32
    naive_cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, scan_layers=False))
    from repro.models import build_model as _bm
    naive_model = _bm(naive_cfg)
    naive_decode = jax.jit(naive_model.decode_step)   # jit once (bound-method
    decode = jax.jit(model.decode_step)               # identity gotcha)
    t_naive = time_fn(lambda: naive_decode(params, tok, cache))
    t_fused = time_fn(lambda: decode(params, tok, cache))

    # int8 weight path on the dominant matmuls (AMX analogue): time the
    # MLP+attention projection GEMMs in int8 vs f32 at decode shapes
    d, f = cfg.d_model, cfg.d_ff
    x = jax.random.normal(jax.random.key(0), (b, d), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (d, f), jnp.float32)
    wq = quantize_int8(w)
    mm32 = jax.jit(lambda a, b_: a @ b_)
    mm8 = jax.jit(qmatmul_ref)
    t_f32_mm = time_fn(lambda: mm32(x, w))
    t_int8_mm = time_fn(lambda: mm8(x, wq))

    rows.append(Row("fig03/naive_f32_decode", t_naive * 1e6,
                    f"tok_s={b / t_naive:.1f}"))
    rows.append(Row("fig03/fused_scan_decode", t_fused * 1e6,
                    f"tok_s={b / t_fused:.1f};speedup_vs_naive={t_naive / t_fused:.2f}x"))
    rows.append(Row("fig03/gemm_f32", t_f32_mm * 1e6, "dominant decode GEMM"))
    rows.append(Row("fig03/gemm_int8", t_int8_mm * 1e6,
                    f"int8_vs_f32={t_f32_mm / t_int8_mm:.2f}x"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Fig 4: TEE throughput/latency overheads (measured + modeled)
# ---------------------------------------------------------------------------

def fig04_tee_overheads() -> List[Row]:
    rows = []
    cfg, model, params = build_bench_model()

    def serve(td_mode: str):
        td = TrustDomain(td_mode)
        if td.confidential:  # sealed-weights load path (the real crypto cost)
            sealed = td.seal_params(params)
            p = td.load_sealed(sealed, params)
        else:
            p = params
        eng = Engine(model, p, max_slots=4, max_len=96, prefill_len=16,
                     trust_domain=td)
        t0 = time.monotonic()
        for i in range(4):
            eng.submit(GenerationRequest(prompt=np.full(16, i + 2, np.int32),
                                         max_new_tokens=8))
        stats = eng.run()
        wall = time.monotonic() - t0
        return stats, wall

    serve("none")  # warmup: populate the jit cache so both modes compare warm
    s_plain, w_plain = serve("none")
    s_tee, w_tee = serve("tdx")
    thr_ov = w_tee / w_plain - 1
    lat_ov = (s_tee.mean_latency_s / s_plain.mean_latency_s - 1
              if s_plain.mean_latency_s else 0.0)
    noise = "(within run-to-run noise)" if abs(thr_ov) < 0.1 else ""
    rows.append(Row("fig04/measured_plain", w_plain * 1e6,
                    f"thr={s_plain.throughput_tps:.1f}tok_s"))
    rows.append(Row("fig04/measured_confidential", w_tee * 1e6,
                    f"thr_overhead={thr_ov * 100:.1f}%{noise};"
                    f"lat_overhead={lat_ov * 100:.1f}%"))

    # modeled: paper's platforms at CPU-scale single-socket terms
    terms = RooflineTerms(compute_s=0.012, memory_s=0.045, collective_s=0.002)
    for prof in ("vm", "sgx", "tdx"):
        ov = predict(terms, prof)
        rows.append(Row(f"fig04/modeled_{prof}", ov.t_tee_s * 1e6,
                        f"overhead={ov.overhead * 100:.2f}%"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Figs 5-6: NUMA / hugepages placement penalties (modeled)
# ---------------------------------------------------------------------------

def fig05_06_placement() -> List[Row]:
    rows = []
    terms = RooflineTerms(compute_s=0.012, memory_s=0.055, collective_s=0.008)
    for prof in ("tdx", "sgx"):
        good = predict(terms, prof)
        bad_numa = predict(terms, prof, numa_bound=False)
        rows.append(Row(f"fig05/{prof}_numa_bound", good.t_tee_s * 1e6,
                        f"overhead={good.overhead * 100:.1f}%"))
        rows.append(Row(f"fig05/{prof}_numa_broken", bad_numa.t_tee_s * 1e6,
                        f"overhead={bad_numa.overhead * 100:.1f}%"))
    no_huge = predict(terms, "tdx", hugepages_fixed=False)
    rows.append(Row("fig06/tdx_no_1g_hugepages", no_huge.t_tee_s * 1e6,
                    f"overhead={no_huge.overhead * 100:.1f}%"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Fig 7: per-block decode breakdown (measured)
# ---------------------------------------------------------------------------

def fig07_per_block() -> List[Row]:
    rows = []
    cfg, model, params = build_bench_model(d_model=256, num_layers=2)
    b, s = 4, 256
    d, h, hd, f = cfg.d_model, cfg.num_heads, cfg.head_dim_, cfg.d_ff
    lp = jax.tree.map(lambda x: x[0], params["layers"])["slot_0"]
    x = jax.random.normal(jax.random.key(0), (b, s, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    from repro.models.transformer import _attn_cfg
    acfg = _attn_cfg(cfg)

    comps = {
        "input_norm": jax.jit(lambda: layers.rmsnorm(lp["pre_norm"], x)),
        "self_attention": jax.jit(lambda: layers.attention_forward(lp["attn"], acfg, x, pos)),
        "post_norm": jax.jit(lambda: layers.rmsnorm(lp["post_norm"], x)),
        "mlp_swiglu": jax.jit(lambda: layers.swiglu(lp["ffn"], x)),
    }
    times = {k: time_fn(v) for k, v in comps.items()}
    total = sum(times.values())
    for k, t in times.items():
        rows.append(Row(f"fig07/{k}", t * 1e6, f"share={t / total * 100:.1f}%"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Fig 8: AMX (int8/bf16 matrix units) vs none, across batch (measured)
# ---------------------------------------------------------------------------

def fig08_amx() -> List[Row]:
    """int8-GEMM (AMX/MXU analogue) vs f32 GEMM across batch sizes: the
    low-precision advantage grows with arithmetic intensity (Insight 8)."""
    rows = []
    d, f = 512, 2048
    w = jax.random.normal(jax.random.key(1), (d, f), jnp.float32)
    wq = quantize_int8(w)
    mm32 = jax.jit(lambda a: a @ w)
    mm8 = jax.jit(qmatmul_ref)
    for batch in (1, 8, 32, 128):
        x = jax.random.normal(jax.random.key(0), (batch, d), jnp.float32)
        t32 = time_fn(lambda: mm32(x), iters=10)
        t8 = time_fn(lambda: mm8(x, wq), iters=10)
        rows.append(Row(f"fig08/batch{batch}", t8 * 1e6,
                        f"int8_speedup={t32 / t8:.2f}x"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Fig 9: overhead vs batch size (measured boundary crypto + modeled memcrypt)
# ---------------------------------------------------------------------------

def fig09_batch_scaling() -> List[Row]:
    rows = []
    cfg, model, params = build_bench_model()
    for batch in (1, 2, 4, 8):
        cache = model.init_cache(batch, 48)
        pf = {"tokens": jnp.ones((batch, 16), jnp.int32)}
        prefill = jax.jit(model.prefill)
        _, cache0 = prefill(params, pf, cache)
        decode = jax.jit(model.decode_step)
        tok = jnp.ones((batch, 1), jnp.int32)
        t_step = time_fn(lambda: decode(params, tok, cache0))
        # measured boundary crypto for this batch (ingress+egress per request)
        td = TrustDomain("tdx")
        t0 = time.perf_counter()
        for i in range(batch):
            td.ingress(np.full(16, 3, np.int32))
            td.egress(np.full(8, 4, np.int32))
        t_crypto = time.perf_counter() - t0
        per_tok_ov = t_crypto / (batch * 8) / t_step
        modeled = sweep_batch("tdx", compute_per_token_s=t_step / batch / 4,
                              memory_s=t_step * 0.75, batches=[batch])[batch]
        rows.append(Row(f"fig09/batch{batch}", t_step * 1e6,
                        f"measured_boundary_ov={per_tok_ov * 100:.2f}%;"
                        f"modeled_tdx_ov={modeled * 100:.2f}%"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Fig 10: overhead vs input size (measured)
# ---------------------------------------------------------------------------

def fig10_input_scaling() -> List[Row]:
    rows = []
    cfg, model, params = build_bench_model()
    td = TrustDomain("tdx")
    prefill = jax.jit(model.prefill, static_argnames=())
    for s in (16, 64, 256):
        cache = model.init_cache(2, s + 8)
        pf = {"tokens": jnp.ones((2, s), jnp.int32)}
        t_pref = time_fn(lambda: prefill(params, pf, cache))
        t0 = time.perf_counter()
        td.ingress(np.ones((2, s), np.int32))
        t_crypto = time.perf_counter() - t0
        ov = t_crypto / t_pref
        rows.append(Row(f"fig10/input{s}", t_pref * 1e6,
                        f"boundary_ov={ov * 100:.2f}%"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Fig 11: cGPU overheads vs batch/input (modeled, calibrated)
# ---------------------------------------------------------------------------

def fig11_cgpu() -> List[Row]:
    rows = []
    # H100-scale decode step terms for llama2-7b: weight streaming at HBM
    # roofline (13.4 GB @ 3.9 TB/s = 3.4 ms/step) + batch-scaled compute.
    memory_s = 13.4e9 / 3.9e12
    for batch in (1, 16, 64, 256):
        compute_s = 2 * 6.7e9 * batch / 990e12
        terms = RooflineTerms(compute_s=compute_s, memory_s=memory_s)
        ov = predict(terms, "cgpu")
        rows.append(Row(f"fig11/batch{batch}", ov.t_tee_s * 1e6,
                        f"cgpu_overhead={ov.overhead * 100:.2f}%"))
    for in_len in (128, 1024, 8192):
        # prefill-ish: compute grows ~quadratically via attention
        compute_s = (2 * 6.7e9 * 4 * in_len + 4 * 4096 * in_len ** 2 * 32) / 990e12
        terms = RooflineTerms(compute_s=compute_s, memory_s=memory_s)
        ov = predict(terms, "cgpu")
        rows.append(Row(f"fig11/input{in_len}", ov.t_tee_s * 1e6,
                        f"cgpu_overhead={ov.overhead * 100:.2f}%"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Figs 12-13: cost model
# ---------------------------------------------------------------------------

def fig12_13_cost() -> List[Row]:
    rows = []
    w = Workload(n_params=6.7e9, batch=1, in_tokens=128, out_tokens=128)
    for v, d in vcpu_sweep(dataclasses.replace(w, batch=64), "emr-amx-tdx",
                           [8, 16, 32, 64]).items():
        rows.append(Row(f"fig12/vcpu{v}", 1e6 / max(d["tokens_per_s"], 1e-9),
                        f"usd_per_mtok={d['usd_per_mtok']:.2f}"))
    for b in (1, 4, 16, 64, 128, 256):
        wb = dataclasses.replace(w, batch=b)
        cpu = best_cpu_cost(wb, "emr-amx-tdx")
        gpu = usd_per_mtok(wb, "h100-cc")
        tpu = usd_per_mtok(wb, "v5e-cc")
        rows.append(Row(f"fig12/batch{b}", 0.0,
                        f"cpu=${cpu:.2f};cgpu=${gpu:.2f};v5e_cc=${tpu:.2f};"
                        f"cpu_adv={(gpu / cpu - 1) * 100:.0f}%"))
    x = crossover_batch(w, "emr-amx-tdx", "h100-cc",
                        [1, 2, 4, 8, 16, 32, 64, 128, 256, 512])
    rows.append(Row("fig12/crossover_batch", 0.0,
                    f"batch={x};paper_reports~128"))
    for s in (128, 256, 512, 1024):
        ws = dataclasses.replace(w, batch=4, in_tokens=s)
        rows.append(Row(f"fig13/input{s}", 0.0,
                        f"cpu=${best_cpu_cost(ws, 'emr-amx-tdx'):.2f};"
                        f"cgpu=${usd_per_mtok(ws, 'h100-cc'):.2f}"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Fig 14: RAG pipelines in the TEE (measured)
# ---------------------------------------------------------------------------

def fig14_rag() -> List[Row]:
    """Mean evaluation time per query, plain vs TDX, three retrieval modes.
    The paper's BEIR runs are batch evaluations: boundary crypto amortizes
    over the batch, leaving the TEE overhead in single digits."""
    rows = []
    docs = {f"d{i}": synthetic_text(i, 30) for i in range(200)}
    docs["hit"] = "confidential enclave attestation llama inference " * 5
    queries = ["confidential enclave attestation", "decode throughput batch",
               "memory encryption keystream", "expert shard pipeline"] * 4
    for mode in ("bm25", "bm25+rerank", "dense"):
        times = {}
        for tee in ("none", "tdx"):
            p = RAGPipeline(docs, mode=mode, trust_domain=TrustDomain(tee))
            for q in queries[:2]:
                p.retrieve(q)  # warmup (jit, caches)
            td = p.td
            t0 = time.perf_counter()
            # batch evaluation: one boundary crossing for the whole query set
            blob = "\n".join(queries).encode()
            clear = bytes(td.ingress(np.frombuffer(blob, np.uint8))).decode()
            for q in clear.split("\n"):
                p.retrieve(q)
            times[tee] = (time.perf_counter() - t0) / len(queries)
        ov = times["tdx"] / times["none"] - 1
        rows.append(Row(f"fig14/{mode}", times["tdx"] * 1e6,
                        f"tee_overhead={ov * 100:.1f}%"))
    return emit(rows)


# ---------------------------------------------------------------------------
# Table I: summary matrix
# ---------------------------------------------------------------------------

def table1_summary() -> List[Row]:
    rows = []
    terms = RooflineTerms(compute_s=0.012, memory_s=0.045, collective_s=0.002)
    for name, prof in PROFILES.items():
        ov = predict(terms, name)
        rows.append(Row(f"table1/{name}", ov.t_tee_s * 1e6,
                        f"single_resource_ov={ov.overhead * 100:.1f}%;"
                        f"mem_tax={prof.mem_tax};link_tax={prof.link_tax};"
                        f"boundary_us={prof.fixed_boundary_s * 1e6:.0f}"))
    return emit(rows)


ALL = [fig03_frameworks, fig04_tee_overheads, fig05_06_placement,
       fig07_per_block, fig08_amx, fig09_batch_scaling, fig10_input_scaling,
       fig11_cgpu, fig12_13_cost, fig14_rag, table1_summary]
