"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Measured rows run OUR
confidential substrate on this CPU; modeled rows evaluate the calibrated TEE
overhead model (DESIGN.md §2 'measured vs modeled').

    PYTHONPATH=src python -m benchmarks.run [fig03 fig09 ...]
"""

import sys
import time


def main() -> None:
    from benchmarks import figs

    names = sys.argv[1:]
    print("name,us_per_call,derived")
    t0 = time.time()
    for fn in figs.ALL:
        if names and not any(fn.__name__.startswith(n) for n in names):
            continue
        try:
            fn()
        except Exception as e:  # report, keep going
            print(f"{fn.__name__}/ERROR,0,{type(e).__name__}:{str(e)[:120]}")
    print(f"# total_wall_s={time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
