#!/usr/bin/env bash
# Fast CI gate (minutes): the "not slow" test tier plus a one-request smoke
# of the serving launcher, so the CLI path can't silently rot again — the
# launcher exercises the full seal -> attest -> key-release -> encrypted
# serving pipeline with the v3 flags (buckets, coalescing, seeded sampling).
#
#   bash benchmarks/ci_fast.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -m "not slow"

python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 1 --max-new-tokens 4 --prefill-buckets 8,16 \
    --coalesce 2 --sample-temp 0.7 --top-k 8 --top-p 0.9 --seed 0

# paged-backend smoke: page-granular admission + sealed preemption under a
# priority mix, through the same seal -> attest -> serve pipeline
python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 4 --max-new-tokens 4 --prefill-buckets 8,16 --slots 2 \
    --priority-mix 0:3,5:1 --kv-backend paged --page-size 8 --seed 1 \
    --sample-temp 0.7

# kernel-decode smoke: the same paged priority-mix workload through the
# table-walking Pallas decode kernel (kv_decode=kernel). The decode-mode
# stats line must confirm the kernel path actually served the run, and the
# fused-unseal savings hook must report (zero pages is fine here — fused
# admission needs full-page restores, covered by the test tier).
python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 4 --max-new-tokens 4 --prefill-buckets 8,16 --slots 2 \
    --priority-mix 0:3,5:1 --kv-backend paged --page-size 8 \
    --kv-decode kernel --seed 1 --sample-temp 0.7 \
    | tee /tmp/ci_kernel_smoke.out
grep -q "kv decode: mode=kernel" /tmp/ci_kernel_smoke.out
grep -q "fused-unseal savings" /tmp/ci_kernel_smoke.out

# prefix-sharing smoke: the same shared-prefix workload (common 8-token
# head) on a deliberately tight on-demand page pool, with sharing off and
# on. Off must survive via capacity preemption (sealed evictions); on must
# map shared pages (nonzero shared-page maps) and seal strictly fewer
# bytes — the shared head is resident once, so the pool never runs dry.
SHARE_ARGS="--arch deepseek-7b --smoke --tee tdx --requests 6 \
    --max-new-tokens 4 --prefill-buckets 16 --prefill-len 16 --slots 3 \
    --kv-backend paged --page-size 8 --num-pages 7 --kv-alloc ondemand \
    --seed 1 --sample-temp 0.7 --shared-prefix-len 8"
python -m repro.launch.serve $SHARE_ARGS | tee /tmp/ci_share_off.out
python -m repro.launch.serve $SHARE_ARGS --prefix-sharing \
    | tee /tmp/ci_share_on.out
SEALED_OFF=$(sed -n 's/.*evictions \/ \([0-9]*\) B out.*/\1/p' /tmp/ci_share_off.out)
SEALED_ON=$(sed -n 's/.*evictions \/ \([0-9]*\) B out.*/\1/p' /tmp/ci_share_on.out)
SHARED_MAPS=$(sed -n 's/.*prefix sharing: \([0-9]*\) shared-page maps.*/\1/p' /tmp/ci_share_on.out)
[ -n "$SEALED_OFF" ] && [ "$SEALED_OFF" -gt 0 ] \
    || { echo "unshared run sealed nothing — smoke lost its preemptions"; exit 1; }
[ -n "$SHARED_MAPS" ] && [ "$SHARED_MAPS" -gt 0 ] \
    || { echo "prefix-sharing run mapped no shared pages"; exit 1; }
[ "${SEALED_ON:-0}" -lt "$SEALED_OFF" ] \
    || { echo "sharing did not reduce sealed bytes (${SEALED_ON:-0} vs $SEALED_OFF)"; exit 1; }
echo "prefix-sharing smoke OK: $SHARED_MAPS shared maps, sealed ${SEALED_ON:-0}B < ${SEALED_OFF}B"

# page-store smoke: two epochs of the same recurring-prefix mix through the
# persistent sealed-page store. The store line must show nonzero hits and
# the second (warm) epoch must write strictly fewer pages than the first —
# recurring full pages restore from retained ciphertext instead of
# re-prefilling.
python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 4 --max-new-tokens 6 --slots 2 --max-len 64 \
    --prefill-len 16 --prefill-buckets 16 --kv-backend paged --page-size 8 \
    --shared-prefix-len 16 --page-store --store-budget-pages 16 \
    --epochs 2 --seed 5 --sample-temp 0.7 | tee /tmp/ci_store_smoke.out
STORE_HITS=$(sed -n 's/^store hits: \([0-9]*\) \/.*/\1/p' /tmp/ci_store_smoke.out)
PAGES_E0=$(sed -n 's/^epoch 0: \([0-9]*\) pages written.*/\1/p' /tmp/ci_store_smoke.out)
PAGES_E1=$(sed -n 's/^epoch 1: \([0-9]*\) pages written.*/\1/p' /tmp/ci_store_smoke.out)
[ -n "$STORE_HITS" ] && [ "$STORE_HITS" -gt 0 ] \
    || { echo "page-store run reported no store hits"; exit 1; }
[ -n "$PAGES_E0" ] && [ -n "$PAGES_E1" ] && [ "$PAGES_E1" -lt "$PAGES_E0" ] \
    || { echo "warm epoch did not write fewer pages (${PAGES_E1:-?} vs ${PAGES_E0:-?})"; exit 1; }
echo "page-store smoke OK: $STORE_HITS store hits, warm ${PAGES_E1} < cold ${PAGES_E0} pages written"

# continuous-batching smoke: step-level admission with a per-step token
# budget through the same pipeline; must report its budget/backfill line
python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 4 --max-new-tokens 4 --prefill-buckets 8,16 --slots 2 \
    --continuous-batching --step-tokens 18 --seed 3 --sample-temp 0.7 \
    | tee /tmp/ci_cb_smoke.out
grep -q "continuous batching" /tmp/ci_cb_smoke.out

# two-plan smoke: prefill disaggregated onto a dedicated ComputePlan; the
# KV handoff must be priced as sealed bytes across the plan boundary
python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 4 --max-new-tokens 4 --prefill-buckets 8,16 --slots 2 \
    --prefill-plan dedicated --seed 3 --sample-temp 0.7 \
    | tee /tmp/ci_2plan_smoke.out
HANDOFF_B=$(sed -n 's/.*sealed handoff: [0-9]* prefill->decode handoffs \/ \([0-9]*\) B.*/\1/p' /tmp/ci_2plan_smoke.out)
[ -n "$HANDOFF_B" ] && [ "$HANDOFF_B" -gt 0 ] \
    || { echo "two-plan run priced no sealed handoff bytes"; exit 1; }
echo "two-phase smoke OK: ${HANDOFF_B}B sealed across the plan boundary"

# fleet smoke: 2 attested workers (own TrustDomain each) behind the gateway
# + orchestrator, one killed mid-serve. The attestation line must show both
# workers admitted and the migration line must price nonzero sealed bytes —
# the kill actually moved in-flight KV under the tenant key domains.
python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 6 --max-new-tokens 6 --prefill-buckets 8,16 --slots 2 \
    --workers 2 --tenants 2 --kill-worker-at 3 --seed 4 --sample-temp 0.7 \
    | tee /tmp/ci_fleet_smoke.out
ATTESTED=$(sed -n 's/.*fleet: \([0-9]*\) workers attested.*/\1/p' /tmp/ci_fleet_smoke.out)
MIGRATED_B=$(sed -n 's/.*migration: [0-9]* sealed moves \/ \([0-9]*\) B migrated.*/\1/p' /tmp/ci_fleet_smoke.out)
[ "${ATTESTED:-0}" -eq 2 ] \
    || { echo "fleet smoke attested ${ATTESTED:-0} workers, wanted 2"; exit 1; }
[ -n "$MIGRATED_B" ] && [ "$MIGRATED_B" -gt 0 ] \
    || { echo "worker kill migrated no sealed KV"; exit 1; }
echo "fleet smoke OK: $ATTESTED workers attested, ${MIGRATED_B}B migrated across the kill"

# mesh smoke: 2 forced host devices, the engine spanning a dp=2 mesh (batch
# sharded, params FSDP-placed and gathered per step). Must print the
# measured-vs-modeled link-tax line — the collective path is live, not
# just modeled.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
python -m repro.launch.serve --arch deepseek-7b --smoke --tee cgpu \
    --requests 4 --max-new-tokens 4 --prefill-buckets 8,16 --slots 2 \
    --mesh dp=2 --seed 2 | tee /tmp/ci_mesh_smoke.out
grep -q "link-tax" /tmp/ci_mesh_smoke.out

echo "ci_fast OK"
