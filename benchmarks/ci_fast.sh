#!/usr/bin/env bash
# Fast CI gate (minutes): the "not slow" test tier plus a one-request smoke
# of the serving launcher, so the CLI path can't silently rot again — the
# launcher exercises the full seal -> attest -> key-release -> encrypted
# serving pipeline with the v3 flags (buckets, coalescing, seeded sampling).
#
#   bash benchmarks/ci_fast.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -m "not slow"

python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 1 --max-new-tokens 4 --prefill-buckets 8,16 \
    --coalesce 2 --sample-temp 0.7 --top-k 8 --top-p 0.9 --seed 0

# paged-backend smoke: page-granular admission + sealed preemption under a
# priority mix, through the same seal -> attest -> serve pipeline
python -m repro.launch.serve --arch deepseek-7b --smoke --tee tdx \
    --requests 4 --max-new-tokens 4 --prefill-buckets 8,16 --slots 2 \
    --priority-mix 0:3,5:1 --kv-backend paged --page-size 8 --seed 1 \
    --sample-temp 0.7

# mesh smoke: 2 forced host devices, the engine spanning a dp=2 mesh (batch
# sharded, params FSDP-placed and gathered per step). Must print the
# measured-vs-modeled link-tax line — the collective path is live, not
# just modeled.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
python -m repro.launch.serve --arch deepseek-7b --smoke --tee cgpu \
    --requests 4 --max-new-tokens 4 --prefill-buckets 8,16 --slots 2 \
    --mesh dp=2 --seed 2 | tee /tmp/ci_mesh_smoke.out
grep -q "link-tax" /tmp/ci_mesh_smoke.out

echo "ci_fast OK"
