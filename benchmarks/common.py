"""Shared benchmark utilities: timing, the mini measurement model, rows."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import build_model


def bench_model_config(d_model: int = 128, num_layers: int = 4,
                       vocab: int = 512, dtype: str = "float32") -> ModelConfig:
    """Llama2-family config scaled to CPU measurement size. The paper's
    subject is Llama2; the *shape* of its overhead curves is what we
    reproduce — absolute times are container-CPU times."""
    return ModelConfig(
        name="llama2-mini", family="dense", num_layers=num_layers,
        d_model=d_model, num_heads=4, num_kv_heads=4, head_dim=d_model // 4,
        d_ff=4 * d_model, vocab_size=vocab, dtype=dtype,
        parallel=ParallelConfig(remat="none"),
    )


def build_bench_model(seed: int = 0, **kw):
    cfg = bench_model_config(**kw)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(seed))
    return cfg, model, params


def time_fn(fn: Callable[[], object], *, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (outputs block_until_ready'd)."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def emit(rows: List[Row]) -> List[Row]:
    for r in rows:
        print(r.csv(), flush=True)
    return rows
