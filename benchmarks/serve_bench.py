"""Serving benchmark: v1-style static prefill vs v2 bucketed batched prefill,
the v3 frame-coalescing sweep (Insight-10 fixed-cost amortization), and the
v4 slot-dense vs paged KV-backend sweep under a long-context mix with forced
preemption (sealed bytes ∝ tokens used, not capacity reserved).

Measures the paper's two user-perceived serving metrics (§III-C) —
throughput (tokens/s) and next-token latency — plus time-to-first-token and
the boundary-crossing counts that drive the cgpu fixed-cost model
(Insight 10), for two engine configurations over the same mixed-length
workload:

  v1-style : one static prefill bucket, one request per prefill call
             (the seed engine's shape; long prompts now chunk instead of
             silently truncating, so outputs are comparable)
  v2       : power-of-two prefill buckets, same-bucket requests batched
             into one jitted prefill call

The coalescing sweep then serves the same workload with FramePolicy
coalesce ∈ {1, 4, 16}: decoded output must be unchanged while boundary
crossings per token fall as 1/N — the amortization curve behind the paper's
observation that cGPU overhead is fixed-cost-per-crossing dominated. The
modeled column prices each point with the cgpu profile's
``fixed_boundary_s``.

The KV-backend sweep (``--kv-backend both``, the default) serves a
long-context seeded-sampling mix on the slot-dense and the paged backend,
forcing sealed-KV preemptions with a late high-priority wave. It asserts
byte-identical outputs between the backends and strictly fewer sealed
bytes per preemption for paged — the Insight-10 claim that what crosses
the boundary (pages actually holding tokens vs a whole max_len slot) is
the lever.

The prefix-sharing sweep serves a shared-prefix workload (one long common
head + distinct same-length tails) with ``prefix_sharing`` off and on,
both under on-demand allocation, forcing preemption with a high-priority
wave. It asserts byte-identical outputs, strictly fewer physical pages
written, and strictly lower sealed bytes with sharing on — the tentpole
claim that a shared prefix is stored once and sealed at most once.

The two-phase sweep serves a burst of long prompts arriving just ahead of
short ones — the TTFT operating point §III-C's latency numbers care about —
three ways: the v5 baseline (batched admission), step-level continuous
batching (``continuous_batching=True``: chunked prefill interleaves into
decode steps under a per-step token budget, short requests backfill budget
a long head chunk cannot use), and disaggregated prefill
(``prefill_plan="dedicated"``: prefill runs on its own ComputePlan and the
finished KV rows cross the plan boundary as a sealed handoff priced in
``ChannelStats``). It asserts byte-identical outputs across all three
modes, a strictly lower TTFT p99 for continuous batching, and nonzero
sealed handoff bytes for the two-plan mode, then writes every mode's
serving metrics to ``BENCH_serve.json``.

The page-store sweep serves a recurring-prompt mix (a RAG-style shared head
with distinct tails) for two epochs on one engine carrying a persistent
sealed-page store: the cold epoch publishes full pages at release, the warm
epoch restores them content-addressed (MAC-verified) — asserting a nonzero
warm hit rate, strictly fewer pages written, byte-identical tokens, and the
``overheads``-priced restore-vs-recompute breakeven.

The mesh sweep (``--mesh dp=2`` or ``dp=2,tp=2``; relaunches itself with
forced host devices when needed) serves the same seeded workload on a
single device and on a mesh-spanning engine, asserts byte-identical
outputs on dp-only meshes, and reports the *measured* collective path:
``ChannelStats.collective_bytes``/``collective_s`` (HLO-parsed bytes +
all-gather probe on the real mesh) against the closed-form bytes/ICI_BW
estimate, priced through ``overheads.predict`` both ways — the
measured-vs-modeled link_tax delta for the paper's §V-D4 Insight 12.

    PYTHONPATH=src:. python benchmarks/serve_bench.py [--requests 12] [--tee tdx]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import build_bench_model
from repro.core import TrustDomain
from repro.core.overheads import PROFILES, measured_link_tax
from repro.runtime import (Engine, FramePolicy, GenerationRequest,
                           SamplingParams, parse_mesh, stats_from_requests)


def make_workload(n: int, vocab: int, seed: int = 7):
    """Mixed prompt lengths spanning the bucket range (8..100 tokens)."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(8, 100, size=n)
    return [rng.integers(1, vocab, size=int(l)).astype(np.int32)
            for l in lengths]


def reqs_for(prompts, max_new_tokens: int, coalesce: int = 1):
    return [GenerationRequest(prompt=p, max_new_tokens=max_new_tokens,
                              frame=FramePolicy(coalesce=coalesce))
            for p in prompts]


def run_config(label: str, model, params, prompts, *, max_new_tokens: int,
               tee: str, buckets, batch_prefill: bool, max_slots: int,
               coalesce: int = 1):
    td = TrustDomain(tee)
    eng = Engine(model, params, max_slots=max_slots, max_len=256,
                 trust_domain=td, prefill_buckets=buckets,
                 batch_prefill=batch_prefill)
    # warmup wave: pays every (rows, bucket) prefill compilation once, so the
    # measured wave reports steady-state serving numbers.
    for r in reqs_for(prompts, max_new_tokens, coalesce):
        eng.submit(r)
    eng.run(max_steps=100_000)
    td.channel.stats.reset()

    t0 = time.monotonic()
    reqs = [eng.submit(r) for r in reqs_for(prompts, max_new_tokens, coalesce)]
    eng.run(max_steps=100_000)
    wall = time.monotonic() - t0
    assert all(r.finished for r in reqs)
    stats = stats_from_requests(reqs)
    frames = td.channel.stats.messages_out if td.confidential else 0
    print(f"{label:8s} {stats.total_tokens:6d} tok  {wall:6.2f}s  "
          f"{stats.throughput_tps:8.1f} tok/s  "
          f"TTFT mean {stats.mean_ttft_s * 1e3:7.1f}ms p99 {stats.p99_ttft_s * 1e3:7.1f}ms  "
          f"step mean {stats.mean_latency_s * 1e3:6.1f}ms  "
          f"egress frames {frames}")
    return stats, reqs, td.channel.stats


def coalesce_sweep(model, params, prompts, *, max_new_tokens: int, tee: str,
                   max_slots: int, windows=(1, 4, 16)):
    """Serve the identical workload at each coalesce window; verify output
    invariance and monotonically decreasing crossings/token, and price each
    point with the cgpu fixed per-crossing cost (Insight 10)."""
    print(f"\nframe-coalescing sweep (coalesce ∈ {list(windows)}, tee={tee}):")
    fixed_s = PROFILES["cgpu"].fixed_boundary_s
    outputs, curve, expected = [], [], []
    for w in windows:
        _, reqs, ch = run_config(f"N={w}", model, params, prompts,
                                 max_new_tokens=max_new_tokens, tee=tee,
                                 buckets=(16, 32, 64, 128), batch_prefill=True,
                                 max_slots=max_slots, coalesce=w)
        outputs.append([r.output for r in reqs])
        want = sum(-(-len(r.output) // w) for r in reqs)   # sum of ceil(t/w)
        assert ch.messages_out == want, \
            f"coalesce={w}: {ch.messages_out} frames, expected {want}"
        expected.append(want)
        cpt = ch.crossings_per_token if ch.tokens_out else 0.0
        curve.append(cpt)
        print(f"         -> {ch.messages_out} frames / {ch.tokens_out} tokens"
              f" = {cpt:.3f} crossings/token | modeled cgpu fixed cost "
              f"{cpt * fixed_s * 1e6:.1f} us/token")
    assert all(o == outputs[0] for o in outputs[1:]), \
        "coalescing changed decoded output"
    # strictly fewer crossings whenever a wider window can actually pack
    # more tokens per frame; ties are only legal when even the expected
    # frame counts tie (every request shorter than both windows).
    for (a, b), (ea, eb) in zip(zip(curve, curve[1:]),
                                zip(expected, expected[1:])):
        assert b < a or (b == a and eb == ea), \
            f"crossings/token must fall monotonically with coalesce, got {curve}"
    print("coalescing sweep OK: identical tokens, "
          f"crossings/token {' >= '.join(f'{c:.3f}' for c in curve)}")


def kv_backend_sweep(model, params, vocab, *, tee: str, max_slots: int,
                     requests: int, page_size: int, backends=("slot", "paged")):
    """Slot-dense vs paged under a long-context mix with forced preemption.

    Identical seeded workload per backend: a low-priority wave fills every
    slot, then a high-priority wave arrives and preempts (sealed-KV
    eviction) before the victims restore and finish. Asserts byte-identical
    outputs across backends and strictly fewer sealed bytes per preemption
    for paged (it moves ceil(tokens/page_size) pages, not max_len)."""
    max_len = 256
    rng = np.random.default_rng(11)
    lens = rng.integers(24, 200, size=requests)
    prompts = [rng.integers(1, vocab, size=int(l)).astype(np.int32)
               for l in lens]
    print(f"\nKV-backend sweep ({' vs '.join(backends)}, tee={tee}, "
          f"page_size={page_size}): {requests} low-prio + "
          f"{max_slots} high-prio requests, prompt lens "
          f"{lens.min()}-{lens.max()}, max_len={max_len}")

    results = {}
    for backend in backends:
        td = TrustDomain(tee)
        eng = Engine(model, params, max_slots=max_slots, max_len=max_len,
                     trust_domain=td, prefill_buckets=(32, 64, 128),
                     kv_backend=backend, page_size=page_size)
        # warmup wave: pay every (rows, bucket) compile before timing
        for p in prompts[:max_slots]:
            eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
        eng.run(max_steps=100_000)
        td.channel.stats.reset()

        t0 = time.monotonic()
        low = [eng.submit(GenerationRequest(
                   prompt=p, max_new_tokens=24, priority=0,
                   params=SamplingParams(temperature=0.8, top_k=32, seed=i)))
               for i, p in enumerate(prompts)]
        for _ in range(4):          # let the low wave claim slots + decode
            eng.step()
        high = [eng.submit(GenerationRequest(
                    prompt=prompts[i % len(prompts)][:48],
                    max_new_tokens=12, priority=5,
                    params=SamplingParams(temperature=0.8, top_k=32,
                                          seed=1000 + i)))
                for i in range(max_slots)]
        eng.run(max_steps=200_000)
        wall = time.monotonic() - t0
        assert all(r.finished for r in low + high)
        stats = stats_from_requests(low + high)
        ch = td.channel.stats
        per_seal = ch.seal_bytes_per_event
        print(f"  {backend:5s} {stats.total_tokens:6d} tok  {wall:6.2f}s  "
              f"{stats.throughput_tps:8.1f} tok/s  "
              f"TTFT mean {stats.mean_ttft_s * 1e3:7.1f}ms  "
              f"preempt {stats.preemptions:2d}  "
              f"sealed {ch.seal_bytes:8d}B ({per_seal:9.0f} B/seal)  "
              f"crossings {ch.messages_in + ch.messages_out}")
        results[backend] = dict(
            outputs=[r.output for r in low + high],
            preemptions=stats.preemptions, per_seal=per_seal, stats=stats)

    if len(backends) == 2:
        a, b = (results[k] for k in backends)
        assert a["outputs"] == b["outputs"], \
            "KV backends must produce byte-identical outputs"
        assert a["preemptions"] > 0 and b["preemptions"] > 0, \
            "the sweep must actually exercise sealed preemption"
        assert results["paged"]["per_seal"] < results["slot"]["per_seal"], \
            (f"paged must seal strictly fewer bytes per preemption "
             f"(paged {results['paged']['per_seal']:.0f} vs "
             f"slot {results['slot']['per_seal']:.0f})")
        ratio = results["slot"]["per_seal"] / results["paged"]["per_seal"]
        print(f"KV sweep OK: identical tokens under preemption; paged seals "
              f"{ratio:.1f}x fewer bytes per eviction")


def prefix_sharing_sweep(model, params, vocab, *, tee: str, max_slots: int,
                         requests: int, page_size: int):
    """Shared-prefix workload (one long common head — a RAG context / system
    prompt — plus distinct same-length tails) served with prefix sharing
    off and on, both under on-demand allocation so sharing is the only
    delta. Asserts byte-identical outputs, strictly fewer physical pages
    written, and strictly lower sealed bytes with sharing — the shared head
    is stored once, and a victim's shared pages seal by reference (parked
    at most once at last-reference drop) instead of as per-victim
    ciphertext."""
    max_len, bucket, head_len = 256, 128, 96
    rng = np.random.default_rng(17)
    head = rng.integers(1, vocab, size=head_len).astype(np.int32)
    prompts = [np.concatenate([head, rng.integers(
                   1, vocab, size=bucket - head_len).astype(np.int32)])
               for _ in range(requests)]
    print(f"\nprefix-sharing sweep (tee={tee}, page_size={page_size}): "
          f"{requests} requests sharing a {head_len}-token head of "
          f"{bucket}-token prompts, + {max_slots} high-prio preemptors")

    results = {}
    for mode in ("off", "on"):
        td = TrustDomain(tee)
        eng = Engine(model, params, max_slots=max_slots, max_len=max_len,
                     trust_domain=td, prefill_buckets=(bucket,),
                     kv_backend="paged", page_size=page_size,
                     kv_alloc="ondemand", prefix_sharing=(mode == "on"))
        # warmup wave: pay the compile cost outside the measured window
        for p in prompts[:max_slots]:
            eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
        eng.run(max_steps=100_000)
        td.channel.stats.reset()
        pages0 = eng.kv.pages_written

        t0 = time.monotonic()
        low = [eng.submit(GenerationRequest(
                   prompt=p, max_new_tokens=16, priority=0,
                   params=SamplingParams(temperature=0.8, top_k=32, seed=i)))
               for i, p in enumerate(prompts)]
        for _ in range(4):
            eng.step()
        high = [eng.submit(GenerationRequest(
                    prompt=prompts[i % len(prompts)], max_new_tokens=8,
                    priority=5,
                    params=SamplingParams(temperature=0.8, top_k=32,
                                          seed=1000 + i)))
                for i in range(max_slots)]
        eng.run(max_steps=200_000)
        wall = time.monotonic() - t0
        assert all(r.finished for r in low + high)
        stats = stats_from_requests(low + high)
        ch = td.channel.stats
        pages = eng.kv.pages_written - pages0
        print(f"  sharing={mode:3s} {stats.total_tokens:6d} tok  {wall:6.2f}s "
              f" {stats.throughput_tps:8.1f} tok/s  preempt "
              f"{stats.preemptions:2d}  pages written {pages:4d}  shared "
              f"maps {eng.kv.shared_page_maps:3d}  CoW {eng.kv.cow_copies:2d}"
              f"  sealed {ch.seal_bytes:8d}B")
        results[mode] = dict(outputs=[r.output for r in low + high],
                             pages=pages, sealed=ch.seal_bytes,
                             shared=eng.kv.shared_page_maps,
                             preemptions=stats.preemptions)

    a, b = results["off"], results["on"]
    assert a["outputs"] == b["outputs"], \
        "prefix sharing must not change decoded output"
    assert a["preemptions"] > 0 and b["preemptions"] > 0, \
        "the sweep must actually exercise sealed preemption"
    assert b["shared"] > 0, "no page was ever shared — sweep is broken"
    assert b["pages"] < a["pages"], \
        (f"sharing must write strictly fewer physical pages "
         f"({b['pages']} vs {a['pages']})")
    assert b["sealed"] < a["sealed"], \
        (f"sharing must seal strictly fewer bytes "
         f"({b['sealed']} vs {a['sealed']})")
    print(f"prefix-sharing sweep OK: identical tokens; "
          f"{a['pages']}→{b['pages']} pages written, "
          f"{a['sealed']}→{b['sealed']} sealed bytes "
          f"({a['sealed'] / max(b['sealed'], 1):.2f}x)")


def two_phase_sweep(model, params, vocab, *, tee: str, json_out: str):
    """Long-prompt burst served by the baseline engine, step-level
    continuous batching, and the disaggregated two-plan engine.

    The workload is the TTFT-hostile shape: a burst of long prompts (each a
    full largest-bucket prefill, decoding for a long time) lands in the
    middle of a stream of short requests. The baseline admits in strict
    queue order, so the longs grab every freed slot and the trailing shorts
    wait out the longs' entire decode — the TTFT tail is a short request
    stuck behind the burst. Continuous batching charges live decode rows
    against the per-step token budget, so while short traffic keeps the
    engine busy the long head chunk does not fit and trailing shorts
    backfill past it; the longs run once the short stream drains. The
    asserted win is a strictly lower TTFT p99. The two-plan mode routes
    prefill through a dedicated ComputePlan and hands finished KV rows to
    the decode plan as a seal/restore pair — the sweep asserts that handoff
    traffic lands in ``ChannelStats`` (nonzero sealed bytes across the plan
    boundary). Outputs must be byte-identical across all three modes
    (scheduling moves tokens in time, never changes them). Per-mode serving
    metrics go to ``json_out``."""
    max_slots, max_len, bucket = 4, 192, 128
    # one long chunk + a couple of decode rows: with >= 3 live rows the
    # long head is budget-blocked and shorts backfill past it
    step_tokens = 130
    rng = np.random.default_rng(31)
    longs = [rng.integers(1, vocab, size=bucket).astype(np.int32)
             for _ in range(2)]
    shorts = [rng.integers(1, vocab, size=16).astype(np.int32)
              for _ in range(16)]
    print(f"\ntwo-phase sweep (tee={tee}): {len(longs)} long "
          f"({bucket}-token) prompts bursting into a stream of "
          f"{len(shorts)} short (16-token) ones, slots={max_slots}, "
          f"step budget {step_tokens}")

    def short_req(i):
        # staggered decode lengths so the live-row count never collapses to
        # zero in one step (which would let the long burst flood in early)
        return GenerationRequest(
            prompt=shorts[i], max_new_tokens=6 + (i % 8), priority=0,
            params=SamplingParams(temperature=0.8, top_k=32, seed=100 + i))

    def workload():
        reqs = [short_req(i) for i in range(4)]
        reqs += [GenerationRequest(
                    prompt=p, max_new_tokens=32, priority=0,
                    params=SamplingParams(temperature=0.8, top_k=32, seed=i))
                 for i, p in enumerate(longs)]
        reqs += [short_req(i) for i in range(4, len(shorts))]
        return reqs

    modes = {
        "baseline": {},
        "continuous": dict(continuous_batching=True,
                           step_tokens=step_tokens),
        "two-plan": dict(prefill_plan="dedicated"),
    }
    results, report = {}, {}
    for label, extra in modes.items():
        td = TrustDomain(tee)
        eng = Engine(model, params, max_slots=max_slots, max_len=max_len,
                     trust_domain=td, prefill_buckets=(16, bucket),
                     kv_backend="paged", page_size=16, **extra)
        # warmup wave: pays every (rows, bucket) prefill compile — and, in
        # two-plan mode, the dedicated prefill plan's compile — outside the
        # measured window.
        for r in workload():
            eng.submit(r)
        eng.run(max_steps=100_000)
        td.channel.stats.reset()
        pages0 = getattr(eng.kv, "pages_written", 0)

        t0 = time.monotonic()
        reqs = [eng.submit(r) for r in workload()]
        eng.run(max_steps=200_000)
        wall = time.monotonic() - t0
        assert all(r.finished for r in reqs)
        stats = stats_from_requests(reqs)
        ch = td.channel.stats
        print(f"  {label:10s} {stats.total_tokens:5d} tok  {wall:6.2f}s  "
              f"{stats.throughput_tps:8.1f} tok/s  "
              f"TTFT p50 {stats.p50_ttft_s * 1e3:7.1f}ms "
              f"p99 {stats.p99_ttft_s * 1e3:7.1f}ms  "
              f"handoffs {stats.handoffs:2d} ({stats.handoff_bytes}B)  "
              f"backfills {stats.backfilled_requests:2d}")
        results[label] = dict(outputs=[r.output for r in reqs], stats=stats,
                              ch=ch)
        report[label] = dict(
            tokens_per_s=round(stats.throughput_tps, 1),
            ttft_p50_ms=round(stats.p50_ttft_s * 1e3, 2),
            ttft_p99_ms=round(stats.p99_ttft_s * 1e3, 2),
            sealed_bytes_per_request=ch.seal_bytes // max(len(reqs), 1),
            pages_written=int(getattr(eng.kv, "pages_written", 0) - pages0),
            crossings_per_token=round(
                ch.crossings_per_token if ch.tokens_out else 0.0, 3),
            handoffs=stats.handoffs, handoff_bytes=stats.handoff_bytes,
            backfilled_requests=stats.backfilled_requests)

    base, cb, tp2 = (results[k] for k in modes)
    assert base["outputs"] == cb["outputs"] == tp2["outputs"], \
        "scheduling mode changed decoded output"
    assert cb["stats"].p99_ttft_s < base["stats"].p99_ttft_s, \
        (f"continuous batching must cut TTFT p99 at the burst operating "
         f"point ({cb['stats'].p99_ttft_s * 1e3:.1f}ms vs "
         f"{base['stats'].p99_ttft_s * 1e3:.1f}ms)")
    assert cb["stats"].backfilled_requests > 0, \
        "the burst must actually exercise backfill admission"
    assert tp2["stats"].handoffs > 0 and tp2["stats"].handoff_bytes > 0, \
        "two-plan mode moved no sealed KV across the plan boundary"
    assert tp2["ch"].seal_bytes >= tp2["stats"].handoff_bytes, \
        "handoff bytes must be priced in ChannelStats sealed traffic"
    Path(json_out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"two-phase sweep OK: identical tokens; TTFT p99 "
          f"{base['stats'].p99_ttft_s * 1e3:.1f}ms -> "
          f"{cb['stats'].p99_ttft_s * 1e3:.1f}ms under continuous batching "
          f"({cb['stats'].backfilled_requests} backfills); two-plan handoff "
          f"{tp2['stats'].handoff_bytes}B sealed across the boundary; "
          f"metrics -> {json_out}")


def handoff_batch_sweep(model, params, vocab, *, tee: str):
    """Grouped sealed handoffs on the dedicated prefill plan: the same
    seeded workload served with ``handoff_batch`` ∈ {1, 2, 4} must decode
    byte-identically while sealed plan-boundary crossings per token fall
    monotonically — N finished prefill rows ride one seal/restore pair
    instead of N, the same fixed-cost-per-crossing amortization lever as
    frame coalescing (Insight 10), applied to the KV handoff direction."""
    max_slots, bucket = 4, 16
    rng = np.random.default_rng(43)
    prompts = [rng.integers(1, vocab, size=bucket).astype(np.int32)
               for _ in range(8)]

    def workload():
        return [GenerationRequest(
                    prompt=p, max_new_tokens=8,
                    params=SamplingParams(temperature=0.8, top_k=32, seed=i))
                for i, p in enumerate(prompts)]

    print(f"\nhandoff-batch sweep (tee={tee}, prefill_plan=dedicated, "
          f"batch ∈ [1, 2, 4]): {len(prompts)} requests, slots={max_slots}")
    outputs, curve = [], []
    for batch in (1, 2, 4):
        td = TrustDomain(tee)
        eng = Engine(model, params, max_slots=max_slots, max_len=64,
                     trust_domain=td, prefill_buckets=(bucket,),
                     prefill_plan="dedicated", handoff_batch=batch)
        for r in workload():      # warmup: both plans' compiles
            eng.submit(r)
        eng.run(max_steps=100_000)
        td.channel.stats.reset()
        crossings0 = eng.handoff_crossings

        reqs = [eng.submit(r) for r in workload()]
        eng.run(max_steps=100_000)
        assert all(r.finished for r in reqs)
        stats = stats_from_requests(reqs)
        crossings = eng.handoff_crossings - crossings0
        cpt = crossings / max(stats.total_tokens, 1)
        outputs.append([r.output for r in reqs])
        curve.append(cpt)
        print(f"  batch={batch}  {stats.handoffs:2d} handoffs over "
              f"{crossings:2d} sealed crossings / {stats.total_tokens} tokens"
              f" = {cpt:.4f} crossings/token  ({stats.handoff_bytes}B)")
        assert stats.handoffs == len(reqs), \
            "every request must cross the plan boundary exactly once"
    assert all(o == outputs[0] for o in outputs[1:]), \
        "handoff batching changed decoded output"
    for a, b in zip(curve, curve[1:]):
        assert b <= a, \
            f"crossings/token must fall monotonically with batch, got {curve}"
    assert curve[-1] < curve[0], \
        f"batching must strictly cut sealed crossings, got {curve}"
    print(f"handoff-batch sweep OK: identical tokens, crossings/token "
          f"{' >= '.join(f'{c:.4f}' for c in curve)}")


def long_context_sweep(model, params, vocab, *, tee: str, json_out: str,
                       contexts=(512, 2048, 8192), steps: int = 8,
                       page_size: int = 32):
    """Gather vs kernel paged decode across context lengths.

    One long prompt per context point, decoded ``steps`` tokens under each
    decode mode on an otherwise idle engine — the per-step cost isolates
    the decode path itself: the gather mode rematerializes the full dense
    [L, slots, max_len, ...] view per step (O(capacity)), the kernel mode
    streams only the valid pages through the Pallas table-walk
    (O(context)), so the gap must grow with context. Decoded tokens must
    be identical — the kernel is numerically close, and at these operating
    points the sampled token stream may not diverge. Rows merge under the
    ``long-context`` key of ``json_out``."""
    print(f"\nlong-context sweep (tee={tee}): gather vs kernel paged "
          f"decode, contexts {list(contexts)}, {steps} decode steps")
    report = {}
    for ctx in contexts:
        prompt_len = ctx - 1            # bucket == ctx, one token of room
        rng = np.random.default_rng(ctx)
        prompt = rng.integers(1, vocab, size=prompt_len).astype(np.int32)
        rows, outputs = {}, {}
        for mode in ("gather", "kernel"):
            td = TrustDomain(tee)
            eng = Engine(model, params, max_slots=1,
                         max_len=ctx + 2 * page_size,
                         trust_domain=td, prefill_buckets=(ctx,),
                         kv_backend="paged", page_size=page_size,
                         kv_decode=mode)
            req = eng.submit(GenerationRequest(
                prompt=prompt, max_new_tokens=steps,
                params=SamplingParams(temperature=0.8, top_k=32, seed=17)))
            eng.step()                  # prefill + first sampled token
            eng.step()                  # decode warmup (compile)
            times = []
            while not req.finished:
                t0 = time.monotonic()
                eng.step()
                times.append(time.monotonic() - t0)
            assert req.finish_reason == "stop" or req.finished
            outputs[mode] = list(req.output)
            times.sort()
            p50 = times[len(times) // 2]
            p99 = times[min(len(times) - 1, int(len(times) * 0.99))]
            rows[mode] = dict(
                decode_step_p50_ms=round(p50 * 1e3, 3),
                decode_step_p99_ms=round(p99 * 1e3, 3),
                tokens_per_s=round(len(times) / max(sum(times), 1e-9), 1))
            print(f"  ctx={ctx:5d} {mode:7s} step p50 "
                  f"{rows[mode]['decode_step_p50_ms']:8.2f}ms  p99 "
                  f"{rows[mode]['decode_step_p99_ms']:8.2f}ms  "
                  f"{rows[mode]['tokens_per_s']:8.1f} tok/s")
        assert outputs["gather"] == outputs["kernel"], \
            f"kernel decode changed tokens at ctx={ctx}"
        rows["speedup_p50"] = round(
            rows["gather"]["decode_step_p50_ms"]
            / max(rows["kernel"]["decode_step_p50_ms"], 1e-9), 3)
        report[str(ctx)] = rows
        print(f"  ctx={ctx:5d} identical tokens; kernel speedup "
              f"{rows['speedup_p50']}x (p50)")
    path = Path(json_out)
    data = json.loads(path.read_text()) if path.exists() else {}
    data["long-context"] = report
    path.write_text(json.dumps(data, indent=2) + "\n")
    print(f"long-context sweep rows -> {json_out}")
    return report


def fleet_sweep(model, params, vocab, *, tee: str, requests: int,
                json_out: str):
    """Multi-worker fleet vs one worker vs a forced mid-serve worker kill,
    all over the same seeded two-tenant workload. Outputs must be
    byte-identical across all three (placement and even enclave loss move
    *where* a request decodes, never *what* it decodes — the request's
    sealed KV and seeded sampling state travel), and the kill run must
    price its migration (sealed moves, ciphertext bytes) in FleetStats.
    Rows merge under the ``fleet`` key of ``json_out``."""
    from repro.fleet import EngineWorker, Gateway, Orchestrator

    rng = np.random.default_rng(41)
    prompts = [rng.integers(1, vocab, size=int(l)).astype(np.int32)
               for l in rng.integers(8, 60, size=requests)]

    def workload():
        # fresh objects per run: routing consumes the plaintext prompt
        # (the envelope round-trip replaces it)
        return [GenerationRequest(
                    prompt=p.copy(), max_new_tokens=12,
                    params=SamplingParams(temperature=0.8, top_k=32, seed=i),
                    tenant=f"t{i % 2}")
                for i, p in enumerate(prompts)]

    def serve(n_workers, kill_at=None):
        kw = dict(max_slots=2, max_len=128, prefill_buckets=(16, 32, 64))
        workers = [EngineWorker(f"w{i}", model, params, tee=tee,
                                engine_kw=kw) for i in range(n_workers)]
        gateway = Gateway(config_repr="bench")
        gateway.register_tenant("t0")
        gateway.register_tenant("t1")
        orch = Orchestrator(gateway, workers)
        t0 = time.monotonic()
        handles = [orch.submit(g) for g in workload()]
        step_i = 0
        occ_samples = []       # per-step busy slots / live capacity
        while not orch.idle and step_i < 100_000:
            if step_i == kill_at and len(orch.ready_workers()) > 1:
                victim = max(orch.ready_workers(), key=lambda w: w.load())
                orch.kill(victim.name)
            orch.step()
            live = orch.ready_workers()
            busy = sum(int(np.sum(w.engine._active_mask)) for w in live)
            cap = sum(w.engine.max_slots for w in live)
            if cap:
                occ_samples.append(busy / cap)
            step_i += 1
        wall = time.monotonic() - t0
        assert all(h.finished for h in handles)
        occupancy = float(np.mean(occ_samples)) if occ_samples else 0.0
        return handles, stats_from_requests(handles), orch, wall, occupancy

    print(f"\nfleet sweep (tee={tee}): {requests} requests over 2 tenants, "
          f"2 slots/worker")
    report, outputs = {}, {}
    for label, n, kill in (("workers=1", 1, None), ("workers=2", 2, None),
                           ("workers=2+kill", 2, 4)):
        handles, stats, orch, wall, occupancy = serve(n, kill)
        outputs[label] = [h.output for h in handles]
        fs = orch.stats
        print(f"  {label:15s} {stats.total_tokens:5d} tok  {wall:6.2f}s  "
              f"{stats.throughput_tps:8.1f} tok/s  "
              f"occupancy {occupancy * 100:5.1f}%  "
              f"TTFT p50 {stats.p50_ttft_s * 1e3:7.1f}ms "
              f"p99 {stats.p99_ttft_s * 1e3:7.1f}ms  "
              f"migrations {fs.migrations} ({fs.migrated_bytes}B, "
              f"{fs.kills} kills)")
        report[label] = dict(
            workers=n, tokens_per_s=round(stats.throughput_tps, 1),
            slot_occupancy=round(occupancy, 3),
            ttft_p50_ms=round(stats.p50_ttft_s * 1e3, 2),
            ttft_p99_ms=round(stats.p99_ttft_s * 1e3, 2),
            migrations=fs.migrations, migrated_bytes=fs.migrated_bytes,
            kills=fs.kills)
    # bench note (the workers=2 tokens/s regression vs workers=1): two
    # in-process workers step serially on one host, so wall time per fleet
    # step roughly doubles while per-engine batch occupancy *drops* — the
    # same request count spreads over twice the slots, so each engine
    # decodes with fewer rows per step. The occupancy column quantifies it;
    # real deployments step workers in parallel, where the regression
    # inverts. See the per-worker numbers in the JSON rows.
    report["bench-note"] = (
        "workers=2 throughput trails workers=1 on this single-host bench: "
        "in-process workers step serially, and per-engine occupancy falls "
        f"from {report['workers=1']['slot_occupancy']:.0%} to "
        f"{report['workers=2']['slot_occupancy']:.0%} as the same workload "
        "spreads across twice the slots. Parallel-stepping deployments "
        "recover the difference.")
    print(f"  note: {report['bench-note']}")
    assert outputs["workers=1"] == outputs["workers=2"] \
        == outputs["workers=2+kill"], \
        "fleet placement / worker kill changed decoded output"
    kill_row = report["workers=2+kill"]
    assert kill_row["kills"] == 1, "the kill run must actually kill a worker"
    assert kill_row["migrations"] > 0 and kill_row["migrated_bytes"] > 0, \
        "a mid-serve kill must move sealed KV to the survivor"
    path = Path(json_out)
    data = json.loads(path.read_text()) if path.exists() else {}
    data["fleet"] = report
    path.write_text(json.dumps(data, indent=2) + "\n")
    print(f"fleet sweep OK: identical tokens across 1 worker, 2 workers and "
          f"a mid-serve kill; {kill_row['migrations']} sealed moves / "
          f"{kill_row['migrated_bytes']}B migrated; rows -> {json_out}")


def page_store_sweep(model, params, vocab, *, tee: str, json_out: str):
    """Cold-start RAG workload through the persistent sealed-page store: a
    recurring-prompt mix (one long shared head — the RAG context — plus
    distinct tails) served twice on one engine. The cold epoch prefills and
    publishes every full page at release; the warm epoch finds them
    content-addressed in the store and restores MAC-verified ciphertext
    instead of writing fresh pages. Asserts a nonzero warm hit rate,
    strictly fewer pages written warm, byte-identical decoded tokens, and
    prices the restore-vs-recompute breakeven through the overhead model.
    Rows merge under the ``page-store`` key of ``json_out``."""
    from repro.core.overheads import store_restore_savings
    from repro.runtime.pagestore import SealedPageStore

    max_slots, max_len, bucket, head_len, page_size = 2, 256, 128, 96, 16
    rng = np.random.default_rng(29)
    head = rng.integers(1, vocab, size=head_len).astype(np.int32)
    prompts = [np.concatenate([head, rng.integers(
                   1, vocab, size=bucket - head_len).astype(np.int32)])
               for _ in range(4)]
    store = SealedPageStore(budget_pages=64, policy="cost", profile=tee)
    td = TrustDomain(tee)
    eng = Engine(model, params, max_slots=max_slots, max_len=max_len,
                 trust_domain=td, prefill_buckets=(bucket,),
                 kv_backend="paged", page_size=page_size,
                 prefix_sharing=True, page_store=store)
    print(f"\npage-store sweep (tee={tee}, policy={store.policy}, "
          f"budget={store.budget_pages} pages): {len(prompts)} recurring "
          f"{bucket}-token prompts sharing a {head_len}-token head, "
          f"2 epochs")

    def wave(seed0):
        return [eng.submit(GenerationRequest(
                    prompt=p, max_new_tokens=16,
                    params=SamplingParams(temperature=0.8, top_k=32,
                                          seed=seed0 + i)))
                for i, p in enumerate(prompts)]

    # warmup on DISJOINT prompts, twice: the first pass pays the prefill /
    # decode compiles, the second pays the store-hit restore path's shapes —
    # without seeding the store with the measured wave's content.
    warm_prompts = [rng.integers(1, vocab, size=bucket).astype(np.int32)
                    for _ in range(2)]
    for _ in range(2):
        for i, p in enumerate(warm_prompts):
            eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
        eng.run(max_steps=100_000)
    td.channel.stats.reset()

    rows, outputs = [], []
    for epoch in ("cold", "warm"):
        pages0 = eng.kv.pages_written
        hits0 = eng.kv.store_hits
        t0 = time.monotonic()
        reqs = wave(500)
        eng.run(max_steps=200_000)
        wall = time.monotonic() - t0
        assert all(r.finished for r in reqs)
        stats = stats_from_requests(reqs)
        pages = eng.kv.pages_written - pages0
        hits = eng.kv.store_hits - hits0
        outputs.append([r.output for r in reqs])
        rows.append(dict(
            epoch=epoch, tokens=stats.total_tokens,
            wall_s=round(wall, 3),
            tokens_per_s=round(stats.throughput_tps, 1),
            pages_written=pages, store_hits=hits,
            hit_rate=round(hits / max(hits + pages, 1), 3)))
        print(f"  {epoch:4s} {stats.total_tokens:5d} tok  {wall:6.2f}s  "
              f"{stats.throughput_tps:8.1f} tok/s  pages written {pages:3d}"
              f"  store hits {hits:3d}  hit rate {rows[-1]['hit_rate']:.0%}")

    cold, warm = rows
    assert outputs[0] == outputs[1], \
        "the store epoch changed decoded output"
    assert warm["store_hits"] > 0, \
        "the warm epoch never hit the store — the tier is dead"
    assert warm["pages_written"] < cold["pages_written"], \
        (f"warm epoch must write strictly fewer pages "
         f"({warm['pages_written']} vs {cold['pages_written']})")
    assert warm["tokens_per_s"] >= 0.85 * cold["tokens_per_s"], \
        (f"warm epoch slowed serving down "
         f"({warm['tokens_per_s']} vs {cold['tokens_per_s']} tok/s)")
    _, _, line = store_restore_savings(
        eng.kv.store_restored_pages, eng.kv.store_restored_bytes,
        eng.kv.store_restored_pages * page_size, tee)
    print(f"  {line}")
    report = dict(
        epochs=rows, policy=store.policy, budget_pages=store.budget_pages,
        publishes=store.publishes, republish_noops=store.republish_noops,
        evictions=store.evictions, resident_pages=store.resident_pages,
        restored_bytes=eng.kv.store_restored_bytes, breakeven=line)
    path = Path(json_out)
    data = json.loads(path.read_text()) if path.exists() else {}
    data["page-store"] = report
    path.write_text(json.dumps(data, indent=2) + "\n")
    print(f"page-store sweep OK: identical tokens; "
          f"{cold['pages_written']}→{warm['pages_written']} pages written, "
          f"warm hit rate {warm['hit_rate']:.0%}; rows -> {json_out}")


def mesh_sweep(model, params, vocab, *, mesh: str, tee: str, max_slots: int,
               requests: int):
    """Single-device vs mesh-spanning engine over one seeded workload:
    byte-identical outputs (dp meshes), then the measured-vs-modeled
    link_tax comparison from the mesh engine's collective counters."""
    dp, tp = parse_mesh(mesh)
    slots = max(max_slots, dp)           # divisible batch => sharded cache
    slots += (-slots) % dp
    rng = np.random.default_rng(23)
    prompts = [rng.integers(1, vocab, size=int(l)).astype(np.int32)
               for l in rng.integers(8, 60, size=requests)]
    print(f"\nmesh sweep (single vs {mesh}, tee={tee}, slots={slots}):")

    results = {}
    for label, spec in (("single", None), (mesh, mesh)):
        td = TrustDomain(tee if tee != "none" else "cgpu")
        eng = Engine(model, params, max_slots=slots, max_len=128,
                     trust_domain=td, prefill_buckets=(16, 32, 64),
                     mesh=spec)
        t0 = time.monotonic()
        reqs = [eng.submit(GenerationRequest(
                    prompt=p, max_new_tokens=12,
                    params=SamplingParams(temperature=0.8, top_k=16, seed=i)))
                for i, p in enumerate(prompts)]
        eng.run(max_steps=100_000)
        wall = time.monotonic() - t0
        assert all(r.finished for r in reqs)
        stats = stats_from_requests(reqs)
        print(f"  {label:8s} {stats.total_tokens:6d} tok  {wall:6.2f}s  "
              f"{stats.throughput_tps:8.1f} tok/s")
        results[label] = dict(outputs=[r.output for r in reqs], td=td,
                              plan=eng.plan, stats=stats)

    if tp == 1:
        assert results["single"]["outputs"] == results[mesh]["outputs"], \
            "dp mesh must produce byte-identical outputs"
        print("  outputs byte-identical across the mesh")
    else:
        print("  (tp > 1: outputs numerically equivalent, not bitwise — "
              "TP all-reduce ordering)")

    ch = results[mesh]["td"].channel.stats
    profile = tee if tee != "none" else "cgpu"
    _, _, line = measured_link_tax(
        ch, profile, results[mesh]["stats"].mean_latency_s or 1e-3)
    print(f"  link-tax ({profile}, {PROFILES[profile].link_tax}x): {line}")
    assert ch.collective_steps > 0, "mesh engine recorded no decode steps"
    if dp * tp > 1:
        assert ch.collective_bytes > 0, \
            "a multi-device mesh must move collective bytes"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--tee", default="tdx",
                    choices=["none", "vm", "sgx", "tdx", "cgpu", "tpu_cc"])
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--skip-sweep", action="store_true",
                    help="only run the v1/v2 comparison")
    ap.add_argument("--kv-backend", default="both",
                    choices=["both", "slot", "paged", "none"],
                    help="KV-backend sweep selection ('both' compares and "
                         "asserts; 'none' skips)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged-backend page size for the KV sweep")
    ap.add_argument("--prefix-sharing", default="both",
                    choices=["both", "none"],
                    help="shared-prefix workload sweep: sharing off vs on "
                         "under on-demand allocation ('none' skips)")
    ap.add_argument("--two-phase", default="both",
                    choices=["both", "none"],
                    help="long-prompt-burst sweep: baseline vs step-level "
                         "continuous batching vs disaggregated two-plan "
                         "serving, with BENCH_serve.json emission "
                         "('none' skips)")
    ap.add_argument("--handoff-sweep", default="both",
                    choices=["both", "none"],
                    help="grouped sealed prefill->decode handoffs: "
                         "handoff_batch 1 vs 2 vs 4 on the dedicated plan "
                         "('none' skips)")
    ap.add_argument("--long-context", default="both",
                    choices=["both", "none"],
                    help="gather vs kernel paged-decode sweep over context "
                         "lengths 512/2k/8k, rows merged into the JSON "
                         "report ('none' skips)")
    ap.add_argument("--fleet", default="both", choices=["both", "none"],
                    help="fleet sweep: 1 worker vs 2 vs 2+mid-serve kill, "
                         "rows merged into the JSON report ('none' skips)")
    ap.add_argument("--page-store", default="both", choices=["both", "none"],
                    help="persistent sealed-page store sweep: cold vs warm "
                         "epoch of a recurring-prompt mix, rows merged "
                         "into the JSON report ('none' skips)")
    ap.add_argument("--json-out", default="BENCH_serve.json",
                    help="where the two-phase sweep writes its per-mode "
                         "serving metrics")
    ap.add_argument("--mesh", default=None, metavar="dp=N[,tp=M]",
                    help="also run the mesh sweep: single-device vs "
                         "mesh-spanning engine with measured-vs-modeled "
                         "link-tax comparison")
    args = ap.parse_args()

    if args.mesh is not None:
        from repro.launch.mesh import ensure_host_devices
        dp, tp = parse_mesh(args.mesh)
        ensure_host_devices(dp * tp)

    cfg, model, params = build_bench_model(d_model=args.d_model,
                                           num_layers=args.layers)
    prompts = make_workload(args.requests, cfg.vocab_size)
    print(f"workload: {args.requests} requests, prompt lens "
          f"{min(map(len, prompts))}-{max(map(len, prompts))}, "
          f"{args.max_new_tokens} new tokens each, tee={args.tee}\n")

    common = dict(max_new_tokens=args.max_new_tokens, tee=args.tee,
                  max_slots=args.max_slots)
    run_config("v1-style", model, params, prompts,
               buckets=(64,), batch_prefill=False, **common)
    run_config("v2", model, params, prompts,
               buckets=(16, 32, 64, 128), batch_prefill=True, **common)
    if not args.skip_sweep:
        sweep_tee = args.tee if args.tee != "none" else "cgpu"
        coalesce_sweep(model, params, prompts, tee=sweep_tee, **{
            k: v for k, v in common.items() if k != "tee"})
    if args.kv_backend != "none":
        backends = (("slot", "paged") if args.kv_backend == "both"
                    else (args.kv_backend,))
        kv_backend_sweep(model, params, cfg.vocab_size,
                         tee=args.tee if args.tee != "none" else "cgpu",
                         max_slots=args.max_slots, requests=args.requests,
                         page_size=args.page_size, backends=backends)
    if args.prefix_sharing != "none":
        prefix_sharing_sweep(model, params, cfg.vocab_size,
                             tee=args.tee if args.tee != "none" else "cgpu",
                             max_slots=args.max_slots,
                             requests=args.requests,
                             page_size=args.page_size)
    if args.two_phase != "none":
        two_phase_sweep(model, params, cfg.vocab_size,
                        tee=args.tee if args.tee != "none" else "cgpu",
                        json_out=args.json_out)
    if args.handoff_sweep != "none":
        handoff_batch_sweep(model, params, cfg.vocab_size,
                            tee=args.tee if args.tee != "none" else "cgpu")
    if args.long_context != "none":
        long_context_sweep(model, params, cfg.vocab_size,
                           tee=args.tee if args.tee != "none" else "cgpu",
                           json_out=args.json_out)
    if args.fleet != "none":
        fleet_sweep(model, params, cfg.vocab_size,
                    tee=args.tee if args.tee != "none" else "cgpu",
                    requests=min(args.requests, 8), json_out=args.json_out)
    if args.page_store != "none":
        page_store_sweep(model, params, cfg.vocab_size,
                         tee=args.tee if args.tee != "none" else "cgpu",
                         json_out=args.json_out)
    if args.mesh is not None:
        mesh_sweep(model, params, cfg.vocab_size, mesh=args.mesh,
                   tee=args.tee, max_slots=args.max_slots,
                   requests=args.requests)


if __name__ == "__main__":
    main()
