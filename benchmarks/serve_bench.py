"""Serving benchmark: v1-style static prefill vs v2 bucketed batched prefill,
plus the v3 frame-coalescing sweep (Insight-10 fixed-cost amortization).

Measures the paper's two user-perceived serving metrics (§III-C) —
throughput (tokens/s) and next-token latency — plus time-to-first-token and
the boundary-crossing counts that drive the cgpu fixed-cost model
(Insight 10), for two engine configurations over the same mixed-length
workload:

  v1-style : one static prefill bucket, one request per prefill call
             (the seed engine's shape; long prompts now chunk instead of
             silently truncating, so outputs are comparable)
  v2       : power-of-two prefill buckets, same-bucket requests batched
             into one jitted prefill call

The coalescing sweep then serves the same workload with FramePolicy
coalesce ∈ {1, 4, 16}: decoded output must be unchanged while boundary
crossings per token fall as 1/N — the amortization curve behind the paper's
observation that cGPU overhead is fixed-cost-per-crossing dominated. The
modeled column prices each point with the cgpu profile's
``fixed_boundary_s``.

    PYTHONPATH=src:. python benchmarks/serve_bench.py [--requests 12] [--tee tdx]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import build_bench_model
from repro.core import TrustDomain
from repro.core.overheads import PROFILES
from repro.runtime import (Engine, FramePolicy, GenerationRequest,
                           stats_from_requests)


def make_workload(n: int, vocab: int, seed: int = 7):
    """Mixed prompt lengths spanning the bucket range (8..100 tokens)."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(8, 100, size=n)
    return [rng.integers(1, vocab, size=int(l)).astype(np.int32)
            for l in lengths]


def reqs_for(prompts, max_new_tokens: int, coalesce: int = 1):
    return [GenerationRequest(prompt=p, max_new_tokens=max_new_tokens,
                              frame=FramePolicy(coalesce=coalesce))
            for p in prompts]


def run_config(label: str, model, params, prompts, *, max_new_tokens: int,
               tee: str, buckets, batch_prefill: bool, max_slots: int,
               coalesce: int = 1):
    td = TrustDomain(tee)
    eng = Engine(model, params, max_slots=max_slots, max_len=256,
                 trust_domain=td, prefill_buckets=buckets,
                 batch_prefill=batch_prefill)
    # warmup wave: pays every (rows, bucket) prefill compilation once, so the
    # measured wave reports steady-state serving numbers.
    for r in reqs_for(prompts, max_new_tokens, coalesce):
        eng.submit(r)
    eng.run(max_steps=100_000)
    td.channel.stats.reset()

    t0 = time.monotonic()
    reqs = [eng.submit(r) for r in reqs_for(prompts, max_new_tokens, coalesce)]
    eng.run(max_steps=100_000)
    wall = time.monotonic() - t0
    assert all(r.finished for r in reqs)
    stats = stats_from_requests(reqs)
    frames = td.channel.stats.messages_out if td.confidential else 0
    print(f"{label:8s} {stats.total_tokens:6d} tok  {wall:6.2f}s  "
          f"{stats.throughput_tps:8.1f} tok/s  "
          f"TTFT mean {stats.mean_ttft_s * 1e3:7.1f}ms p99 {stats.p99_ttft_s * 1e3:7.1f}ms  "
          f"step mean {stats.mean_latency_s * 1e3:6.1f}ms  "
          f"egress frames {frames}")
    return stats, reqs, td.channel.stats


def coalesce_sweep(model, params, prompts, *, max_new_tokens: int, tee: str,
                   max_slots: int, windows=(1, 4, 16)):
    """Serve the identical workload at each coalesce window; verify output
    invariance and monotonically decreasing crossings/token, and price each
    point with the cgpu fixed per-crossing cost (Insight 10)."""
    print(f"\nframe-coalescing sweep (coalesce ∈ {list(windows)}, tee={tee}):")
    fixed_s = PROFILES["cgpu"].fixed_boundary_s
    outputs, curve, expected = [], [], []
    for w in windows:
        _, reqs, ch = run_config(f"N={w}", model, params, prompts,
                                 max_new_tokens=max_new_tokens, tee=tee,
                                 buckets=(16, 32, 64, 128), batch_prefill=True,
                                 max_slots=max_slots, coalesce=w)
        outputs.append([r.output for r in reqs])
        want = sum(-(-len(r.output) // w) for r in reqs)   # sum of ceil(t/w)
        assert ch.messages_out == want, \
            f"coalesce={w}: {ch.messages_out} frames, expected {want}"
        expected.append(want)
        cpt = ch.crossings_per_token if ch.tokens_out else 0.0
        curve.append(cpt)
        print(f"         -> {ch.messages_out} frames / {ch.tokens_out} tokens"
              f" = {cpt:.3f} crossings/token | modeled cgpu fixed cost "
              f"{cpt * fixed_s * 1e6:.1f} us/token")
    assert all(o == outputs[0] for o in outputs[1:]), \
        "coalescing changed decoded output"
    # strictly fewer crossings whenever a wider window can actually pack
    # more tokens per frame; ties are only legal when even the expected
    # frame counts tie (every request shorter than both windows).
    for (a, b), (ea, eb) in zip(zip(curve, curve[1:]),
                                zip(expected, expected[1:])):
        assert b < a or (b == a and eb == ea), \
            f"crossings/token must fall monotonically with coalesce, got {curve}"
    print("coalescing sweep OK: identical tokens, "
          f"crossings/token {' >= '.join(f'{c:.3f}' for c in curve)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--tee", default="tdx",
                    choices=["none", "vm", "sgx", "tdx", "cgpu", "tpu_cc"])
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--skip-sweep", action="store_true",
                    help="only run the v1/v2 comparison")
    args = ap.parse_args()

    cfg, model, params = build_bench_model(d_model=args.d_model,
                                           num_layers=args.layers)
    prompts = make_workload(args.requests, cfg.vocab_size)
    print(f"workload: {args.requests} requests, prompt lens "
          f"{min(map(len, prompts))}-{max(map(len, prompts))}, "
          f"{args.max_new_tokens} new tokens each, tee={args.tee}\n")

    common = dict(max_new_tokens=args.max_new_tokens, tee=args.tee,
                  max_slots=args.max_slots)
    run_config("v1-style", model, params, prompts,
               buckets=(64,), batch_prefill=False, **common)
    run_config("v2", model, params, prompts,
               buckets=(16, 32, 64, 128), batch_prefill=True, **common)
    if not args.skip_sweep:
        sweep_tee = args.tee if args.tee != "none" else "cgpu"
        coalesce_sweep(model, params, prompts, tee=sweep_tee, **{
            k: v for k, v in common.items() if k != "tee"})


if __name__ == "__main__":
    main()
