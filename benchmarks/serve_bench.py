"""Serving benchmark: v1-style static prefill vs v2 bucketed batched prefill.

Measures the paper's two user-perceived serving metrics (§III-C) —
throughput (tokens/s) and next-token latency — plus time-to-first-token and
the boundary-crossing counts that drive the cgpu fixed-cost model
(Insight 10), for two engine configurations over the same mixed-length
workload:

  v1-style : one static prefill bucket, one request per prefill call
             (the seed engine's shape; long prompts now chunk instead of
             silently truncating, so outputs are comparable)
  v2       : power-of-two prefill buckets, same-bucket requests batched
             into one jitted prefill call

    PYTHONPATH=src:. python benchmarks/serve_bench.py [--requests 12] [--tee tdx]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import build_bench_model
from repro.core import TrustDomain
from repro.runtime.engine import Engine
from repro.runtime.scheduler import stats_from_requests


def make_workload(n: int, vocab: int, seed: int = 7):
    """Mixed prompt lengths spanning the bucket range (8..100 tokens)."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(8, 100, size=n)
    return [rng.integers(1, vocab, size=int(l)).astype(np.int32)
            for l in lengths]


def run_config(label: str, model, params, prompts, *, max_new_tokens: int,
               tee: str, buckets, batch_prefill: bool, max_slots: int):
    td = TrustDomain(tee)
    eng = Engine(model, params, max_slots=max_slots, max_len=256,
                 trust_domain=td, prefill_buckets=buckets,
                 batch_prefill=batch_prefill)
    # warmup wave: pays every (rows, bucket) prefill compilation once, so the
    # measured wave reports steady-state serving numbers.
    for p in prompts:
        eng.submit(p, max_new_tokens)
    eng.run(max_steps=100_000)
    td.channel.stats.reset()

    t0 = time.monotonic()
    reqs = [eng.submit(p, max_new_tokens) for p in prompts]
    eng.run(max_steps=100_000)
    wall = time.monotonic() - t0
    assert all(r.finished for r in reqs)
    stats = stats_from_requests(reqs)
    frames = td.channel.stats.messages_out if td.confidential else 0
    print(f"{label:8s} {stats.total_tokens:6d} tok  {wall:6.2f}s  "
          f"{stats.throughput_tps:8.1f} tok/s  "
          f"TTFT mean {stats.mean_ttft_s * 1e3:7.1f}ms p99 {stats.p99_ttft_s * 1e3:7.1f}ms  "
          f"step mean {stats.mean_latency_s * 1e3:6.1f}ms  "
          f"egress frames {frames}")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--tee", default="tdx",
                    choices=["none", "vm", "sgx", "tdx", "cgpu", "tpu_cc"])
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg, model, params = build_bench_model(d_model=args.d_model,
                                           num_layers=args.layers)
    prompts = make_workload(args.requests, cfg.vocab_size)
    print(f"workload: {args.requests} requests, prompt lens "
          f"{min(map(len, prompts))}-{max(map(len, prompts))}, "
          f"{args.max_new_tokens} new tokens each, tee={args.tee}\n")

    common = dict(max_new_tokens=args.max_new_tokens, tee=args.tee,
                  max_slots=args.max_slots)
    run_config("v1-style", model, params, prompts,
               buckets=(64,), batch_prefill=False, **common)
    run_config("v2", model, params, prompts,
               buckets=(16, 32, 64, 128), batch_prefill=True, **common)


if __name__ == "__main__":
    main()
