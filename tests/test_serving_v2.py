"""Engine v2 serving path: streaming frames, bucketed/chunked prefill,
priority preemption, and the termination edges the v1 engine got wrong.
(Migrated to the v3 request-object API; the deprecated kwargs shim has its
own coverage in test_request_api.py.)"""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.core.bounce import BounceBuffer
from repro.core.sealing import IntegrityError, SealingKey, _nonce_for
from repro.models import build_model
from repro.runtime import Engine, GenerationRequest


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


PROMPT = np.arange(1, 9, dtype=np.int32)


def G(prompt, max_new_tokens=32, eos_id=None, priority=0, **kw):
    return GenerationRequest(prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=max_new_tokens, eos_id=eos_id,
                             priority=priority, **kw)


def make_engine(model, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_len", 8)
    return Engine(model, params, **kw)


class TestTermination:
    def test_max_new_tokens_one_yields_one_token(self, small_model):
        """v1 recorded the prefill token AND one decode token for
        max_new_tokens=1. v2 must stop at exactly one, releasing the slot
        at admission without a wasted decode step."""
        cfg, model, params = small_model
        eng = make_engine(model, params)
        req = eng.submit(G(PROMPT, 1))
        produced = eng.step()
        # the request finished inside admission: no decode tokens produced
        assert produced == 0
        assert req.finished
        assert len(req.output) == 1
        assert eng.slots.num_active == 0
        assert eng.idle

    def test_eos_as_first_token_stops_immediately(self, small_model):
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(G(PROMPT, 1)).tokens
        eng = make_engine(model, params)
        out = eng.generate(G(PROMPT, 5, eos_id=ref[0]))
        assert out.tokens == ref
        assert len(out.tokens) == 1
        assert eng.slots.num_active == 0

    def test_eos_mid_stream_stops(self, small_model):
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(G(PROMPT, 6)).tokens
        eng = make_engine(model, params)
        out = eng.generate(G(PROMPT, 6, eos_id=ref[3]))
        assert out.tokens == ref[:4]


class TestStreaming:
    def test_one_encrypted_frame_per_token(self, small_model):
        cfg, model, params = small_model
        plain = make_engine(model, params).generate(G(PROMPT, 7)).tokens
        eng = make_engine(model, params, trust_domain=TrustDomain("tdx"))
        toks = list(eng.stream(G(PROMPT, 7)))
        assert toks == plain
        assert eng.td.channel.stats.messages_out == len(toks) == 7
        frames = [e for e in eng.td.audit if e.kind == "egress_frame"]
        assert len(frames) == 7

    def test_stream_frames_are_session_sequenced(self, small_model):
        """Two streamed requests on one domain: per-request stream ids,
        monotonically sequenced frames on each."""
        cfg, model, params = small_model
        eng = make_engine(model, params, trust_domain=TrustDomain("tdx"))
        r0 = eng.submit(G(PROMPT, 4))
        r1 = eng.submit(G(PROMPT[::-1].copy(), 4))
        eng.run()
        details = [e.detail for e in eng.td.audit if e.kind == "egress_frame"]
        assert r0.stream_id != r1.stream_id
        for sid in (r0.stream_id, r1.stream_id):
            seqs = [int(d.split("seq=")[1].split()[0]) for d in details
                    if f"stream={sid} " in d]
            assert seqs == list(range(4))

    def test_engines_sharing_a_domain_never_collide_streams(self, small_model):
        """Stream ids are channel-allocated: two engines on one TrustDomain
        (each with rids starting at 0) must produce distinct frame names —
        a reused (stream, seq) name would reuse a ChaCha20 nonce."""
        cfg, model, params = small_model
        td = TrustDomain("tdx")
        eng_a = make_engine(model, params, trust_domain=td)
        eng_b = make_engine(model, params, trust_domain=td)
        ra = eng_a.submit(G(PROMPT, 3))
        eng_a.run()
        rb = eng_b.submit(G(PROMPT, 3))
        eng_b.run()
        assert ra.rid == rb.rid == 0        # per-engine rids do collide
        assert ra.stream_id != rb.stream_id  # channel stream ids must not
        details = [e.detail for e in td.audit if e.kind == "egress_frame"]
        names = [(d.split("stream=")[1].split()[0], d.split("seq=")[1].split()[0])
                 for d in details]
        assert len(set(names)) == len(names) == 6
        assert ra.output == rb.output

    def test_engines_sharing_a_domain_never_collide_seals(self, small_model):
        """Sealed-KV names use the channel-global stream id, so two engines'
        rid-0 requests seal under disjoint nonce namespaces."""
        cfg, model, params = small_model
        td = TrustDomain("tdx")
        sealed_names = set()
        for eng in (make_engine(model, params, trust_domain=td),
                    make_engine(model, params, trust_domain=td)):
            req = eng.submit(G(PROMPT, 6))
            eng.step()
            sealed, _ = eng.seal_slot(0)
            assert req.rid == 0
            new = set(sealed)
            assert not (sealed_names & new)
            sealed_names |= new

    def test_stream_submits_eagerly(self, small_model):
        """stream() must enqueue the request at call time, not at first
        next(): a caller that run()s before iterating still gets it served."""
        cfg, model, params = small_model
        eng = make_engine(model, params)
        it = eng.stream(G(PROMPT, 3))
        stats = eng.run()
        assert stats.total_requests == 1    # served by run(), not the iterator
        assert list(it) == eng.scheduler.finished[0].output

    def test_frame_nonce_uniqueness_and_replay_detection(self):
        key = SealingKey.generate(b"frames")
        bb = BounceBuffer(key)
        frames = [bb.device_send_frame(3, np.asarray([i], np.int32))
                  for i in range(40)]
        frames += [bb.device_send_frame(4, np.asarray([i], np.int32))
                   for i in range(40)]
        nonces = {_nonce_for(key, f.sealed.name) for f in frames}
        assert len(nonces) == len(frames) == 80
        assert bb.stats.messages_out == 80
        for i, f in enumerate(frames):
            assert int(bb.host_recv_frame(f)[0]) == i % 40
        # a frame presented under another frame's (stream, seq) is rejected
        forged = frames[1]
        forged.seq = 2
        with pytest.raises(IntegrityError):
            bb.host_recv_frame(forged)
        # a tampered frame must not burn the expected seq: send + forge a
        # copy, reject it, then the authentic frame still decrypts
        import dataclasses as _dc
        nxt = bb.device_send_frame(5, np.asarray([9], np.int32))
        bad = _dc.replace(nxt, sealed=_dc.replace(nxt.sealed, mac=b"\0" * 32))
        with pytest.raises(IntegrityError):
            bb.host_recv_frame(bad)
        assert int(bb.host_recv_frame(nxt)[0]) == 9
        # a verbatim replay (valid MAC, stale seq) is rejected too
        with pytest.raises(IntegrityError):
            bb.host_recv_frame(frames[5])
        # a closed stream stays unreplayable and unsendable forever
        bb.close_stream(3)
        with pytest.raises(IntegrityError):
            bb.host_recv_frame(frames[0])
        with pytest.raises(IntegrityError):
            bb.device_send_frame(3, np.asarray([1], np.int32))


class TestBucketedPrefill:
    def test_long_prompt_is_not_truncated(self, small_model):
        """v1 silently kept only the last prefill_len tokens. v2 chunks the
        tail through decode-aligned steps: the same 20-token prompt must give
        the same output no matter how the prefill/decode boundary falls."""
        cfg, model, params = small_model
        prompt = np.arange(1, 21, dtype=np.int32)   # len 20 > any bucket
        outs = []
        for buckets in [(4,), (16,)]:
            eng = make_engine(model, params, prefill_buckets=buckets)
            req = eng.submit(G(prompt, 5))
            eng.run()
            assert req.pending_input == []      # whole tail was consumed
            assert len(req.output) == 5
            outs.append(req.output)
        assert outs[0] == outs[1]

    def test_truncation_sensitivity(self, small_model):
        """Flipping the FIRST prompt token changes the output — impossible
        under v1's keep-the-last-prefill_len truncation."""
        cfg, model, params = small_model
        base = np.arange(1, 21, dtype=np.int32)
        edited = base.copy()
        edited[0] = 37
        eng = make_engine(model, params, prefill_buckets=(8,), max_slots=2)
        r0 = eng.submit(G(base, 6))
        r1 = eng.submit(G(edited, 6))
        eng.run()
        assert r0.output != r1.output

    # bucket-grouped admission vs sequential parity moved into the
    # differential harness (test_differential.py): the canonical scenario
    # mixes buckets 4/8 plus a chunked tail and diffs every backend
    # configuration against solo single-request references.


class TestPriorityPreemption:
    # preempt-and-resume byte-identity is asserted by the differential
    # harness against solo references (with preemptions forced on every
    # backend configuration); the tests below keep the edge cases.

    def test_preemption_mid_prompt_chunking(self, small_model):
        """Evict a request whose prompt tail is still being fed; the pending
        tail must travel with the sealed request and resume exactly."""
        cfg, model, params = small_model
        prompt = np.arange(1, 21, dtype=np.int32)
        ref_eng = make_engine(model, params, max_slots=1, prefill_buckets=(8,))
        ref = ref_eng.generate(G(prompt, 5)).tokens
        eng = make_engine(model, params, max_slots=1, prefill_buckets=(8,))
        low = eng.submit(G(prompt, 5, priority=0))
        eng.step()                      # prefill 8, feed 1 tail token
        assert low.pending_input        # still consuming the prompt
        high = eng.submit(G(PROMPT, 2, priority=9))
        eng.run()
        assert low.output == ref
        assert high.finished

    def test_double_preemption_uses_fresh_seal_nonces(self, small_model):
        """A request sealed twice holds different KV each time; the sealed
        tensor names (which derive the ChaCha20 nonces) must differ."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(G(PROMPT, 8)).tokens
        eng = make_engine(model, params, max_slots=1)
        low = eng.submit(G(PROMPT, 8, priority=0))
        eng.step()
        eng.submit(G(np.full(8, 2, np.int32), 1, priority=5))
        eng.step()                      # preempt #1 (+ restore on finish)
        eng.submit(G(np.full(8, 4, np.int32), 1, priority=5))
        eng.run()
        assert low.n_preemptions == 2
        assert low.seal_epoch == 2      # two distinct nonce namespaces
        assert low.output == ref

    def test_overflowing_request_is_rejected(self, small_model):
        """KV positions past max_len would silently clamp onto the last
        cache row; submit must refuse instead."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_len=32, prefill_buckets=(8,),
                          trust_domain=TrustDomain("tdx"))
        with pytest.raises(ValueError, match="KV positions"):
            eng.submit(G(np.arange(1, 41, dtype=np.int32), 4))
        with pytest.raises(ValueError, match="KV positions"):
            eng.submit(G(PROMPT, 30))
        # rejected requests never crossed the boundary: stats stay exact
        assert eng.td.channel.stats.messages_in == 0
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(G(PROMPT, 0))
        assert eng.generate(G(PROMPT, 4)).tokens  # in-budget requests still serve

    def test_prompt_budget_is_submit_boundary(self, small_model):
        """prompt_budget accounts for bucket padding: a budget-length prompt
        is accepted, one token more is refused."""
        cfg, model, params = small_model
        for buckets, mnt in [((8, 16), 4), ((8, 16), 20), ((16,), 12)]:
            eng = make_engine(model, params, max_len=32,
                              prefill_buckets=buckets)
            budget = eng.prompt_budget(mnt)
            assert budget > 0
            eng.submit(G(np.ones(budget, np.int32), mnt))     # accepted
            with pytest.raises(ValueError, match="KV positions"):
                eng.submit(G(np.ones(budget + 1, np.int32), mnt))
        # no bucket fits: budget is 0 (engine cannot serve that request)
        eng = make_engine(model, params, max_len=32, prefill_buckets=(16,))
        assert eng.prompt_budget(30) == 0

    def test_finished_streams_release_channel_state(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params, trust_domain=TrustDomain("tdx"))
        for i in range(3):
            eng.submit(G(np.full(8, i + 1, np.int32), 3))
        eng.run()
        # per-stream seq state is dropped as each request finishes
        assert eng.td.channel._stream_seq == {}
        assert eng.td.channel._stream_recv == {}

    def test_equal_priority_never_preempts(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1)
        a = eng.submit(G(PROMPT, 4, priority=1))
        eng.step()
        b = eng.submit(G(np.full(8, 3, np.int32), 4, priority=1))
        eng.run()
        assert a.n_preemptions == 0
        assert a.t_done <= b.t_done     # FIFO within a priority level

    def test_stats_include_ttft(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params)
        for i in range(3):
            eng.submit(G(np.full(8, i + 1, np.int32), 3))
        stats = eng.run()
        assert len(stats.ttft_s) == 3
        assert stats.mean_ttft_s > 0
        assert stats.p99_ttft_s >= stats.mean_ttft_s
