"""Per-architecture smoke + correctness tests.

Every assigned arch instantiates a reduced same-family config, runs one
forward/train step (shapes + no NaNs), and passes the prefill->decode parity
check: decoding token s after prefilling [0, s) must reproduce the
teacher-forced forward logits at position s (the state/cache handoff is
where most serving bugs live)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, smoke_config
from repro.configs.base import shape_applicable
from repro.models import build_model

# jit-compiles every assigned architecture: the bulk of suite wall-time
pytestmark = pytest.mark.slow

ASSIGNED = [
    "whisper-small", "deepseek-7b", "qwen3-32b", "deepseek-67b",
    "mistral-nemo-12b", "dbrx-132b", "deepseek-v3-671b", "jamba-v0.1-52b",
    "rwkv6-3b", "chameleon-34b",
]


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_config(name)
            model = build_model(cfg)
            params = model.init_params(jax.random.key(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


def _batch(cfg, model, b=2, s=16, key=None):
    key = key or jax.random.key(1)
    if model.is_encdec:
        return {
            "frames": jax.random.normal(key, (b, s, cfg.d_model), cfg.jnp_dtype),
            "tokens": jax.random.randint(key, (b, cfg.max_target_len), 0,
                                         cfg.vocab_size, jnp.int32),
            "labels": jax.random.randint(key, (b, cfg.max_target_len), 0,
                                         cfg.vocab_size, jnp.int32),
        }
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32),
    }


class TestRegistry:
    def test_all_assigned_registered(self):
        for a in ASSIGNED:
            assert a in list_configs()

    def test_configs_match_assignment(self):
        """Spot-check exact assigned hyperparameters."""
        c = get_config("deepseek-67b")
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
        c = get_config("qwen3-32b")
        assert c.qk_norm and c.num_kv_heads == 8 and c.vocab_size == 151936
        c = get_config("deepseek-v3-671b")
        assert c.moe.num_experts == 256 and c.moe.top_k == 8 and c.mla
        c = get_config("jamba-v0.1-52b")
        assert c.attn_period == 8 and c.moe.num_experts == 16 and c.moe.top_k == 2
        c = get_config("rwkv6-3b")
        assert c.family == "ssm" and c.d_model == 2560 and c.sub_quadratic
        c = get_config("whisper-small")
        assert c.encoder_layers == 12 and c.vocab_size == 51865

    def test_param_counts_near_nameplate(self):
        """Total params should be within ~35% of the model's nameplate size."""
        expect = {"deepseek-7b": 7e9, "deepseek-67b": 67e9, "qwen3-32b": 32e9,
                  "mistral-nemo-12b": 12e9, "dbrx-132b": 132e9,
                  "deepseek-v3-671b": 671e9, "jamba-v0.1-52b": 52e9,
                  "rwkv6-3b": 3e9, "chameleon-34b": 34e9}
        for name, nominal in expect.items():
            total, active = get_config(name).params_count()
            assert 0.65 * nominal < total < 1.45 * nominal, (name, total)
            assert active <= total

    def test_long_500k_applicability(self):
        runs = [a for a in ASSIGNED
                if shape_applicable(get_config(a), SHAPES["long_500k"])[0]]
        assert sorted(runs) == ["jamba-v0.1-52b", "rwkv6-3b"]


@pytest.mark.parametrize("name", ASSIGNED)
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, built, name):
        cfg, model, params = built(name)
        batch = _batch(cfg, model)
        loss, metrics = jax.jit(model.loss)(params, batch)
        assert np.isfinite(float(loss))
        assert np.isfinite(float(metrics["ce_loss"]))
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        for leaf in jax.tree.leaves(grads):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32)))

    def test_prefill_decode_parity(self, built, name):
        cfg, model, params = built(name)
        b, s = 2, 12
        batch = _batch(cfg, model, b=b, s=s)
        # teacher-forced logits
        if model.is_encdec:
            logits_all, _ = model._impl.forward(params, batch["frames"],
                                                batch["tokens"])
        else:
            logits_all, _ = model._impl.forward(params, batch["tokens"])
        # prefill on [:-1], then decode the final token's predecessor
        cache = model.init_cache(b, (cfg.max_target_len if model.is_encdec else s) + 4)
        if model.is_encdec:
            pf = {"frames": batch["frames"], "tokens": batch["tokens"][:, :-1]}
            last = batch["tokens"][:, -1:]
        else:
            pf = {"tokens": batch["tokens"][:, :-1]}
            last = batch["tokens"][:, -1:]
        logits_pf, cache = jax.jit(model.prefill)(params, pf, cache)
        np.testing.assert_allclose(np.asarray(logits_pf),
                                   np.asarray(logits_all[:, -2]),
                                   atol=2e-3, rtol=2e-3)
        logits_dec, cache = jax.jit(model.decode_step)(params, last, cache)
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_all[:, -1]),
                                   atol=2e-3, rtol=2e-3)

    def test_decode_is_deterministic(self, built, name):
        cfg, model, params = built(name)
        b = 2
        batch = _batch(cfg, model, b=b, s=8)
        cache = model.init_cache(b, (cfg.max_target_len if model.is_encdec else 8) + 8)
        pf = ({"frames": batch["frames"], "tokens": batch["tokens"][:, :4]}
              if model.is_encdec else {"tokens": batch["tokens"][:, :4]})
        _, c1 = jax.jit(model.prefill)(params, pf, cache)
        tok = jnp.ones((b, 1), jnp.int32)
        l1, _ = jax.jit(model.decode_step)(params, tok, c1)
        l2, _ = jax.jit(model.decode_step)(params, tok, c1)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


class TestMoEDispatch:
    def test_sorted_dispatch_matches_per_token_loop(self):
        """Sort-based MoE == explicit per-token expert loop (oracle)."""
        from repro.models import moe as moe_mod
        cfg = moe_mod.MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                                capacity_factor=10.0)  # no drops
        params = moe_mod.init_moe(jax.random.key(0), 16, cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (10, 16), jnp.float32)
        out, _ = moe_mod.moe_ffn_tokens(params, cfg, x)
        w, idx, _ = moe_mod.route(params, cfg, x)
        expect = np.zeros((10, 16), np.float32)
        we = params["experts"]
        for t in range(10):
            for j in range(cfg.top_k):
                e = int(idx[t, j])
                g = x[t] @ we["w_gate"][e]
                u = x[t] @ we["w_up"][e]
                y = (jax.nn.silu(g) * u) @ we["w_down"][e]
                expect[t] += float(w[t, j]) * np.asarray(y)
        np.testing.assert_allclose(np.asarray(out), expect, atol=1e-4, rtol=1e-4)

    def test_capacity_drops_tokens(self):
        from repro.models import moe as moe_mod
        cfg = moe_mod.MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                                capacity_factor=0.1)
        params = moe_mod.init_moe(jax.random.key(0), 8, cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (40, 8), jnp.float32)
        out, _ = moe_mod.moe_ffn_tokens(params, cfg, x)
        # capacity = 0.1*40/2 = 2 slots per expert -> most tokens dropped (zero rows)
        zero_rows = int(jnp.sum(jnp.all(out == 0, axis=-1)))
        assert zero_rows >= 30


class TestLayerOracles:
    def test_gqa_equals_repeated_mha(self):
        from repro.models import layers
        b, s, h, hk, hd = 2, 16, 8, 2, 8
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hk, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hk, hd), jnp.float32)
        out = layers.sdpa(q, k, v, causal=True)
        out2 = layers.sdpa(q, jnp.repeat(k, h // hk, 2), jnp.repeat(v, h // hk, 2),
                           causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)

    def test_rope_relative_property(self):
        """RoPE: <q_m, k_n> depends only on (m - n)."""
        from repro.models import layers
        d = 32
        q = jax.random.normal(jax.random.key(0), (1, 1, d))
        k = jax.random.normal(jax.random.key(1), (1, 1, d))
        def dot_at(m, n):
            qm = layers.apply_rope(q, jnp.array([[m]]))
            kn = layers.apply_rope(k, jnp.array([[n]]))
            return float(jnp.sum(qm * kn))
        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
        assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6  # but changes with gap

    def test_mamba_chunked_scan_matches_sequential(self):
        from repro.models import ssm
        cfg = ssm.MambaConfig(d_model=16, d_state=4, d_conv=4, expand=2, chunk=8)
        params = ssm.init_mamba(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 20, 16), jnp.float32)
        y_full = ssm.mamba_forward(params, cfg, x)
        # sequential single-token stepping must agree
        state = ssm.init_mamba_state(2, cfg, jnp.float32)
        outs = []
        for t in range(20):
            y, state = ssm.mamba_step(params, cfg, x[:, t:t + 1], state)
            outs.append(y)
        y_seq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq),
                                   atol=1e-4, rtol=1e-4)
