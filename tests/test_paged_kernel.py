"""Table-walking Pallas paged-attention decode kernel + fused in-kernel
unseal: kernel-level parity against a dense-gather oracle, fused-decrypt
parity against unseal-then-attend, backend/engine wiring, and the
ciphertext-resident restore lifecycle (MAC gate, materialization on host
consumption, decoded-token equality with the gather reference)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.sealing import (IntegrityError, SealingKey, seal_tensor,
                                verify_mac)
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_unseal,
                                           supports_fused_unseal)


# ---------------------------------------------------------------------------
# oracles and fixtures
# ---------------------------------------------------------------------------

def dense_oracle(q, k_pool, v_pool, table, valid):
    """Gather the pages dense, run masked GQA softmax attention in f64-free
    numpy — the same math the gather decode path's sdpa performs."""
    b, h, hd = q.shape
    _, ps, hk, _ = k_pool.shape
    g = h // hk
    out = np.zeros((b, h, hd), np.float32)
    for i in range(b):
        n = int(valid[i])
        if n == 0:
            continue
        phys = np.asarray(table[i])
        k = np.concatenate([np.asarray(k_pool[p]) for p in phys])[:n]
        v = np.concatenate([np.asarray(v_pool[p]) for p in phys])[:n]
        qg = np.asarray(q[i], np.float32).reshape(hk, g, hd)
        kf = k.astype(np.float32)                       # [n, hk, hd]
        s = np.einsum("kgd,nkd->kgn", qg, kf) / np.sqrt(hd)
        s = s - s.max(axis=-1, keepdims=True)
        p = np.exp(s)
        p /= p.sum(axis=-1, keepdims=True)
        out[i] = np.einsum("kgn,nkd->kgd",
                           p, v.astype(np.float32)).reshape(h, hd)
    return out


def make_pool(rng, *, slots=3, pages=4, ps=8, h=4, hk=2, hd=16,
              dtype=np.float32):
    """Random pool + a table where every slot maps a distinct shuffled set
    of physical pages and valids include a partial tail and an idle row."""
    npages = slots * pages
    k_pool = rng.normal(size=(npages + 1, ps, hk, hd)).astype(dtype)
    v_pool = rng.normal(size=(npages + 1, ps, hk, hd)).astype(dtype)
    order = rng.permutation(npages) + 1
    table = order.reshape(slots, pages).astype(np.int32)
    valid = np.array([pages * ps, 2 * ps + 3, 0][:slots] +
                     [ps] * max(0, slots - 3), np.int32)[:slots]
    q = rng.normal(size=(slots, h, hd)).astype(dtype)
    return q, k_pool, v_pool, table, valid


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("h,hk", [(4, 4), (4, 2), (8, 1)])
    def test_matches_dense_oracle(self, h, hk):
        rng = np.random.default_rng(h * 10 + hk)
        q, kp, vp, table, valid = make_pool(rng, h=h, hk=hk)
        out = paged_attention(jnp.asarray(q), jnp.asarray(kp),
                              jnp.asarray(vp), jnp.asarray(table),
                              jnp.asarray(valid))
        expect = dense_oracle(q, kp, vp, table, valid)
        live = valid > 0
        np.testing.assert_allclose(np.asarray(out)[live], expect[live],
                                   atol=2e-5, rtol=2e-5)

    def test_partial_tail_page_masked(self):
        """Garbage beyond ``valid`` in the tail page must not reach a
        logit: corrupting those positions leaves the output unchanged."""
        rng = np.random.default_rng(0)
        q, kp, vp, table, valid = make_pool(rng, slots=1, pages=2)
        valid[:] = 11                                    # page 1 holds 3
        base = np.asarray(paged_attention(
            jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(table), jnp.asarray(valid)))
        kp2, vp2 = kp.copy(), vp.copy()
        tail = table[0, 1]
        kp2[tail, 3:] = 1e6
        vp2[tail, 3:] = -1e6
        out = np.asarray(paged_attention(
            jnp.asarray(q), jnp.asarray(kp2), jnp.asarray(vp2),
            jnp.asarray(table), jnp.asarray(valid)))
        np.testing.assert_array_equal(out, base)

    def test_bf16_pool(self):
        rng = np.random.default_rng(3)
        q, kp, vp, table, valid = make_pool(rng)
        to16 = lambda a: jnp.asarray(a).astype(jnp.bfloat16)
        out = paged_attention(to16(q), to16(kp), to16(vp),
                              jnp.asarray(table), jnp.asarray(valid))
        expect = dense_oracle(np.asarray(to16(q), np.float32),
                              np.asarray(to16(kp), np.float32),
                              np.asarray(to16(vp), np.float32),
                              table, valid)
        live = valid > 0
        np.testing.assert_allclose(
            np.asarray(out, np.float32)[live], expect[live], atol=3e-2)

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_emulation_matches_pallas_interpret(self, dtype):
        """The jnp page-walk stand-in (emulate=True, the default under
        interpret) must be bit-identical to the Pallas kernel's interpret
        output — it is what engine tests and CPU benches actually run."""
        rng = np.random.default_rng(11)
        q, kp, vp, table, valid = make_pool(rng)
        args = [jnp.asarray(a).astype(dtype) for a in (q, kp, vp)]
        args += [jnp.asarray(table), jnp.asarray(valid)]
        emu = paged_attention(*args, interpret=True)
        pallas = paged_attention(*args, interpret=True, emulate=False)
        np.testing.assert_array_equal(np.asarray(emu), np.asarray(pallas))


# ---------------------------------------------------------------------------
# fused in-kernel unseal
# ---------------------------------------------------------------------------

def seal_page_linear(key, name, page):
    """Seal one [L, ps, hk, hd] page the way the backend does and return
    (ciphertext bits laid out in the page's plaintext shape, nonce words).
    Mirrors restore's _admit_cipher_page."""
    from repro.core.sealing import ciphertext_page_bytes, nonce_words_for
    st = seal_tensor(key, name, page)
    raw = ciphertext_page_bytes(st)
    bits = np.frombuffer(raw, page.dtype).reshape(page.shape)
    return st, bits, nonce_words_for(key, name)


class TestFusedUnseal:
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_in_kernel_decrypt_matches_unseal_then_attend(self, dtype):
        """The acceptance-criteria parity: a pool where some pages are
        ciphertext-resident (crypt flag live) attends identically to the
        same pool fully host-decrypted — per layer, bit-exactly."""
        rng = np.random.default_rng(7)
        L, ps, hk, hd, h, slots = 2, 8, 2, 16, 4, 2
        q, kp, vp, table, valid = make_pool(
            rng, slots=slots, pages=2, ps=ps, h=h, hk=hk, hd=hd)
        kp = jnp.asarray(kp).astype(dtype)
        vp = jnp.asarray(vp).astype(dtype)
        q = jnp.asarray(q).astype(dtype)
        page_bytes = ps * hk * hd * jnp.dtype(dtype).itemsize
        assert supports_fused_unseal(dtype, page_bytes)
        bpp = page_bytes // 64

        key = SealingKey.generate(b"fused")
        npages = kp.shape[0]
        k_crypt = np.zeros((npages, 4), np.uint32)
        v_crypt = np.zeros((npages, 4), np.uint32)
        kp_c, vp_c = np.asarray(kp).copy(), np.asarray(vp).copy()
        # make slot 0's first page ciphertext-resident; everything else
        # stays plaintext (the flag-dead path must be bit-exact identity)
        phys = int(table[0, 0])
        # the sealed blob packs the page's L layers contiguously — here the
        # kernel is called per layer, so seal an L-stacked page and place
        # each layer's ciphertext
        for pool, crypt, leaf in ((kp_c, k_crypt, "k"),
                                  (vp_c, v_crypt, "v")):
            stacked = np.stack([np.asarray(pool[phys])] * L)
            # distinct per-layer contents
            for l in range(L):
                stacked[l] += l
            st, bits, nonce = seal_page_linear(
                key, f"t['{leaf}']/p0", stacked)
            verify_mac(key, st)
            crypt[phys, :3] = nonce
            crypt[phys, 3] = 1
            pool[phys] = bits[0]          # layer 0 resident this call
        plain_kp = np.asarray(kp).copy()
        plain_vp = np.asarray(vp).copy()

        fused = paged_attention_unseal(
            q, jnp.asarray(kp_c), jnp.asarray(vp_c), jnp.asarray(table),
            jnp.asarray(valid), jnp.int32(0), key.key_words,
            jnp.asarray(k_crypt), jnp.asarray(v_crypt),
            blocks_per_page=bpp)
        ref = paged_attention(q, jnp.asarray(plain_kp),
                              jnp.asarray(plain_vp), jnp.asarray(table),
                              jnp.asarray(valid))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))

        # and the jnp stand-in decrypts bit-identically to the Pallas
        # interpreter on the same mixed cipher/plaintext pool
        pallas = paged_attention_unseal(
            q, jnp.asarray(kp_c), jnp.asarray(vp_c), jnp.asarray(table),
            jnp.asarray(valid), jnp.int32(0), key.key_words,
            jnp.asarray(k_crypt), jnp.asarray(v_crypt),
            blocks_per_page=bpp, emulate=False)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(pallas))

    def test_layer_counter_offset(self):
        """Layer l decrypts with counter_base = l * blocks_per_page: layer
        1's ciphertext under layer index 1 must equal its plaintext."""
        rng = np.random.default_rng(11)
        L, ps, hk, hd, h = 3, 8, 2, 16, 4
        q, kp, vp, table, valid = make_pool(
            rng, slots=1, pages=1, ps=ps, h=h, hk=hk, hd=hd)
        bpp = ps * hk * hd * 4 // 64
        key = SealingKey.generate(b"layers")
        phys = int(table[0, 0])
        stacked = rng.normal(size=(L, ps, hk, hd)).astype(np.float32)
        _, bits, nonce = seal_page_linear(key, "t['k']/p0", stacked)
        crypt = np.zeros((kp.shape[0], 4), np.uint32)
        crypt[phys, :3], crypt[phys, 3] = nonce, 1
        for l in range(L):
            kp_l = kp.copy()
            kp_l[phys] = bits[l]
            fused = paged_attention_unseal(
                jnp.asarray(q), jnp.asarray(kp_l), jnp.asarray(vp),
                jnp.asarray(table), jnp.asarray(valid), jnp.int32(l),
                key.key_words, jnp.asarray(crypt),
                jnp.asarray(np.zeros_like(crypt)), blocks_per_page=bpp)
            kp_p = kp.copy()
            kp_p[phys] = stacked[l]
            ref = paged_attention(jnp.asarray(q), jnp.asarray(kp_p),
                                  jnp.asarray(vp), jnp.asarray(table),
                                  jnp.asarray(valid))
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(ref))

    def test_eligibility_predicate(self):
        assert supports_fused_unseal(jnp.float32, 8192)
        assert supports_fused_unseal(jnp.bfloat16, 4096)
        assert not supports_fused_unseal(jnp.float32, 8192 + 32)  # not 64B
        assert not supports_fused_unseal(jnp.int8, 8192)          # dtype


# ---------------------------------------------------------------------------
# backend + engine wiring
# ---------------------------------------------------------------------------

_MODEL_CACHE = {}


def tiny_model():
    from repro.configs import smoke_config
    from repro.models import build_model
    if "m" not in _MODEL_CACHE:
        cfg = smoke_config("deepseek-7b")
        model = build_model(cfg)
        _MODEL_CACHE["m"] = (model, model.init_params(jax.random.key(0)))
    return _MODEL_CACHE["m"]


@pytest.fixture(scope="module")
def kernel_engine_pair():
    """Decoded outputs of the same workload under gather and kernel decode
    (module-scoped: compiled engines are expensive under interpret)."""
    from repro.runtime import Engine, GenerationRequest, SamplingParams
    model, params = tiny_model()
    rng = np.random.default_rng(0)
    specs = [(list(rng.integers(1, 250, 6)), 10, i) for i in range(3)]

    def run(kv_decode):
        eng = Engine(model, params, max_slots=2, max_len=64,
                     prefill_buckets=(4, 8), kv_backend="paged",
                     page_size=8, kv_decode=kv_decode)
        reqs = [eng.submit(GenerationRequest(
                    prompt=np.asarray(p, np.int32), max_new_tokens=m,
                    params=SamplingParams(temperature=0.9, top_k=16,
                                          seed=s)))
                for p, m, s in specs]
        eng.run(max_steps=10_000)
        return [list(map(int, r.output)) for r in reqs], eng
    return run("gather"), run("kernel")


class TestKernelDecodeMode:
    def test_decoded_tokens_match_gather(self, kernel_engine_pair):
        (g_out, _), (k_out, k_eng) = kernel_engine_pair
        assert g_out == k_out
        assert k_eng.kv.decode_mode == "kernel"

    def test_slot_backend_rejects_kernel(self):
        from repro.runtime.kvcache import make_backend
        model, _ = tiny_model()
        with pytest.raises(ValueError, match="kv_decode"):
            make_backend("slot", model, max_slots=2, max_len=32,
                         decode="kernel")

    def test_bad_mode_rejected(self):
        from repro.runtime.kvcache import make_backend
        model, _ = tiny_model()
        with pytest.raises(ValueError):
            make_backend("paged", model, max_slots=2, max_len=32,
                         decode="fast")

    def test_sharded_plan_rejects_kernel(self):
        from repro.runtime.plan import ShardedPlan
        from repro.runtime.kvcache import make_backend
        model, _ = tiny_model()
        plan = ShardedPlan.from_spec(model, "dp=2")
        with pytest.raises(ValueError, match="single-device"):
            make_backend("paged", model, max_slots=2, max_len=32,
                         plan=plan, decode="kernel")


# ---------------------------------------------------------------------------
# ciphertext-resident restore lifecycle
# ---------------------------------------------------------------------------

def seal_restore_cycle(kv_decode, *, tamper=False, after=None):
    """Prefill+decode a request, whole-slot seal it, release, restore into
    a fresh slot, then decode 6 more steps greedily straight against the
    backend. Returns (tokens, backend)."""
    from repro.runtime import Engine, GenerationRequest, SamplingParams
    model, params = tiny_model()
    eng = Engine(model, params, max_slots=2, max_len=64,
                 prefill_buckets=(4, 8), kv_backend="paged", page_size=8,
                 kv_decode=kv_decode)
    kv = eng.kv
    rng = np.random.default_rng(42)
    prompt = np.asarray(list(rng.integers(1, 250, 20)), np.int32)
    eng.submit(GenerationRequest(
        prompt=prompt, max_new_tokens=24,
        params=SamplingParams(temperature=0.9, top_k=16, seed=7)))
    for _ in range(14):
        eng.step()
    key = SealingKey.generate(b"cycle")
    slot = next(s for s in range(2) if eng._active_mask[s])
    last = int(eng._last_token[slot])
    sealed = kv.seal(key, slot, "ckpt")
    pos = int(kv.pos[slot])
    kv.release(slot)
    s2 = kv.acquire(999, 64)
    if tamper:
        name = next(n for n in sealed if n.endswith("/p0"))
        ct = np.array(sealed[name].ciphertext)
        ct[0, 0] ^= 1
        sealed[name].ciphertext = jnp.asarray(ct)
        with pytest.raises(IntegrityError):
            kv.restore(key, sealed, s2, "ckpt", pos)
        return None, kv
    kv.restore(key, sealed, s2, "ckpt", pos)
    if after is not None:
        after(kv, s2)
    toks, out = np.zeros(2, np.int32), []
    toks[s2] = last
    for _ in range(6):
        nt = kv.decode(eng.params, toks, None, 0, [s2])
        toks[s2] = nt[s2]
        out.append(int(nt[s2]))
    return out, kv


class TestFusedRestore:
    def test_restore_admits_ciphertext_and_matches_gather(self):
        g, gkv = seal_restore_cycle("gather")
        k, kkv = seal_restore_cycle("kernel")
        assert g == k
        assert gkv.fused_restore_pages == 0
        # pos=22, page_size=8 -> pages 0 and 1 are full (fused), page 2 is
        # the partial tail (host path)
        assert kkv.fused_restore_pages == 2
        assert kkv.fused_restore_bytes > 0
        assert len(kkv._cipher_pages) == 2

    def test_tampered_page_fails_mac_before_admission(self):
        _, kv = seal_restore_cycle("kernel", tamper=True)
        assert not kv._cipher_pages      # nothing was admitted

    def test_materialize_on_reseal(self):
        """Sealing a slot holding ciphertext-resident pages host-decrypts
        them first; the re-sealed blobs restore to the same plaintext."""
        events = {}

        def reseal(kv, slot):
            key2 = SealingKey.generate(b"second")
            kv.seal(key2, slot, "ckpt2")
            events["cipher_after"] = set(kv._cipher_pages)
            events["ev"] = [e for e in kv.drain_events()
                            if e[0] == "materialize"]
        out, kv = seal_restore_cycle("kernel", after=reseal)
        assert events["cipher_after"] == set()
        assert len(events["ev"]) == 2            # both fused pages
        # decode after materialization still agrees with gather
        g, _ = seal_restore_cycle("gather")
        assert out == g

    def test_gather_mode_never_goes_fused(self):
        _, kv = seal_restore_cycle("gather")
        assert kv.decode_mode == "gather"
        assert not kv._cipher_pages
        assert kv.fused_restore_pages == 0
