"""Mesh-spanning engine (ComputePlan seam): single-device vs sharded parity,
per-shard sealing, measured collective accounting, and the
``overheads.predict`` measured-collective override.

Fast tier runs everything on an in-process 1-device mesh (the plan/wrapper
machinery is fully exercised — placement, suffixed sealing, HLO analysis —
without multi-device state). The 8-device byte-identity checks run in a
subprocess with a forced host device count (same harness as
tests/test_distributed.py) and carry ``pytest.mark.slow``.
"""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.core.bounce import ChannelStats
from repro.core.overheads import PROFILES, RooflineTerms, predict
from repro.models import build_model
from repro.runtime import (Engine, GenerationRequest, SamplingParams,
                           ShardedKVBackend, ShardedPlan, SingleDevicePlan,
                           parse_mesh)


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def make_engine(model, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_len", 8)
    return Engine(model, params, **kw)


PROMPT = np.arange(1, 9, dtype=np.int32)


def gen(prompt=PROMPT, **kw):
    return GenerationRequest(prompt=np.asarray(prompt, np.int32), **kw)


class TestPlanPlumbing:
    def test_default_plan_is_single_device(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params)
        assert isinstance(eng.plan, SingleDevicePlan)
        assert not isinstance(eng.kv, ShardedKVBackend)

    def test_mesh_engine_gets_sharded_plan_and_wrapper(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params, mesh="dp=1")
        assert isinstance(eng.plan, ShardedPlan)
        assert isinstance(eng.kv, ShardedKVBackend)
        assert eng.plan.dp == 1 and eng.plan.tp == 1

    def test_mesh_and_plan_are_exclusive(self, small_model):
        cfg, model, params = small_model
        with pytest.raises(ValueError, match="not both"):
            make_engine(model, params, mesh="dp=1",
                        plan=SingleDevicePlan(model))

    def test_parse_mesh(self):
        assert parse_mesh("dp=2") == (2, 1)
        assert parse_mesh("dp=2,tp=4") == (2, 4)
        assert parse_mesh("tp=2") == (1, 2)
        for bad in ("dp", "dp=0", "pp=2", "dp=2;tp=2", "", "  "):
            with pytest.raises(ValueError):
                parse_mesh(bad)

    def test_empty_mesh_string_rejected(self, small_model):
        """An empty --mesh (e.g. an unset shell variable) must fail loudly,
        not silently build a single-device engine."""
        cfg, model, params = small_model
        with pytest.raises(ValueError, match="empty mesh"):
            make_engine(model, params, mesh="")

    def test_oversized_mesh_rejected_with_hint(self, small_model):
        cfg, model, params = small_model
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            make_engine(model, params, mesh="dp=4096")


class TestOneDeviceMeshParity:
    """Sharded-vs-single output parity moved into the differential harness
    (test_differential.py), which replays the canonical scenario on a REAL
    in-process dp=2 mesh — strictly stronger than the dp=1 smoke this class
    used to run. What stays here is the per-shard sealing machinery."""

    def test_seal_names_carry_shard_suffix_and_roundtrip(self, small_model):
        """Per-shard sealing: every sealed name ends in /s{shard}, and a
        preemption round-trips byte-identically through the tagged form."""
        cfg, model, params = small_model
        sp = SamplingParams(temperature=0.9, top_k=8, seed=3)
        ref = make_engine(model, params, max_slots=1).generate(
            gen(max_new_tokens=8, params=sp)).tokens
        eng = make_engine(model, params, max_slots=1, mesh="dp=1",
                          trust_domain=TrustDomain("tdx"))
        req = eng.submit(gen(max_new_tokens=8, params=sp))
        for _ in range(3):
            eng.step()
        sealed, evicted = eng.seal_slot(0)
        assert sealed and all(n.endswith("/s0") for n in sealed), \
            sorted(sealed)
        eng.restore_slot(sealed, evicted)
        eng.run(max_steps=50_000)
        assert req.output == ref

    def test_partial_eviction_tail_suffix_roundtrip(self, small_model):
        """Page-granular partial eviction under a mesh: the tail blob's
        names carry the shard tag and the delta restore finds them."""
        cfg, model, params = small_model
        common = dict(max_slots=1, kv_backend="paged", page_size=8)
        ref = make_engine(model, params, **common).generate(
            gen(np.arange(1, 25, dtype=np.int32), max_new_tokens=8)).tokens
        eng = make_engine(model, params, mesh="dp=1",
                          trust_domain=TrustDomain("tdx"), **common)
        req = eng.submit(gen(np.arange(1, 25, dtype=np.int32),
                             max_new_tokens=8))
        for _ in range(2):
            eng.step()
        eng.partial_preempt(0, 1)
        assert 0 in eng._paused
        assert any(n.endswith("/s0") for n in eng._paused[0].sealed)
        eng.run(max_steps=50_000)      # _resume_paused restores the delta
        assert req.output == ref

    def test_collective_counters_flow_into_channel_stats(self, small_model):
        """Even a 1-device mesh counts its decode steps (bytes are honestly
        zero — nothing crosses between devices)."""
        cfg, model, params = small_model
        td = TrustDomain("cgpu")
        eng = make_engine(model, params, mesh="dp=1", trust_domain=td)
        eng.generate(gen(max_new_tokens=5))
        assert td.channel.stats.collective_steps > 0
        assert td.channel.stats.collective_bytes == 0


class TestMeasuredLinkTax:
    def test_predict_collective_override(self):
        terms = RooflineTerms(compute_s=1e-3, memory_s=1e-3,
                              collective_s=1e-4)
        base = predict(terms, "cgpu")
        measured = predict(terms, "cgpu", collective_s=1e-3)
        # 10x the collective time under a 12.3x link tax must cost more
        assert measured.overhead > base.overhead
        # the override replaces (not adds to) the closed-form estimate
        same = predict(terms, "cgpu", collective_s=1e-4)
        assert abs(same.overhead - base.overhead) < 1e-12

    def test_link_tax_provenance_pinned(self):
        """Insight 12: 40/3 - 1 ≈ 12.3 (host-routed vs RDMA, §V-D4). The
        measured path prices the same ratio off observed collective time."""
        assert PROFILES["cgpu"].link_tax == pytest.approx(40 / 3 - 1, abs=0.1)

    def test_channel_stats_collective_fields(self):
        ch = ChannelStats()
        assert ch.collective_s_per_step == 0.0
        ch.collective_steps, ch.collective_bytes, ch.collective_s = 4, 400, 2.0
        assert ch.collective_s_per_step == 0.5
        ch.reset()
        assert (ch.collective_steps, ch.collective_bytes, ch.collective_s) \
            == (0, 0, 0.0)


@pytest.mark.slow
class TestEightDeviceParity:
    def test_sharded_outputs_byte_identical_with_preemption(self, subproc):
        """Acceptance: seeded generate() under ShardedPlan (slot AND paged)
        is byte-identical to single-device, including across sealed
        preemption/restore, on a real 8-device mesh — and the mesh engine
        measures nonzero collective traffic."""
        out = subproc("""
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import build_model
from repro.core import TrustDomain
from repro.runtime import (Engine, GenerationRequest, SamplingParams,
                           ShardedKVBackend)

cfg = smoke_config("deepseek-7b")
m = build_model(cfg)
params = m.init_params(jax.random.key(0))
rng = np.random.default_rng(3)
prompts = [rng.integers(1, cfg.vocab_size, size=int(l)).astype(np.int32)
           for l in rng.integers(8, 40, size=10)]

def scenario(mesh, kv):
    td = TrustDomain("tdx")
    eng = Engine(m, params, max_slots=8, max_len=64,
                 prefill_buckets=(8, 16, 32), trust_domain=td,
                 kv_backend=kv, page_size=8, mesh=mesh)
    low = [eng.submit(GenerationRequest(
               prompt=p, max_new_tokens=10, priority=0,
               params=SamplingParams(temperature=0.8, top_k=16, seed=i,
                                     repetition_penalty=1.2)))
           for i, p in enumerate(prompts)]
    for _ in range(3):
        eng.step()
    high = [eng.submit(GenerationRequest(
                prompt=prompts[i][:8], max_new_tokens=6, priority=5,
                params=SamplingParams(temperature=0.8, top_k=16,
                                      seed=100 + i)))
            for i in range(8)]
    eng.run(max_steps=100_000)
    assert all(r.finished for r in low + high)
    return ([r.output for r in low + high],
            sum(r.n_preemptions for r in low), eng, td)

for kv in ("slot", "paged"):
    single, p1, _, _ = scenario(None, kv)
    mesh, p2, eng, td = scenario("dp=8", kv)
    assert single == mesh, f"{kv}: sharded outputs diverged"
    assert p1 > 0 and p2 > 0, f"{kv}: no preemption exercised ({p1}, {p2})"
    assert isinstance(eng.kv, ShardedKVBackend)
    ch = td.channel.stats
    assert ch.collective_steps > 0 and ch.collective_bytes > 0, \\
        f"{kv}: no collective traffic measured"
    assert ch.collective_s > 0
    print(kv, "OK", ch.collective_bytes // ch.collective_steps, "B/step")
print("OK")
""", devices=8)
        assert "OK" in out
        assert "slot OK" in out and "paged OK" in out
