"""Paged KV backend: slot-vs-paged output parity (incl. across sealed
preemption), page-granular seal/restore round trips, partial eviction,
page-table reuse after free, tampered-page MAC failure, and page-charged
admission accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.core.sealing import IntegrityError, _nonce_for
from repro.models import build_model
from repro.runtime import Engine, GenerationRequest
from repro.runtime.kvcache import make_backend
from repro.runtime.paged import PagedKVBackend


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


PROMPT = np.arange(1, 9, dtype=np.int32)


def G(prompt=PROMPT, max_new_tokens=8, **kw):
    return GenerationRequest(prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=max_new_tokens, **kw)


def make_engine(model, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_len", 8)
    return Engine(model, params, **kw)


def paged_engine(model, params, **kw):
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 8)
    return make_engine(model, params, **kw)


class TestBackendConstruction:
    def test_factory_and_flags(self, small_model):
        cfg, model, params = small_model
        assert make_engine(model, params).kv.name == "slot"
        assert paged_engine(model, params).kv.name == "paged"
        with pytest.raises(ValueError, match="kv backend"):
            make_engine(model, params, kv_backend="vllm")
        with pytest.raises(ValueError, match="multiple"):
            paged_engine(model, params, page_size=7)   # 64 % 7 != 0
        with pytest.raises(ValueError, match="page_size"):
            paged_engine(model, params, page_size=0)

    def test_backend_direct(self, small_model):
        cfg, model, params = small_model
        be = make_backend("paged", model, max_slots=2, max_len=64, page_size=8)
        assert isinstance(be, PagedKVBackend)
        assert be.max_pages == 8 and be.num_pages == 16
        assert be.pages_for(1) == 1 and be.pages_for(8) == 1
        assert be.pages_for(9) == 2
        assert be.free_physical_pages == 16
        # the paged pool's footprint matches the dense cache (+1 null page
        # per paged leaf)
        dense = make_backend("slot", model, max_slots=2, max_len=64)
        assert be.cache_nbytes() >= dense.cache_nbytes()


class TestParity:
    # The fast-tier slot-vs-paged parity tests (greedy mixes, seeded
    # sampling across forced preemption) moved into the cross-backend
    # differential harness: tests/test_differential.py replays ONE
    # canonical scenario over slot / paged / paged+sharing / sharded(dp=2)
    # and diffs everything against solo references. Only the slow
    # long-context mix stays here (the harness scenario is short).

    @pytest.mark.slow
    def test_long_context_parity(self, small_model):
        """Long-context mix across both backends: chunked prefill tails,
        multi-page sequences, and a forced preemption all preserve parity."""
        cfg, model, params = small_model
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (70, 150, 230)]
        outs = []
        for backend in ("slot", "paged"):
            eng = Engine(model, params, max_slots=2, max_len=512,
                         prefill_buckets=(32, 64, 128), kv_backend=backend,
                         page_size=32, trust_domain=TrustDomain("tdx"))
            reqs = [eng.submit(G(p, 12, priority=0)) for p in prompts]
            for _ in range(3):
                eng.step()
            eng.submit(G(np.full(16, 5, np.int32), max_new_tokens=4,
                         priority=9))   # forces a sealed eviction
            eng.run(max_steps=50_000)
            assert all(r.finished for r in reqs)
            outs.append([r.output for r in reqs])
        assert outs[0] == outs[1]


class TestPageGranularSealing:
    # sealed-bytes ordering vs the slot backend is asserted by the
    # differential harness (test_differential.py) on the canonical
    # scenario's real preemption pattern.

    def test_per_page_nonces_are_unique(self, small_model):
        """Every sealed page gets its own nonce (name), across leaves, page
        ordinals, and seal epochs."""
        cfg, model, params = small_model
        td = TrustDomain("tdx")
        eng = paged_engine(model, params, max_slots=1, trust_domain=td)
        req = eng.submit(G(max_new_tokens=12))
        for _ in range(2):
            eng.step()
        sealed1, evicted = eng.seal_slot(0)
        eng.restore_slot(sealed1, evicted)
        for _ in range(2):
            eng.step()
        sealed2, evicted = eng.seal_slot(0)
        names = list(sealed1) + list(sealed2)
        assert len(set(names)) == len(names)
        nonces = {_nonce_for(td.sealing_key, n) for n in names}
        assert len(nonces) == len(names)
        page_names = [n for n in sealed2 if "/p" in n]
        assert page_names, "paged seal must contain per-page entries"

    def test_tampered_page_fails_mac(self, small_model):
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=1,
                           trust_domain=TrustDomain("tdx"))
        req = eng.submit(G(max_new_tokens=6))
        eng.step()
        sealed, evicted = eng.seal_slot(0)
        victim = next(st for name, st in sealed.items() if "/p0" in name)
        ct = np.asarray(victim.ciphertext).copy()
        ct[0, 0] ^= 1
        victim.ciphertext = jnp.asarray(ct)
        with pytest.raises(IntegrityError, match="/p0"):
            eng.restore_slot(sealed, evicted)
        # the failed restore must not leak the slot or its page reservation
        assert eng.slots.num_active == 0
        assert eng.kv.free_page_reserve == eng.kv.num_pages

    def test_tampered_meta_fails_mac(self, small_model):
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=1,
                           trust_domain=TrustDomain("tdx"))
        eng.submit(G(max_new_tokens=6))
        eng.step()
        sealed, evicted = eng.seal_slot(0)
        meta = next(st for name, st in sealed.items() if name.endswith("/meta"))
        meta.mac = b"\x00" * 32
        with pytest.raises(IntegrityError, match="meta"):
            eng.restore_slot(sealed, evicted)


class TestPartialEviction:
    def test_partial_round_trip_preserves_output(self, small_model):
        """Seal the victim's tail pages, let the pool serve someone else,
        restore the delta, and the victim's tokens are unchanged."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=2, num_pages=8,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(max_new_tokens=10, priority=0))
        for _ in range(3):
            eng.step()                  # pos=11 -> 2 pages allocated
        assert eng.kv.allocated_pages(0) == 2
        free_before = eng.kv.free_physical_pages
        eng.partial_preempt(0, 1)
        assert eng.kv.allocated_pages(0) == 1
        assert eng.kv.free_physical_pages == free_before + 1
        assert low.n_preemptions == 1
        # the paused victim sits out of the batch but keeps its slot
        assert 0 in eng.scheduler.running
        eng.step()                      # resume restores the sealed delta
        eng.run()
        assert low.output == ref

    def test_partial_eviction_triggered_by_page_pressure(self, small_model):
        """A high-priority arrival that is short only on *pages* (a slot is
        free) partially evicts the victim's tail instead of sealing the
        whole slot."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=2, num_pages=8,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(max_new_tokens=10, priority=0))   # 3 pages reserved
        for _ in range(3):
            eng.step()                  # 2 pages physically allocated
        # needs 6 pages; only 5 unreserved -> shortfall of 1 page
        hi = eng.submit(G(np.full(8, 7, np.int32), max_new_tokens=41,
                          priority=5))
        eng.run(max_steps=300)
        assert hi.finished and low.finished
        assert low.output == ref
        partials = [e for e in eng.td.audit
                    if e.kind == "seal_kv" and "partial" in e.detail]
        assert len(partials) == 1
        restores = [e for e in eng.td.audit
                    if e.kind == "restore_kv" and "partial" in e.detail]
        assert len(restores) == 1

    def test_whole_seal_of_paused_slot_reassembles(self, small_model):
        """A partially-evicted slot can still be whole-sealed (so a yet
        higher-priority arrival is never stranded behind a paused victim):
        the resident remainder seals under a fresh epoch, the earlier tail
        blob rides along, and restore grafts both back."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=1, num_pages=8,
                           trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(max_new_tokens=10))
        for _ in range(3):
            eng.step()                  # 2 pages allocated
        eng.partial_preempt(0, 1)       # paused, 1 resident page
        sealed, evicted = eng.seal_slot(0)     # whole-seal while paused
        assert eng.slots.num_active == 0
        assert eng.kv.free_page_reserve == eng.kv.num_pages
        assert any(n.endswith("/pagemeta") for n in sealed)   # tail blob rode
        eng.restore_slot(sealed, evicted)
        assert eng.kv.allocated_pages(0) == 2   # remainder + grafted tail
        eng.run()
        assert low.output == ref

    @pytest.mark.slow
    def test_hybrid_model_pause_freezes_recurrent_state(self):
        """On a hybrid (mamba+attn) arch the paged backend must freeze a
        paused row's recurrent-state leaves while its slot-mates keep
        stepping — only rows that actually append may advance — or the
        victim would resume from corrupted SSM state."""
        cfg = smoke_config("jamba-v0.1-52b")
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        prompt = np.arange(1, 9, dtype=np.int32)
        eng = Engine(model, params, max_slots=2, max_len=64, prefill_len=8,
                     kv_backend="paged", page_size=8,
                     trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(prompt, 10))
        mate = eng.submit(G(np.full(8, 3, np.int32), 10))
        for _ in range(3):
            eng.step()

        def state_rows(slot):
            rows = {}
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    eng.kv.blocks)[0]:
                k = jax.tree_util.keystr(path)
                if k not in eng.kv._paged_paths:
                    rows[k] = np.asarray(leaf[:, slot])
            return rows

        assert state_rows(0), "hybrid model must have recurrent-state leaves"
        before = state_rows(0)
        # a decode step slot 0 sits out of (write_slots excludes it — what
        # the engine passes while a slot is paused) must leave its
        # recurrent-state rows bit-identical, while the stepping mate's move
        eng.kv.decode(eng.params, eng._last_token, None, 0, write_slots=[1])
        after = state_rows(0)
        for k in before:
            np.testing.assert_array_equal(before[k], after[k], err_msg=k)
        eng.run()                       # both finish normally afterwards
        assert low.finished and mate.finished

    def test_paused_victim_is_not_stranded_capacity(self, small_model):
        """An even-higher-priority arrival can whole-seal a paused victim
        (partial tail + resident remainder both travel), so a paused slot
        never wedges the pool: everyone eventually finishes and the twice-
        evicted victim's tokens are exact."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=3, num_pages=8,
                           trust_domain=TrustDomain("tdx"))
        a = eng.submit(G(max_new_tokens=10, priority=0))     # 3 pages
        for _ in range(3):
            eng.step()                  # a allocates its 2nd page
        b = eng.submit(G(np.full(8, 7, np.int32), max_new_tokens=41,
                         priority=5))   # 6 pages -> partial-evicts a
        eng.step()
        assert 0 in eng._paused and a.n_preemptions == 1
        c = eng.submit(G(np.full(8, 9, np.int32), max_new_tokens=9,
                         priority=9))   # 2 pages -> must whole-seal paused a
        eng.run(max_steps=500)
        assert a.finished and b.finished and c.finished
        assert a.n_preemptions == 2     # partial, then whole while paused
        assert a.output == ref
        assert not eng._paused and not eng._preempted
        assert eng.kv.free_page_reserve == eng.kv.num_pages

    def test_partial_preempt_rejects_bad_usage(self, small_model):
        cfg, model, params = small_model
        slot_eng = make_engine(model, params, max_slots=1)
        slot_eng.submit(G(max_new_tokens=6))
        slot_eng.step()
        with pytest.raises(RuntimeError, match="page granularity"):
            slot_eng.partial_preempt(0, 1)
        eng = paged_engine(model, params, max_slots=1, page_size=32)
        eng.submit(G(max_new_tokens=6))
        eng.step()                       # 1 page allocated: no strict subset
        assert eng.kv.allocated_pages(0) == 1
        with pytest.raises(ValueError, match="partial eviction"):
            eng.partial_preempt(0, 1)


class TestPageAccounting:
    def test_pages_released_and_reused_after_free(self, small_model):
        """Slots churn through the pool: every page returns to the free list
        when its sequence finishes, and later sequences reuse the same
        physical pages through fresh table entries."""
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=2, num_pages=8)
        first = eng.submit(G(max_new_tokens=6))
        eng.run()
        assert first.finished
        assert eng.kv.free_physical_pages == 8
        assert eng.kv.free_page_reserve == 8
        used_before = set()
        # serve more sequential waves than the pool could hold at once
        refs = []
        for i in range(4):
            req = eng.submit(G(np.full(8, i + 1, np.int32), max_new_tokens=6))
            eng.step()
            used_before |= {int(p) for p in eng.kv.table[:, :2].ravel() if p}
            eng.run()
            refs.append(req)
        assert all(r.finished and len(r.output) == 6 for r in refs)
        assert eng.kv.free_physical_pages == 8
        assert (eng.kv.table == 0).all()          # fully unmapped when idle
        assert len(used_before) < 8 * 4           # pages were reused

    def test_admission_charges_pages_not_max_len(self, small_model):
        """Two requests each reserving >half the pool serialize on pages
        even though slots are free — and both finish (reservation-based
        accounting cannot deadlock appends)."""
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=2, num_pages=8)
        a = eng.submit(G(max_new_tokens=33))   # need 8+32=40 -> 5 pages
        b = eng.submit(G(np.full(8, 3, np.int32), max_new_tokens=33))
        eng.step()
        assert len(eng.scheduler.running) == 1    # b is page-gated
        assert eng.kv.free_page_reserve == 3
        eng.run(max_steps=500)
        assert a.finished and b.finished
        assert a.t_done <= b.t_done

    def test_prompt_budget_and_capacity_reflect_pool(self, small_model):
        cfg, model, params = small_model
        slot_eng = make_engine(model, params, max_len=64)
        tiny = paged_engine(model, params, max_len=64, num_pages=4)
        assert tiny.kv.request_capacity == 32
        assert slot_eng.prompt_budget(16) > tiny.prompt_budget(16)
        assert tiny.prompt_budget(16) == 32 - 16 + 1
        with pytest.raises(ValueError, match="KV positions"):
            tiny.submit(G(np.ones(30, np.int32), 16))
        tiny.submit(G(np.ones(tiny.prompt_budget(16), np.int32), 16))
        tiny.run()


from conftest import make_sharing_engine as sharing_engine  # noqa: E402


class TestPrefixSharing:
    def test_construction_flags(self, small_model):
        cfg, model, params = small_model
        with pytest.raises(ValueError, match="paged"):
            make_engine(model, params, prefix_sharing=True)   # slot backend
        with pytest.raises(ValueError, match="ondemand"):
            paged_engine(model, params, prefix_sharing=True,
                         kv_alloc="reserve")
        with pytest.raises(ValueError, match="alloc"):
            paged_engine(model, params, kv_alloc="lazy")
        eng = sharing_engine(model, params)
        assert eng.kv.supports_sharing and eng.kv.on_demand
        plain = paged_engine(model, params)
        assert not plain.kv.supports_sharing and not plain.kv.on_demand

    def test_prefix_page_keys_are_cumulative(self):
        from repro.runtime.paged import prefix_page_keys
        a = prefix_page_keys(np.arange(16, dtype=np.int32), 4, 16)
        assert len(a) == 4 and len(set(a)) == 4
        # same content => same keys; a flipped EARLY token changes every
        # later key (KV at a position depends on all earlier tokens)
        b = prefix_page_keys(np.arange(16, dtype=np.int32), 4, 16)
        assert a == b
        toks = np.arange(16, dtype=np.int32)
        toks[1] = 99
        c = prefix_page_keys(toks, 4, 16)
        assert all(x != y for x, y in zip(a, c))
        # a diverging LATER page keeps the common prefix keys
        toks = np.arange(16, dtype=np.int32)
        toks[9] = 99
        d = prefix_page_keys(toks, 4, 16)
        assert d[:2] == a[:2] and d[2:] != a[2:]
        # partial final page: length-sensitive
        e = prefix_page_keys(np.arange(16, dtype=np.int32), 4, 10)
        assert len(e) == 3 and e[:2] == a[:2] and e[2] != a[2]

    def test_identical_prompts_share_and_release_cleanly(self, small_model):
        cfg, model, params = small_model
        eng = sharing_engine(model, params, max_slots=2)
        a = eng.submit(G(max_new_tokens=6))
        b = eng.submit(G(max_new_tokens=6))
        eng.step()
        # one physical page serves both tables (prompt = exactly one page)
        assert eng.kv.shared_page_maps == 1
        phys = [int(eng.kv.table[s, 0]) for s in (0, 1)]
        assert phys[0] == phys[1] and eng.kv._page_ref[phys[0]] == 2
        eng.run()
        assert a.output == b.output
        assert eng.kv.free_physical_pages == eng.kv.num_pages
        assert not eng.kv._index and not eng.kv._parked

    def test_share_prefix_opt_out_stays_private(self, small_model):
        cfg, model, params = small_model
        eng = sharing_engine(model, params, max_slots=2)
        eng.submit(G(max_new_tokens=4, share_prefix=False))
        eng.submit(G(max_new_tokens=4, share_prefix=False))
        eng.run()
        assert eng.kv.shared_page_maps == 0
        # an opted-out page is never index-registered either
        eng.submit(G(max_new_tokens=4, share_prefix=False))
        eng.step()
        assert not eng.kv._index
        eng.run()

    def test_resident_prefix_relaxes_admission_not_capacity(self,
                                                            small_model):
        """Satellite: effective (post-sharing) accounting. The per-request
        capacity bound is physical (every page of one sequence is mapped
        simultaneously, shared or not) and stays put; what residency lowers
        is the demand admission charges against the pool — a request whose
        prompt is resident admits on one page of append headroom, while an
        opted-out twin (prompt page + headroom) has to wait."""
        cfg, model, params = small_model
        eng = sharing_engine(model, params, max_slots=3, num_pages=4)
        # capacity = min(64, 4 * 8) = 32 positions, resident or not
        with pytest.raises(ValueError, match="KV positions"):
            eng.submit(G(max_new_tokens=26))        # need 8+25 = 33 > 32
        keepers = [eng.submit(G(max_new_tokens=12)) for _ in range(2)]
        eng.step()
        # two keepers: 1 shared prompt page + 1 private decode page each
        assert eng.kv.free_physical_pages == 1
        need, eff = eng.effective_kv_need(PROMPT, 4)
        assert (need, eff) == (11, 3)     # prompt page resident: 8 off
        warm = eng.submit(G(max_new_tokens=4))
        assert warm.kv_need == 3
        cold = eng.submit(G(max_new_tokens=4, share_prefix=False))
        eng.step()
        # the resident-prefix request admitted into the one spare page; the
        # opted-out twin (fresh prompt page + headroom vs 1 free) queued
        assert any(r is warm for r in eng.scheduler.running.values())
        assert all(r is not cold for r in eng.scheduler.running.values())
        eng.run(max_steps=2000)
        assert all(r.finished for r in keepers + [warm, cold])
        assert warm.output == cold.output   # opting out never changes tokens

    def test_shared_head_not_partially_evictable(self, small_model):
        """Partial eviction may only take private tail pages: a shared page
        cannot be torn out of other readers' tables."""
        cfg, model, params = small_model
        p16 = np.arange(1, 17, dtype=np.int32)
        eng = sharing_engine(model, params, max_slots=2,
                             prefill_buckets=(16,))
        a = eng.submit(G(p16, max_new_tokens=10))
        b = eng.submit(G(p16, max_new_tokens=10))
        for _ in range(3):
            eng.step()   # 2 shared prompt pages + 1 private decode page
        assert eng.kv.evictable_tail_pages(0) == 1
        with pytest.raises(ValueError, match="shared prefix"):
            eng.kv.seal_tail_pages(eng.td.sealing_key, 0, "kvslot/x/0", 2)
        eng.partial_preempt(0, 1)      # the private tail is fair game
        eng.run()
        assert a.output == b.output

    def test_lone_live_slot_reclaims_pages_from_paused_victim(
            self, small_model):
        """Regression: when the only live slot needs pages and the rest of
        the pool is held by a PAUSED (partially-evicted) victim, capacity
        preemption must be able to whole-seal the paused slot (tail blob
        grafted along) rather than wedge — and both requests still finish
        byte-identically."""
        cfg, model, params = small_model
        pa = np.arange(1, 9, dtype=np.int32)
        pb = np.arange(11, 19, dtype=np.int32)
        refs = [make_engine(model, params, max_slots=1).generate(
                    G(p, 20)).tokens for p in (pa, pb)]
        eng = sharing_engine(model, params, max_slots=2, num_pages=4,
                             trust_domain=TrustDomain("tdx"))
        a = eng.submit(G(pa, 20))
        for _ in range(10):
            eng.step()          # a grows to 3 of 4 pages
        b = eng.submit(G(pb, 20, priority=5))   # partial-evicts a, then
        eng.run(max_steps=3000)                 # grows past the pool itself
        assert a.finished and b.finished
        assert [a.output, b.output] == refs
        assert a.n_preemptions >= 2             # partial, then whole-sealed
        assert not eng._paused and not eng._preempted
        assert eng.kv.free_physical_pages == eng.kv.num_pages

    def test_capacity_preemption_under_page_pressure(self, small_model):
        """On-demand pool runs dry mid-decode: the engine frees pages by
        evicting the laxest victim instead of failing the append, and
        every request still finishes with exact tokens."""
        cfg, model, params = small_model
        ref_eng = make_engine(model, params, max_slots=1)
        refs = [ref_eng.generate(G(np.arange(1 + i, 9 + i, dtype=np.int32),
                                   max_new_tokens=12)).tokens
                for i in range(3)]
        eng = sharing_engine(model, params, max_slots=3, num_pages=5)
        # 3 slots x (1 prompt page + appends past it) > 5 pages
        reqs = [eng.submit(G(np.arange(1 + i, 9 + i, dtype=np.int32),
                             max_new_tokens=12)) for i in range(3)]
        eng.run(max_steps=2000)
        assert [r.output for r in reqs] == refs
        assert sum(r.n_preemptions for r in reqs) > 0, \
            "page pressure never forced a capacity preemption"
        assert eng.kv.free_physical_pages == eng.kv.num_pages