"""Paged KV backend: slot-vs-paged output parity (incl. across sealed
preemption), page-granular seal/restore round trips, partial eviction,
page-table reuse after free, tampered-page MAC failure, and page-charged
admission accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.core.sealing import IntegrityError, _nonce_for, sealed_nbytes
from repro.models import build_model
from repro.runtime import Engine, GenerationRequest, SamplingParams
from repro.runtime.kvcache import make_backend
from repro.runtime.paged import PagedKVBackend


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


PROMPT = np.arange(1, 9, dtype=np.int32)


def G(prompt=PROMPT, max_new_tokens=8, **kw):
    return GenerationRequest(prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=max_new_tokens, **kw)


def make_engine(model, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_len", 8)
    return Engine(model, params, **kw)


def paged_engine(model, params, **kw):
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 8)
    return make_engine(model, params, **kw)


class TestBackendConstruction:
    def test_factory_and_flags(self, small_model):
        cfg, model, params = small_model
        assert make_engine(model, params).kv.name == "slot"
        assert paged_engine(model, params).kv.name == "paged"
        with pytest.raises(ValueError, match="kv backend"):
            make_engine(model, params, kv_backend="vllm")
        with pytest.raises(ValueError, match="multiple"):
            paged_engine(model, params, page_size=7)   # 64 % 7 != 0
        with pytest.raises(ValueError, match="page_size"):
            paged_engine(model, params, page_size=0)

    def test_backend_direct(self, small_model):
        cfg, model, params = small_model
        be = make_backend("paged", model, max_slots=2, max_len=64, page_size=8)
        assert isinstance(be, PagedKVBackend)
        assert be.max_pages == 8 and be.num_pages == 16
        assert be.pages_for(1) == 1 and be.pages_for(8) == 1
        assert be.pages_for(9) == 2
        assert be.free_physical_pages == 16
        # the paged pool's footprint matches the dense cache (+1 null page
        # per paged leaf)
        dense = make_backend("slot", model, max_slots=2, max_len=64)
        assert be.cache_nbytes() >= dense.cache_nbytes()


class TestParity:
    def test_greedy_outputs_identical(self, small_model):
        cfg, model, params = small_model
        prompts = [PROMPT, np.arange(9, 1, -1, dtype=np.int32),
                   np.arange(1, 21, dtype=np.int32)]    # incl. chunked tail
        slot_eng = make_engine(model, params, max_slots=3)
        paged_eng = paged_engine(model, params, max_slots=3)
        a = [slot_eng.submit(G(p, 6)) for p in prompts]
        b = [paged_eng.submit(G(p, 6)) for p in prompts]
        slot_eng.run()
        paged_eng.run()
        assert [r.output for r in a] == [r.output for r in b]

    def test_seeded_outputs_identical_across_preemption(self, small_model):
        """Acceptance: the same seeded sampled request, preempted mid-flight
        on each backend, reproduces byte-identical tokens — the layout (and
        its sealing granularity) is invisible to the math."""
        cfg, model, params = small_model
        sp = SamplingParams(temperature=0.9, top_k=16, seed=42)
        outs = []
        for backend in ("slot", "paged"):
            eng = make_engine(model, params, max_slots=1, kv_backend=backend,
                              page_size=8, trust_domain=TrustDomain("tdx"))
            low = eng.submit(G(max_new_tokens=10, params=sp, priority=0))
            for _ in range(3):
                eng.step()
            eng.submit(G(np.full(8, 7, np.int32), max_new_tokens=3,
                         priority=9))
            eng.run()
            assert low.n_preemptions == 1
            outs.append(low.output)
        assert outs[0] == outs[1]
        assert len(outs[0]) == 10

    @pytest.mark.slow
    def test_long_context_parity(self, small_model):
        """Long-context mix across both backends: chunked prefill tails,
        multi-page sequences, and a forced preemption all preserve parity."""
        cfg, model, params = small_model
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (70, 150, 230)]
        outs = []
        for backend in ("slot", "paged"):
            eng = Engine(model, params, max_slots=2, max_len=512,
                         prefill_buckets=(32, 64, 128), kv_backend=backend,
                         page_size=32, trust_domain=TrustDomain("tdx"))
            reqs = [eng.submit(G(p, 12, priority=0)) for p in prompts]
            for _ in range(3):
                eng.step()
            eng.submit(G(np.full(16, 5, np.int32), max_new_tokens=4,
                         priority=9))   # forces a sealed eviction
            eng.run(max_steps=50_000)
            assert all(r.finished for r in reqs)
            outs.append([r.output for r in reqs])
        assert outs[0] == outs[1]


class TestPageGranularSealing:
    def test_sealed_bytes_proportional_to_tokens(self, small_model):
        """The same short preemption seals strictly fewer bytes on the paged
        backend (pages actually used) than slot-dense (whole max_len)."""
        cfg, model, params = small_model
        sizes = {}
        for backend in ("slot", "paged"):
            eng = make_engine(model, params, max_slots=1, kv_backend=backend,
                              page_size=8, trust_domain=TrustDomain("tdx"))
            eng.submit(G(max_new_tokens=10))
            eng.step()
            sealed, req = eng.seal_slot(0)
            sizes[backend] = sealed_nbytes(sealed)
            eng.restore_slot(sealed, req)
            eng.run()
            assert req.finished and len(req.output) == 10
            assert req.sealed_bytes == sizes[backend]
        assert sizes["paged"] < sizes["slot"]
        ch_ratio = sizes["slot"] / sizes["paged"]
        # 8 prompt tokens + a little decode = 2 pages of 8 vs max_len=64
        assert ch_ratio > 2

    def test_per_page_nonces_are_unique(self, small_model):
        """Every sealed page gets its own nonce (name), across leaves, page
        ordinals, and seal epochs."""
        cfg, model, params = small_model
        td = TrustDomain("tdx")
        eng = paged_engine(model, params, max_slots=1, trust_domain=td)
        req = eng.submit(G(max_new_tokens=12))
        for _ in range(2):
            eng.step()
        sealed1, evicted = eng.seal_slot(0)
        eng.restore_slot(sealed1, evicted)
        for _ in range(2):
            eng.step()
        sealed2, evicted = eng.seal_slot(0)
        names = list(sealed1) + list(sealed2)
        assert len(set(names)) == len(names)
        nonces = {_nonce_for(td.sealing_key, n) for n in names}
        assert len(nonces) == len(names)
        page_names = [n for n in sealed2 if "/p" in n]
        assert page_names, "paged seal must contain per-page entries"

    def test_tampered_page_fails_mac(self, small_model):
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=1,
                           trust_domain=TrustDomain("tdx"))
        req = eng.submit(G(max_new_tokens=6))
        eng.step()
        sealed, evicted = eng.seal_slot(0)
        victim = next(st for name, st in sealed.items() if "/p0" in name)
        ct = np.asarray(victim.ciphertext).copy()
        ct[0, 0] ^= 1
        victim.ciphertext = jnp.asarray(ct)
        with pytest.raises(IntegrityError, match="/p0"):
            eng.restore_slot(sealed, evicted)
        # the failed restore must not leak the slot or its page reservation
        assert eng.slots.num_active == 0
        assert eng.kv.free_page_reserve == eng.kv.num_pages

    def test_tampered_meta_fails_mac(self, small_model):
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=1,
                           trust_domain=TrustDomain("tdx"))
        eng.submit(G(max_new_tokens=6))
        eng.step()
        sealed, evicted = eng.seal_slot(0)
        meta = next(st for name, st in sealed.items() if name.endswith("/meta"))
        meta.mac = b"\x00" * 32
        with pytest.raises(IntegrityError, match="meta"):
            eng.restore_slot(sealed, evicted)


class TestPartialEviction:
    def test_partial_round_trip_preserves_output(self, small_model):
        """Seal the victim's tail pages, let the pool serve someone else,
        restore the delta, and the victim's tokens are unchanged."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=2, num_pages=8,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(max_new_tokens=10, priority=0))
        for _ in range(3):
            eng.step()                  # pos=11 -> 2 pages allocated
        assert eng.kv.allocated_pages(0) == 2
        free_before = eng.kv.free_physical_pages
        eng.partial_preempt(0, 1)
        assert eng.kv.allocated_pages(0) == 1
        assert eng.kv.free_physical_pages == free_before + 1
        assert low.n_preemptions == 1
        # the paused victim sits out of the batch but keeps its slot
        assert 0 in eng.scheduler.running
        eng.step()                      # resume restores the sealed delta
        eng.run()
        assert low.output == ref

    def test_partial_eviction_triggered_by_page_pressure(self, small_model):
        """A high-priority arrival that is short only on *pages* (a slot is
        free) partially evicts the victim's tail instead of sealing the
        whole slot."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=2, num_pages=8,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(max_new_tokens=10, priority=0))   # 3 pages reserved
        for _ in range(3):
            eng.step()                  # 2 pages physically allocated
        # needs 6 pages; only 5 unreserved -> shortfall of 1 page
        hi = eng.submit(G(np.full(8, 7, np.int32), max_new_tokens=41,
                          priority=5))
        eng.run(max_steps=300)
        assert hi.finished and low.finished
        assert low.output == ref
        partials = [e for e in eng.td.audit
                    if e.kind == "seal_kv" and "partial" in e.detail]
        assert len(partials) == 1
        restores = [e for e in eng.td.audit
                    if e.kind == "restore_kv" and "partial" in e.detail]
        assert len(restores) == 1

    def test_whole_seal_of_paused_slot_reassembles(self, small_model):
        """A partially-evicted slot can still be whole-sealed (so a yet
        higher-priority arrival is never stranded behind a paused victim):
        the resident remainder seals under a fresh epoch, the earlier tail
        blob rides along, and restore grafts both back."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=1, num_pages=8,
                           trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(max_new_tokens=10))
        for _ in range(3):
            eng.step()                  # 2 pages allocated
        eng.partial_preempt(0, 1)       # paused, 1 resident page
        sealed, evicted = eng.seal_slot(0)     # whole-seal while paused
        assert eng.slots.num_active == 0
        assert eng.kv.free_page_reserve == eng.kv.num_pages
        assert any(n.endswith("/pagemeta") for n in sealed)   # tail blob rode
        eng.restore_slot(sealed, evicted)
        assert eng.kv.allocated_pages(0) == 2   # remainder + grafted tail
        eng.run()
        assert low.output == ref

    @pytest.mark.slow
    def test_hybrid_model_pause_freezes_recurrent_state(self):
        """On a hybrid (mamba+attn) arch the paged backend must freeze a
        paused row's recurrent-state leaves while its slot-mates keep
        stepping — only rows that actually append may advance — or the
        victim would resume from corrupted SSM state."""
        cfg = smoke_config("jamba-v0.1-52b")
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        prompt = np.arange(1, 9, dtype=np.int32)
        eng = Engine(model, params, max_slots=2, max_len=64, prefill_len=8,
                     kv_backend="paged", page_size=8,
                     trust_domain=TrustDomain("tdx"))
        low = eng.submit(G(prompt, 10))
        mate = eng.submit(G(np.full(8, 3, np.int32), 10))
        for _ in range(3):
            eng.step()

        def state_rows(slot):
            rows = {}
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    eng.kv.blocks)[0]:
                k = jax.tree_util.keystr(path)
                if k not in eng.kv._paged_paths:
                    rows[k] = np.asarray(leaf[:, slot])
            return rows

        assert state_rows(0), "hybrid model must have recurrent-state leaves"
        before = state_rows(0)
        # a decode step slot 0 sits out of (write_slots excludes it — what
        # the engine passes while a slot is paused) must leave its
        # recurrent-state rows bit-identical, while the stepping mate's move
        eng.kv.decode(eng.params, eng._last_token, None, 0, write_slots=[1])
        after = state_rows(0)
        for k in before:
            np.testing.assert_array_equal(before[k], after[k], err_msg=k)
        eng.run()                       # both finish normally afterwards
        assert low.finished and mate.finished

    def test_paused_victim_is_not_stranded_capacity(self, small_model):
        """An even-higher-priority arrival can whole-seal a paused victim
        (partial tail + resident remainder both travel), so a paused slot
        never wedges the pool: everyone eventually finishes and the twice-
        evicted victim's tokens are exact."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            G(max_new_tokens=10)).tokens
        eng = paged_engine(model, params, max_slots=3, num_pages=8,
                           trust_domain=TrustDomain("tdx"))
        a = eng.submit(G(max_new_tokens=10, priority=0))     # 3 pages
        for _ in range(3):
            eng.step()                  # a allocates its 2nd page
        b = eng.submit(G(np.full(8, 7, np.int32), max_new_tokens=41,
                         priority=5))   # 6 pages -> partial-evicts a
        eng.step()
        assert 0 in eng._paused and a.n_preemptions == 1
        c = eng.submit(G(np.full(8, 9, np.int32), max_new_tokens=9,
                         priority=9))   # 2 pages -> must whole-seal paused a
        eng.run(max_steps=500)
        assert a.finished and b.finished and c.finished
        assert a.n_preemptions == 2     # partial, then whole while paused
        assert a.output == ref
        assert not eng._paused and not eng._preempted
        assert eng.kv.free_page_reserve == eng.kv.num_pages

    def test_partial_preempt_rejects_bad_usage(self, small_model):
        cfg, model, params = small_model
        slot_eng = make_engine(model, params, max_slots=1)
        slot_eng.submit(G(max_new_tokens=6))
        slot_eng.step()
        with pytest.raises(RuntimeError, match="page granularity"):
            slot_eng.partial_preempt(0, 1)
        eng = paged_engine(model, params, max_slots=1, page_size=32)
        eng.submit(G(max_new_tokens=6))
        eng.step()                       # 1 page allocated: no strict subset
        assert eng.kv.allocated_pages(0) == 1
        with pytest.raises(ValueError, match="partial eviction"):
            eng.partial_preempt(0, 1)


class TestPageAccounting:
    def test_pages_released_and_reused_after_free(self, small_model):
        """Slots churn through the pool: every page returns to the free list
        when its sequence finishes, and later sequences reuse the same
        physical pages through fresh table entries."""
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=2, num_pages=8)
        first = eng.submit(G(max_new_tokens=6))
        eng.run()
        assert first.finished
        assert eng.kv.free_physical_pages == 8
        assert eng.kv.free_page_reserve == 8
        used_before = set()
        # serve more sequential waves than the pool could hold at once
        refs = []
        for i in range(4):
            req = eng.submit(G(np.full(8, i + 1, np.int32), max_new_tokens=6))
            eng.step()
            used_before |= {int(p) for p in eng.kv.table[:, :2].ravel() if p}
            eng.run()
            refs.append(req)
        assert all(r.finished and len(r.output) == 6 for r in refs)
        assert eng.kv.free_physical_pages == 8
        assert (eng.kv.table == 0).all()          # fully unmapped when idle
        assert len(used_before) < 8 * 4           # pages were reused

    def test_admission_charges_pages_not_max_len(self, small_model):
        """Two requests each reserving >half the pool serialize on pages
        even though slots are free — and both finish (reservation-based
        accounting cannot deadlock appends)."""
        cfg, model, params = small_model
        eng = paged_engine(model, params, max_slots=2, num_pages=8)
        a = eng.submit(G(max_new_tokens=33))   # need 8+32=40 -> 5 pages
        b = eng.submit(G(np.full(8, 3, np.int32), max_new_tokens=33))
        eng.step()
        assert len(eng.scheduler.running) == 1    # b is page-gated
        assert eng.kv.free_page_reserve == 3
        eng.run(max_steps=500)
        assert a.finished and b.finished
        assert a.t_done <= b.t_done

    def test_prompt_budget_and_capacity_reflect_pool(self, small_model):
        cfg, model, params = small_model
        slot_eng = make_engine(model, params, max_len=64)
        tiny = paged_engine(model, params, max_len=64, num_pages=4)
        assert tiny.kv.request_capacity == 32
        assert slot_eng.prompt_budget(16) > tiny.prompt_budget(16)
        assert tiny.prompt_budget(16) == 32 - 16 + 1
        with pytest.raises(ValueError, match="KV positions"):
            tiny.submit(G(np.ones(30, np.int32), 16))
        tiny.submit(G(np.ones(tiny.prompt_budget(16), np.int32), 16))
        tiny.run()