"""RAG substrate: BM25 properties, dense retrieval, confidential pipeline."""

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import TrustDomain
from repro.data.pipeline import synthetic_text
from repro.rag.bm25 import BM25Index, tokenize
from repro.rag.pipeline import RAGPipeline


@pytest.fixture(scope="module")
def corpus():
    docs = {f"d{i}": synthetic_text(i, 5) for i in range(15)}
    docs["needle"] = ("confidential enclave attestation protects llama "
                      "inference throughput inside trusted hardware")
    return docs


class TestBM25:
    def test_relevant_doc_ranks_first(self, corpus):
        idx = BM25Index().build(corpus)
        hits = idx.search("confidential enclave attestation llama", top_k=3)
        assert hits[0][0] == "needle"

    def test_scores_nonnegative_and_sorted(self, corpus):
        idx = BM25Index().build(corpus)
        hits = idx.search("inference token decode", top_k=10)
        scores = [s for _, s in hits]
        assert scores == sorted(scores, reverse=True)
        assert all(s >= 0 for s in scores)

    @given(reps=st.integers(1, 6))
    @settings(max_examples=8, deadline=None)
    def test_tf_monotonicity_property(self, reps):
        """More occurrences of the query term -> higher score (same length
        padding keeps the length normalization comparable)."""
        filler = "alpha beta gamma delta"
        idx = BM25Index()
        idx.add("lo", ("zebra " * 1 + filler * 10).strip())
        idx.add("hi", ("zebra " * (1 + reps) + filler * 10).strip())
        s_lo = idx.score("zebra", 0)
        s_hi = idx.score("zebra", 1)
        assert s_hi > s_lo

    def test_tokenize(self):
        assert tokenize("Hello, World! 42x") == ["hello", "world", "42x"]


class TestPipelineModes:
    @pytest.mark.parametrize("mode", ["bm25", "bm25+rerank", "dense"])
    def test_mode_runs_confidentially(self, corpus, mode):
        p = RAGPipeline(corpus, mode=mode, trust_domain=TrustDomain("tdx"))
        r = p.query("confidential enclave attestation llama")
        assert len(r.retrieved) > 0
        assert r.retrieval_s >= 0
        if mode != "dense":  # dense uses a random-init encoder: rank varies
            assert r.retrieved[0][0] == "needle"

    def test_plain_vs_confidential_same_results(self, corpus):
        plain = RAGPipeline(corpus, mode="bm25", trust_domain=TrustDomain("none"))
        conf = RAGPipeline(corpus, mode="bm25", trust_domain=TrustDomain("sgx"))
        q = "inference throughput enclave"
        assert plain.retrieve(q, 5) == conf.retrieve(q, 5)
