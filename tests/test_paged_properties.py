"""Property-based pool invariants for the prefix-sharing paged backend.

Random serving plans — allocate / share / copy-on-write / free / seal /
restore sequences arising from random prompts (drawn from a small alphabet
of patterns so prefixes genuinely collide), random priorities (forced
whole- and partial-slot preemptions), per-request sharing opt-outs, and a
deliberately tight on-demand pool (capacity preemption) — must never leak
a page, never double-free, never map the null scratch page, and must keep
every refcount equal to its page's number of live table mappings
(conftest.check_pool_invariants, asserted after every engine step).

Skips cleanly offline: ``hypothesis`` is imported through tests/_hypo.py.

The module-scope engine is deliberately reused across examples (each
example drains to idle and asserts the pool returns to a pristine state,
so accumulated history only strengthens the property); a failing example
may therefore shrink against inherited index state.
"""

import jax
import numpy as np
import pytest
from _hypo import given, settings, st

from conftest import check_pool_invariants, make_sharing_engine
from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.models import build_model
from repro.runtime import Engine, GenerationRequest, SamplingParams

P8 = np.arange(1, 9, dtype=np.int32)
P4 = np.arange(1, 5, dtype=np.int32)
P12 = np.arange(1, 13, dtype=np.int32)
PATTERNS = [P8, P8, P4, P12]        # duplicates make sharing likely


@pytest.fixture(scope="module")
def model_params():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def sharing_engine(model_params):
    model, params = model_params
    # pool of 8 < 3 slots x 3 worst-case pages: capacity preemption fires
    return make_sharing_engine(model, params, max_slots=3,
                               prefill_buckets=(4, 8), num_pages=8)


@pytest.fixture(scope="module")
def permutation_refs(model_params):
    """Solo ground truth for the three seeded sharers the permutation
    property reuses across examples."""
    model, params = model_params
    refs = []
    for i in range(3):
        eng = Engine(model, params, max_slots=1, max_len=64,
                     prefill_buckets=(4, 8))
        refs.append(eng.generate(GenerationRequest(
            prompt=P8.copy(), max_new_tokens=6,
            params=SamplingParams(temperature=0.9, top_k=8,
                                  seed=40 + i))).tokens)
    return refs


def _drain(eng, max_steps=4000):
    steps = 0
    while not eng.idle:
        eng.step()
        check_pool_invariants(eng.kv)
        steps += 1
        assert steps < max_steps, "serving plan failed to drain"


def _assert_pristine(kv):
    """An idle engine's pool carries no residue: all pages free, nothing
    indexed, parked, or sealed-referenced, every refcount zero."""
    assert kv.free_physical_pages == kv.num_pages
    assert (kv.table == 0).all()
    assert int(kv._page_ref.sum()) == 0
    assert not kv._index and not kv._page_key
    assert not kv._parked and not kv._sealed_refs


# four DISTINCT full-page contents for the store properties (the pool
# patterns above mostly collide on purpose; here eviction needs variety)
STORE_PATTERNS = [P8, P8 + 8, P8 + 16, P8 + 24]


@pytest.fixture(scope="module")
def store_engine(model_params):
    """Tight pool AND tight store: 4 recurring distinct pages over a
    2-page retention budget force publish/hit/evict churn on top of the
    park/remat churn the small pool already drives."""
    model, params = model_params
    return make_sharing_engine(model, params, max_slots=3,
                               prefill_buckets=(4, 8), num_pages=8,
                               page_store=True, store_budget_pages=2)


class TestPageStoreProperties:
    @given(plan=st.lists(
        st.tuples(st.integers(0, 3),      # which distinct full-page prompt
                  st.integers(1, 5),      # max_new_tokens
                  st.integers(0, 5),      # priority (forces park/remat)
                  st.integers(0, 2)),     # engine steps after submit
        min_size=1, max_size=10))
    @settings(max_examples=12, deadline=None)
    def test_random_store_churn_never_breaks_budget_or_pool(
            self, store_engine, plan):
        """Random publish/hit/evict/park/remat interleavings: the store
        never exceeds its page budget, the pool never leaks or
        double-frees, and every example drains back to a pristine pool
        (store residency, by design, survives the drain)."""
        eng = store_engine
        store = eng.kv.page_store
        for pat, mnt, prio, steps in plan:
            eng.submit(GenerationRequest(
                prompt=STORE_PATTERNS[pat].copy(), max_new_tokens=mnt,
                priority=prio,
                params=SamplingParams(temperature=0.9, top_k=8,
                                      seed=pat * 11 + mnt)))
            for _ in range(steps):
                eng.step()
                check_pool_invariants(eng.kv)
                assert store.resident_pages <= store.budget_pages
        _drain(eng)
        _assert_pristine(eng.kv)
        assert store.resident_pages <= store.budget_pages

    def test_store_hit_restores_published_bytes_exactly(self, store_engine):
        """Anchor (runs regardless of hypothesis): the plaintext a store
        hit lands in the pool is byte-identical to what the publisher
        sealed — through however much churn the store has seen."""
        from repro.core.sealing import unseal_tensor
        eng = store_engine
        store = eng.kv.page_store
        skey = eng.td.sealing_key
        eng.generate(GenerationRequest(
            prompt=P8.copy(), max_new_tokens=4,
            params=SamplingParams(temperature=0.9, top_k=8, seed=77)))
        (key,) = eng.kv.page_keys(P8, len(P8))
        assert store.contains(skey, key)
        expected = {kp: np.asarray(unseal_tensor(skey, blob))
                    for kp, blob in store.lookup(skey, key).items()}
        eng.submit(GenerationRequest(
            prompt=P8.copy(), max_new_tokens=4,
            params=SamplingParams(temperature=0.9, top_k=8, seed=78)))
        eng.step()
        hits0 = eng.kv.store_hits
        assert hits0 >= 1
        phys = eng.kv._index[key]
        pages = eng.kv._page_arrays([phys])
        for kp, want in expected.items():
            np.testing.assert_array_equal(np.asarray(pages[kp][:, 0]), want)
        _drain(eng)
        _assert_pristine(eng.kv)


class TestPoolInvariantProperties:
    @given(plan=st.lists(
        st.tuples(st.integers(0, 3),      # prompt pattern
                  st.integers(1, 6),      # max_new_tokens
                  st.integers(0, 5),      # priority (forces preemption)
                  st.booleans(),          # share_prefix opt-out
                  st.integers(0, 2)),     # engine steps after submit
        min_size=1, max_size=8))
    @settings(max_examples=12, deadline=None)
    def test_random_serving_never_corrupts_pool(self, sharing_engine, plan):
        eng = sharing_engine
        for pat, mnt, prio, share, steps in plan:
            eng.submit(GenerationRequest(
                prompt=PATTERNS[pat].copy(), max_new_tokens=mnt,
                priority=prio, share_prefix=share,
                params=SamplingParams(temperature=0.9, top_k=8,
                                      seed=pat * 7 + mnt)))
            for _ in range(steps):
                eng.step()
                check_pool_invariants(eng.kv)
        _drain(eng)
        _assert_pristine(eng.kv)

    @given(order=st.permutations(range(3)), presteps=st.integers(1, 3))
    @settings(max_examples=10, deadline=None)
    def test_seal_restore_permutations_are_exact(self, sharing_engine,
                                                 permutation_refs, order,
                                                 presteps):
        """Three sharers of one prompt page, all sealed out, restored in an
        arbitrary order: every interleaving of re-link / park /
        re-materialize must keep the invariants and reproduce each
        request's solo tokens byte for byte."""
        eng = sharing_engine
        sp = [SamplingParams(temperature=0.9, top_k=8, seed=40 + i)
              for i in range(3)]
        reqs = [eng.submit(GenerationRequest(prompt=P8.copy(),
                                             max_new_tokens=6, params=sp[i]))
                for i in range(3)]
        for _ in range(presteps):
            eng.step()
            check_pool_invariants(eng.kv)
        sealed = {}
        for slot in list(eng.scheduler.running):
            sealed[slot] = eng.seal_slot(slot)
            check_pool_invariants(eng.kv)
        for slot in order:
            if slot in sealed:
                eng.restore_slot(*sealed[slot])
                check_pool_invariants(eng.kv)
        _drain(eng)
        _assert_pristine(eng.kv)
        for r, ref in zip(reqs, permutation_refs):
            assert r.finished and r.output == ref

    def test_reference_outputs_unchanged_by_property_churn(
            self, sharing_engine, model_params):
        """Anchor (runs regardless of hypothesis): after arbitrary churn the
        engine still reproduces a solo reference byte-for-byte."""
        eng = sharing_engine
        model, params = model_params
        sp = SamplingParams(temperature=0.9, top_k=8, seed=99)
        out = eng.generate(GenerationRequest(prompt=P8.copy(),
                                             max_new_tokens=8, params=sp))
        ref = Engine(model, params, max_slots=1, max_len=64,
                     prefill_buckets=(4, 8)).generate(
            GenerationRequest(prompt=P8.copy(), max_new_tokens=8,
                              params=sp)).tokens
        assert out.tokens == ref
        _assert_pristine(eng.kv)
