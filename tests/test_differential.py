"""Cross-backend differential harness + adversarial shared-page sealing.

ONE canonical serving scenario (tests/conftest.py: mixed priorities, forced
sealed preemption, seeded sampling, chunked prefill, shared prefixes with a
partial CoW page) is replayed over every backend configuration — slot,
paged, paged+prefix-sharing, and an in-process dp=2 sharded mesh — and each
replay must reproduce, byte for byte, the tokens each request produces when
served alone on an uncontended engine. The layout, allocator, sharing, and
sharding machinery must all be invisible to the decoded math; what may
differ (and is asserted to differ, in the right direction) is memory and
sealed-boundary traffic.

The adversarial half targets the refcount-aware sealing of shared pages:
tampered parked ciphertext or shared-keys MACs must fail the restore of
*every* referencing request without leaking slots, pages, or refcounts, and
re-linked restores must never mint (or reuse) a sealing nonce.
"""

import jax
import numpy as np
import pytest

from conftest import (CANONICAL_CONFIGS, burst_requests, canonical_requests,
                      check_pool_invariants, make_sharing_engine,
                      run_burst_scenario, run_canonical_scenario, _gen)
from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.core.sealing import IntegrityError
from repro.models import build_model
from repro.runtime import (Engine, GenerationRequest, SamplingParams,
                           ShardedKVBackend)


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def scenario_runs(small_model):
    """Each configuration's scenario result, computed once per module:
    name -> (outputs, engine, trust domain)."""
    cfg, model, params = small_model
    cache = {}

    def get(name):
        if name not in cache:
            cache[name] = run_canonical_scenario(
                model, params, **CANONICAL_CONFIGS[name])
        return cache[name]
    return get


@pytest.fixture(scope="module")
def solo_reference(small_model):
    """Every canonical request served alone on an uncontended single-slot
    slot-dense engine: the ground truth any batched/paged/shared/sharded
    replay must reproduce byte for byte."""
    cfg, model, params = small_model
    low, high = canonical_requests()
    refs = []
    for spec in low + high:
        eng = Engine(model, params, max_slots=1, max_len=64,
                     prefill_buckets=(4, 8))
        refs.append(eng.generate(_gen(spec)).tokens)
    return refs


class TestDifferentialHarness:
    def test_outputs_match_solo_reference(self, backend_config, scenario_runs,
                                          solo_reference):
        """Acceptance: each backend configuration reproduces the solo
        ground truth byte-for-byte across batching, preemption, sealed
        restore, sharing, and sharding — and leaves a structurally sound
        page pool behind."""
        name, _ = backend_config
        outputs, eng, _ = scenario_runs(name)
        assert outputs == solo_reference, f"{name} diverged from solo runs"
        check_pool_invariants(eng.kv)

    def test_all_configs_byte_identical(self, scenario_runs):
        outs = {name: scenario_runs(name)[0] for name in CANONICAL_CONFIGS}
        base = outs.pop("slot")
        for name, o in outs.items():
            assert o == base, f"{name} != slot outputs"

    def test_rerun_byte_identical(self, small_model, scenario_runs):
        """Acceptance: replaying the same configuration in the same process
        reproduces the same bytes. Guards the ``host_upload`` copy-on-upload
        rule (runtime/kvcache.py): ``jnp.asarray`` may zero-copy a host
        numpy buffer at whatever alignment malloc handed out, and XLA:CPU
        kernels take alignment-dependent code paths whose FMA grouping
        differs in the last ulp — enough to flip a near-tie sampled token
        between otherwise identical runs (the parity tests' historical
        flake). Two reruns keep the catch probability meaningful: the
        alignment draw is per-allocation, so a regression flips roughly
        every other run, not every run."""
        cfg, model, params = small_model
        first = scenario_runs("slot")[0]
        for _ in range(2):
            again, _, _ = run_canonical_scenario(model, params,
                                                 **CANONICAL_CONFIGS["slot"])
            assert again == first, \
                "identical rerun diverged (nondeterministic serve)"

    def test_paged_seals_fewer_bytes_than_slot(self, scenario_runs):
        """Insight-10 ordering on the same preemption pattern: per-page
        sealing moves strictly fewer bytes than whole-slot sealing."""
        _, _, td_slot = scenario_runs("slot")
        _, _, td_paged = scenario_runs("paged")
        a, b = td_slot.channel.stats, td_paged.channel.stats
        assert a.seal_events > 0 and b.seal_events > 0
        assert b.seal_bytes < a.seal_bytes

    def test_sharing_shares_pages_and_copies_on_write(self, scenario_runs):
        """The sharing replay actually shares (requests 0/1 have identical
        prompts; request 2 shares their head in the partial small bucket)
        and the partial page's first divergent append copies-on-write."""
        _, eng_plain, td_plain = scenario_runs("paged")
        _, eng_share, td = scenario_runs("paged-sharing")
        assert eng_share.kv.shared_page_maps > 0
        assert eng_share.kv.cow_copies > 0
        assert eng_share.kv.pages_written < eng_plain.kv.pages_written
        assert (td.channel.stats.seal_bytes
                <= td_plain.channel.stats.seal_bytes)

    def test_sharded_dp2_really_spans_the_mesh(self, scenario_runs):
        """The dp=2 replay is not a degenerate single-device run: the
        wrapped backend seals per shard and the engine measured real
        collective traffic between the two devices."""
        _, eng, td = scenario_runs("sharded-dp2")
        assert isinstance(eng.kv, ShardedKVBackend)
        assert eng.plan.dp == 2
        ch = td.channel.stats
        assert ch.collective_steps > 0
        assert ch.collective_bytes > 0


TWO_PHASE_CONFIGS = ("slot-cb", "paged-cb", "slot-2plan", "paged-2plan")


class TestTwoPhaseServing:
    """Step-level continuous batching and disaggregated prefill under a
    burst of long prompts: decoded bytes must be untouched, the sealed
    plan-to-plan handoff must be priced, and interleaved prefill must
    actually improve short-request admission latency."""

    @pytest.fixture(scope="class")
    def burst_solo(self, small_model):
        cfg, model, params = small_model
        return [Engine(model, params, max_slots=1, max_len=64,
                       prefill_buckets=(4, 8)).generate(_gen(s)).tokens
                for s in burst_requests()]

    @pytest.mark.parametrize("name", ("slot",) + TWO_PHASE_CONFIGS)
    def test_burst_byte_identical_to_solo(self, small_model, burst_solo,
                                          name):
        cfg, model, params = small_model
        outs, eng, td = run_burst_scenario(model, params,
                                           **CANONICAL_CONFIGS[name])
        assert outs == burst_solo, f"{name} diverged on the long-prompt burst"
        check_pool_invariants(eng.kv)

    @pytest.mark.parametrize("name", ("slot-2plan", "paged-2plan"))
    def test_handoff_priced_in_sealed_bytes(self, small_model, name):
        """Every disaggregated request crosses the plan boundary exactly
        once, and the crossing lands in ChannelStats sealed traffic —
        the disaggregation boundary is accounted like a preemption."""
        cfg, model, params = small_model
        outs, eng, td = run_burst_scenario(model, params,
                                           **CANONICAL_CONFIGS[name])
        st = eng.scheduler.stats()
        assert st.handoffs == len(outs)
        assert st.handoff_bytes > 0
        ch = td.channel.stats
        assert ch.seal_events >= st.handoffs
        assert ch.seal_bytes >= st.handoff_bytes
        assert ch.restore_bytes >= st.handoff_bytes

    def test_interleaved_prefill_admits_short_before_long(self, small_model):
        """TTFT regression: with continuous batching, a short request backs
        into the leftover step budget while a long prefill is still blocked
        on it — under bucket-batched admission the long (earlier) request
        would have claimed the slot first."""
        cfg, model, params = small_model
        eng = Engine(model, params, max_slots=2, max_len=64,
                     prefill_buckets=(4, 8), continuous_batching=True,
                     step_tokens=8)
        filler = eng.submit(_gen((np.arange(1, 5, dtype=np.int32), 8, 0, 400)))
        eng.step()   # filler occupies one slot -> next step's budget is 7
        long = eng.submit(_gen((np.arange(1, 13, dtype=np.int32), 6, 0, 401)))
        short = eng.submit(_gen((np.arange(1, 4, dtype=np.int32), 5, 0, 402)))
        eng.step()
        running = list(eng.scheduler.running.values())
        assert short in running, "short request should backfill the budget"
        assert long not in running, \
            "the long prefill (bucket 8 > budget 7) must wait for fresh budget"
        assert short.backfilled and eng.backfills >= 1
        eng.run(max_steps=50_000)
        for req, spec in ((filler, (np.arange(1, 5, dtype=np.int32), 8, 0, 400)),
                          (long, (np.arange(1, 13, dtype=np.int32), 6, 0, 401)),
                          (short, (np.arange(1, 4, dtype=np.int32), 5, 0, 402))):
            ref = Engine(model, params, max_slots=1, max_len=64,
                         prefill_buckets=(4, 8)).generate(_gen(spec)).tokens
            assert list(req.output) == ref, "backfill changed decoded bytes"

    def test_phase_lifecycle_and_backfill_stats(self, small_model):
        cfg, model, params = small_model
        outs, eng, _ = run_burst_scenario(
            model, params, **CANONICAL_CONFIGS["slot-2plan"])
        assert all(r.phase == "done" for r in eng.scheduler.finished)
        st = eng.scheduler.stats()
        assert st.backfilled_requests == 0   # no budget in two-plan mode


PROMPT = np.arange(1, 9, dtype=np.int32)


def sharer(seed, n=10, prio=0):
    return GenerationRequest(
        prompt=PROMPT.copy(), max_new_tokens=n, priority=prio,
        params=SamplingParams(temperature=0.9, top_k=16, seed=seed))


def seal_both_sharers(model, params, **kw):
    """Two requests sharing their whole prompt page, both sealed out: the
    first seal leaves the page resident (the mate still maps it), the
    second drops the last live reference and parks the page content-named.
    Returns (engine, [(sealed, req), ...], parked key)."""
    eng = make_sharing_engine(model, params, **kw)
    a, b = eng.submit(sharer(1)), eng.submit(sharer(2))
    for _ in range(2):
        eng.step()
    sealed_a = eng.seal_slot(0)
    assert not eng.kv._parked, "page must stay resident while the mate lives"
    sealed_b = eng.seal_slot(1)
    assert len(eng.kv._parked) == 1, "last reference drop must park the page"
    (key,) = eng.kv._parked
    assert eng.kv._sealed_refs[key] == 2
    return eng, [sealed_a, sealed_b], key


class TestSharedPageAdversarial:
    def test_tampered_parked_page_fails_every_referencing_restore(
            self, small_model):
        """Flip one ciphertext bit of the parked shared page: EVERY sealed
        request referencing it must fail restore with an integrity error,
        and none of the failures may leak a slot, a page, or a refcount."""
        cfg, model, params = small_model
        eng, sealed_reqs, key = seal_both_sharers(model, params)
        blob = next(iter(eng.kv._parked[key].values()))
        ct = np.asarray(blob.ciphertext).copy()
        ct[0, 0] ^= 1
        blob.ciphertext = jax.numpy.asarray(ct)
        for sealed, req in sealed_reqs:
            with pytest.raises(IntegrityError):
                eng.restore_slot(sealed, req)
            assert eng.slots.num_active == 0
            assert eng.kv.free_physical_pages == eng.kv.num_pages
            check_pool_invariants(eng.kv)

    def test_tampered_sharedkeys_mac_fails_without_leak(self, small_model):
        cfg, model, params = small_model
        eng, sealed_reqs, _ = seal_both_sharers(model, params)
        sealed, req = sealed_reqs[0]
        keys_blob = next(st for name, st in sealed.items()
                         if "/sharedkeys" in name)
        keys_blob.mac = b"\x00" * 32
        with pytest.raises(IntegrityError, match="sharedkeys"):
            eng.restore_slot(sealed, req)
        assert eng.slots.num_active == 0
        assert eng.kv.free_physical_pages == eng.kv.num_pages
        check_pool_invariants(eng.kv)
        # the untampered co-referencer still restores and finishes exactly
        other_sealed, other_req = sealed_reqs[1]
        eng.restore_slot(other_sealed, other_req)
        eng.run()
        ref = Engine(model, params, max_slots=1, max_len=64,
                     prefill_len=8).generate(sharer(2)).tokens
        assert other_req.output == ref

    def test_relinked_restore_mints_no_new_nonce(self, small_model):
        """A restore that re-links a resident shared page seals nothing:
        the audit shows no new seal event, the sealed-name universe gains
        no entry, and every name ever sealed is either unique or (content-
        named) carries the byte-identical ciphertext — one nonce never
        covers two plaintexts."""
        cfg, model, params = small_model
        td = TrustDomain("tdx")
        eng = make_sharing_engine(model, params, trust_domain=td)
        a, b = eng.submit(sharer(1)), eng.submit(sharer(2, n=20))
        for _ in range(2):
            eng.step()
        sealed_a, req_a = eng.seal_slot(0)
        seen = {name: bytes(np.asarray(st.ciphertext).tobytes())
                for name, st in sealed_a.items()}
        seals_before = sum(1 for e in td.audit if e.kind == "seal_kv")
        eng.restore_slot(sealed_a, req_a)       # re-link: the mate is live
        assert sum(1 for e in td.audit
                   if e.kind == "seal_kv") == seals_before
        # second eviction epoch: every fresh name is new; a repeated
        # content-derived name must carry identical ciphertext
        for _ in range(2):
            eng.step()
        sealed_a2, req_a2 = eng.seal_slot(0)
        for name, st in sealed_a2.items():
            ct = bytes(np.asarray(st.ciphertext).tobytes())
            assert name not in seen or seen[name] == ct, \
                f"nonce {name} reused with different plaintext"
            seen[name] = ct
        eng.restore_slot(sealed_a2, req_a2)
        eng.run()
        ref = Engine(model, params, max_slots=1, max_len=64,
                     prefill_len=8).generate(sharer(1)).tokens
        assert a.output == ref

    def test_discard_sealed_releases_shared_refs(self, small_model):
        """Dropping a sealed request unrestored (the deadline-abort path)
        releases its shared references; parked ciphertext dies with its
        last reader instead of accumulating."""
        cfg, model, params = small_model
        eng, sealed_reqs, key = seal_both_sharers(model, params)
        for sealed, req in sealed_reqs:
            eng.kv.discard_sealed(
                eng.td.sealing_key, sealed,
                f"kvslot/{req.stream_id}/{req.seal_epoch - 1}")
        assert not eng.kv._sealed_refs and not eng.kv._parked
        check_pool_invariants(eng.kv)

    def test_store_publish_on_release_then_hit_is_byte_identical(
            self, small_model):
        """The persistent-store happy path: a finished request's full prompt
        page is published (ciphertext, content-named) when its last
        reference drops, and an identical later request restores it from
        the store — MAC-verified — producing byte-identical output."""
        cfg, model, params = small_model
        eng = make_sharing_engine(model, params, page_store=True)
        store = eng.kv.page_store
        a = eng.submit(sharer(1))
        eng.run()
        assert store.publishes >= 1, "release must publish the full page"
        assert eng.kv.store_hits == 0
        b = eng.submit(sharer(1))
        eng.run()
        assert eng.kv.store_hits >= 1, "recurring prompt must hit the store"
        assert b.output == a.output
        ref = Engine(model, params, max_slots=1, max_len=64,
                     prefill_len=8).generate(sharer(1)).tokens
        assert a.output == ref
        check_pool_invariants(eng.kv)

    def test_tampered_store_ciphertext_fails_every_consumer_without_leak(
            self, small_model):
        """Flip one ciphertext bit of a store-resident page: every consumer
        restoring through it must fail with an integrity error — raised
        before a single pool page is taken, so nothing leaks."""
        cfg, model, params = small_model
        from repro.runtime.pagestore import SealedPageStore
        store = SealedPageStore()
        td = TrustDomain("tdx")
        eng = make_sharing_engine(model, params, page_store=store,
                                  trust_domain=td)
        eng.submit(sharer(1))
        eng.run()
        entry = next(iter(store._domains[td.sealing_key.key_id()].values()))
        blob = next(iter(entry.blobs.values()))
        ct = np.asarray(blob.ciphertext).copy()
        ct[0, 0] ^= 1
        blob.ciphertext = jax.numpy.asarray(ct)
        for seed in (5, 6):
            consumer = make_sharing_engine(
                model, params, page_store=store,
                trust_domain=TrustDomain("tdx", sealing_key=td.sealing_key))
            consumer.submit(sharer(seed))
            with pytest.raises(IntegrityError):
                consumer.run()
            assert consumer.kv.free_physical_pages == consumer.kv.num_pages
            check_pool_invariants(consumer.kv)

    def test_cross_tenant_store_lookup_is_a_clean_miss(self, small_model):
        """Two engines with distinct sealing keys share ONE store object:
        tenant B's lookup of content tenant A published is a clean miss —
        never a MAC failure — because entries are namespaced per key
        domain; and A's blobs fail MAC under B's key if offered directly."""
        cfg, model, params = small_model
        from repro.core.sealing import unseal_tensor
        from repro.runtime.pagestore import SealedPageStore
        store = SealedPageStore()
        eng_a = make_sharing_engine(model, params, page_store=store)
        eng_b = make_sharing_engine(model, params, page_store=store)
        a = eng_a.submit(sharer(1))
        eng_a.run()
        assert store.publishes >= 1
        b = eng_b.submit(sharer(1))
        eng_b.run()                       # must not raise: miss, not MAC fail
        assert eng_b.kv.store_hits == 0
        assert store.misses >= 1
        assert b.output == a.output       # seeded: same bytes either way
        # the domains are cryptographically separate, not just namespaced:
        entry = next(iter(
            store._domains[eng_a.td.sealing_key.key_id()].values()))
        blob = next(iter(entry.blobs.values()))
        with pytest.raises(IntegrityError):
            unseal_tensor(eng_b.td.sealing_key, blob)
        check_pool_invariants(eng_a.kv)
        check_pool_invariants(eng_b.kv)

    def test_republishing_identical_content_mints_no_new_nonce(
            self, small_model):
        """Serving the same prompt twice re-releases the same full page:
        the second release must not re-seal or re-publish — the store entry
        count, its ciphertext bytes, and the audit log's store-publish
        lines all stay exactly as the first release left them."""
        cfg, model, params = small_model
        td = TrustDomain("tdx")
        eng = make_sharing_engine(model, params, page_store=True,
                                  trust_domain=td)
        store = eng.kv.page_store
        eng.submit(sharer(1))
        eng.run()
        dom = store._domains[td.sealing_key.key_id()]
        cts = {k: {n: bytes(np.asarray(st.ciphertext).tobytes())
                   for n, st in e.blobs.items()} for k, e in dom.items()}
        pubs, noops = store.publishes, store.republish_noops
        audit_pubs = sum(1 for e in td.audit if e.kind == "seal_kv"
                         and "store" in e.detail)
        eng.submit(sharer(1))
        eng.run()
        assert eng.kv.store_hits >= 1
        assert store.publishes == pubs, "identical content re-published"
        assert store.republish_noops == noops   # skipped pre-publish, not in it
        assert sum(1 for e in td.audit if e.kind == "seal_kv"
                   and "store" in e.detail) == audit_pubs, \
            "second release sealed a store blob it already holds"
        for k, e in store._domains[td.sealing_key.key_id()].items():
            assert k in cts and cts[k] == {
                n: bytes(np.asarray(st.ciphertext).tobytes())
                for n, st in e.blobs.items()}, \
                f"nonce {k.hex()} re-minted with fresh ciphertext"

    def test_discard_sealed_publishes_then_store_serves_waiters(
            self, small_model):
        """The deadline-abort path (discard_sealed) eagerly releases parked
        refs — but a store-retained page must survive that release while
        admission counts it toward a waiting request's discount, and a
        fresh identical request must then serve from the store."""
        cfg, model, params = small_model
        eng, sealed_reqs, key = seal_both_sharers(model, params,
                                                  page_store=True)
        store = eng.kv.page_store
        assert store.contains(eng.td.sealing_key, key), \
            "parking the last live ref must also publish the full page"
        for sealed, req in sealed_reqs:
            eng.kv.discard_sealed(
                eng.td.sealing_key, sealed,
                f"kvslot/{req.stream_id}/{req.seal_epoch - 1}")
        assert not eng.kv._sealed_refs and not eng.kv._parked
        assert store.contains(eng.td.sealing_key, key), \
            "discard_sealed must not take the store entry down with the park"
        keys = eng.kv.page_keys(PROMPT, len(PROMPT))
        assert eng.kv.store_resident_pages(keys) == 1
        assert eng.kv.resident_pages(keys) == 0
        hits0 = eng.kv.store_hits
        c = eng.submit(sharer(3))
        eng.run()
        assert eng.kv.store_hits > hits0
        ref = Engine(model, params, max_slots=1, max_len=64,
                     prefill_len=8).generate(sharer(3)).tokens
        assert c.output == ref
        check_pool_invariants(eng.kv)

    def test_park_rematerialize_round_trip_is_exact(self, small_model):
        """Both sharers sealed (page parked), both restored: the first
        restore re-materializes from parked ciphertext, the second re-links
        the re-materialized page, and both finish byte-identically to solo
        runs."""
        cfg, model, params = small_model
        eng, sealed_reqs, _ = seal_both_sharers(model, params)
        relinks_before = eng.kv.shared_page_maps
        for sealed, req in sealed_reqs:
            eng.restore_slot(sealed, req)
        assert not eng.kv._parked and not eng.kv._sealed_refs
        assert eng.kv.shared_page_maps == relinks_before + 1
        eng.run()
        for i, (_, req) in enumerate(sealed_reqs):
            ref = Engine(model, params, max_slots=1, max_len=64,
                         prefill_len=8).generate(sharer(i + 1)).tokens
            assert req.output == ref
        check_pool_invariants(eng.kv)
