"""Fleet-tier tests: per-tenant key domains, the attested gateway, and
sealed-KV migration across workers.

The confidentiality claims are the adversarial half: tenant key domains are
derived (never assigned by convention), so a blob sealed for tenant A must
fail MAC — not merely decrypt to garbage — under tenant B's domain, and a
failed cross-tenant restore must leak no slot, page, or reservation. The
serving claims are differential: a 2-worker fleet, and a fleet that loses a
worker mid-decode, must reproduce byte-for-byte the tokens every request
produces alone on an uncontended single-slot engine — placement and enclave
loss move *where* a request decodes, never *what* it decodes.
"""

import time

import jax
import numpy as np
import pytest

from conftest import check_pool_invariants
from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.core.attestation import (AttestationError, Verifier,
                                    derive_tenant_material)
from repro.core.sealing import (IntegrityError, SealingKey, seal_tensor,
                                unseal_tensor)
from repro.fleet import (ATTESTING, DEAD, DRAINING, READY, EngineWorker,
                         Gateway, Orchestrator)
from repro.models import build_model
from repro.runtime import (FINISH_REJECTED, Engine, GenerationRequest,
                           SamplingParams)

ENGINE_KW = dict(max_slots=2, max_len=64, prefill_buckets=(4, 8),
                 kv_backend="paged", page_size=8)


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _gen(prompt, mnt=6, seed=1, tenant=None, **kw):
    return GenerationRequest(prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=mnt,
                             params=SamplingParams(temperature=0.9, top_k=16,
                                                   seed=seed),
                             tenant=tenant, **kw)


def fleet_specs():
    """(prompt, max_new_tokens, seed, tenant) for the canonical fleet
    workload: six requests over two tenants, mixed prompt lengths."""
    rng = np.random.default_rng(3)
    return [(rng.integers(1, 100, size=int(l)).astype(np.int32),
             6, 50 + i, "ab"[i % 2])
            for i, l in enumerate(rng.integers(4, 12, size=6))]


def fleet_requests():
    return [_gen(p.copy(), mnt, seed, tenant)
            for p, mnt, seed, tenant in fleet_specs()]


@pytest.fixture(scope="module")
def solo_reference(small_model):
    """Each fleet request served alone on an uncontended single-slot
    engine: the byte-level ground truth for every fleet replay."""
    _, model, params = small_model
    refs = []
    for p, mnt, seed, _ in fleet_specs():
        eng = Engine(model, params, max_slots=1, max_len=64,
                     prefill_buckets=(4, 8))
        refs.append(list(eng.generate(_gen(p.copy(), mnt, seed)).tokens))
    return refs


def make_fleet(model, params, n=2, tenants=("a", "b"), **orch_kw):
    workers = [EngineWorker(f"w{i}", model, params, engine_kw=ENGINE_KW)
               for i in range(n)]
    gateway = Gateway(config_repr="test")
    for t in tenants:
        gateway.register_tenant(t)
    orch = Orchestrator(gateway, workers, **orch_kw)
    return gateway, orch, workers


def assert_no_leaks(eng):
    assert eng.slots.num_active == 0
    assert eng.kv.free_physical_pages == eng.kv.num_pages
    check_pool_invariants(eng.kv)


class TestKeyDomains:
    def test_derive_is_deterministic_and_label_separated(self):
        k = SealingKey.generate(b"m" * 32)
        a1, a2 = k.derive("tenant/a"), k.derive("tenant/a")
        b = k.derive("tenant/b")
        assert (a1.key, a1.mac_key) == (a2.key, a2.mac_key)
        assert a1.key != b.key and a1.mac_key != b.mac_key
        assert a1.key != k.key, "derived domain must not equal its parent"

    def test_cross_domain_unseal_fails_mac(self):
        k = SealingKey.generate(b"m" * 32)
        blob = seal_tensor(k.derive("tenant/a"), "kv/x",
                           np.arange(8, dtype=np.float32))
        with pytest.raises(IntegrityError):
            unseal_tensor(k.derive("tenant/b"), blob)
        np.testing.assert_array_equal(
            unseal_tensor(k.derive("tenant/a"), blob),
            np.arange(8, dtype=np.float32))

    def test_tenant_material_identical_across_attested_workers(self):
        """Two distinct enclaves, one master: each quote-gated release must
        land on the same per-tenant material (what lets a migrant cross),
        while two tenants' materials are unrelated."""
        master = b"s" * 32
        tds = [TrustDomain("tdx"), TrustDomain("tdx")]
        got = []
        for td in tds:
            v = td.make_verifier("cfg")
            q = td.quote(v.challenge(), "cfg")
            got.append(v.release_tenant_key(q, master, "a"))
        assert got[0] == got[1] == derive_tenant_material(master, "a")
        assert derive_tenant_material(master, "b") != got[0]

    def test_release_gates_on_measurement_and_freshness(self):
        td = TrustDomain("tdx")
        bad = Verifier(td.root, "0" * 64)
        with pytest.raises(AttestationError):
            bad.release_tenant_key(td.quote(bad.challenge(), "cfg"),
                                   b"s" * 32, "a")
        v = td.make_verifier("cfg")
        q = td.quote(v.challenge(), "cfg")
        v.release_tenant_key(q, b"s" * 32, "a")
        with pytest.raises(AttestationError):   # replayed quote
            v.release_tenant_key(q, b"s" * 32, "a")


class TestGateway:
    def test_admit_releases_transport_and_tenant_domains(self, small_model):
        _, model, params = small_model
        gateway, orch, (w0, w1) = make_fleet(model, params)
        assert w0.state == READY and w1.state == READY
        assert gateway.stats.attested_workers == 2
        # 3 tenants (a, b + the orchestrator's default) x 2 workers, each
        # release on its own fresh quote
        assert gateway.stats.keys_released == 6
        assert w0.tenant_keys["a"].key == w1.tenant_keys["a"].key
        assert w0.tenant_keys["a"].key != w0.tenant_keys["b"].key
        assert w0.transport.key != w1.transport.key

    def test_bad_measurement_is_rejected_dead(self, small_model):
        _, model, params = small_model
        w = EngineWorker("wx", model, params, engine_kw=ENGINE_KW)
        gateway = Gateway(config_repr="test")
        with pytest.raises(AttestationError):
            gateway.admit(w, expected_measurement="0" * 64)
        assert w.state == DEAD
        assert gateway.stats.rejected_quotes == 1
        with pytest.raises(KeyError):           # no transport key released
            gateway.envelope_seal("wx", "a", np.arange(4, dtype=np.int32))

    def test_envelope_only_opens_on_the_addressed_worker(self, small_model):
        _, model, params = small_model
        gateway, orch, (w0, w1) = make_fleet(model, params)
        prompt = np.arange(1, 9, dtype=np.int32)
        env = gateway.envelope_seal("w0", "a", prompt)
        np.testing.assert_array_equal(w0.open_envelope(env), prompt)
        with pytest.raises(IntegrityError):     # addressed to w0, not w1
            w1.open_envelope(env)
        env2 = gateway.envelope_seal("w0", "a", prompt)
        flipped = np.array(env2.sealed_prompt.ciphertext)
        flipped.flat[0] ^= 1                    # in-transit tamper
        env2.sealed_prompt.ciphertext = flipped
        with pytest.raises(IntegrityError):
            w0.open_envelope(env2)


class TestFleetServing:
    def test_two_worker_fleet_matches_solo(self, small_model,
                                           solo_reference):
        _, model, params = small_model
        _, orch, workers = make_fleet(model, params)
        handles = [orch.submit(g) for g in fleet_requests()]
        orch.run()
        assert [list(h.output) for h in handles] == solo_reference
        for w in workers:
            assert_no_leaks(w.engine)
        assert orch.stats.migrations == 0

    def test_kill_worker_mid_decode_byte_identical(self, small_model,
                                                   solo_reference):
        """The acceptance scenario: force a worker failure mid-decode and
        every in-flight request still completes byte-identically on the
        survivor, with the migration priced in both FleetStats and the
        surviving worker's ChannelStats."""
        _, model, params = small_model
        _, orch, workers = make_fleet(model, params)
        handles = [orch.submit(g) for g in fleet_requests()]
        for _ in range(3):                      # both workers mid-decode
            orch.step()
        victim = max(orch.ready_workers(), key=lambda w: w.load())
        survivor = next(w for w in workers if w is not victim)
        assert any(not h.finished for h in handles)
        ch0 = survivor.td.channel.stats.restore_events
        orch.kill(victim.name)
        assert victim.state == DEAD
        stats = orch.run()
        assert [list(h.output) for h in handles] == solo_reference
        assert orch.stats.migrations > 0
        assert orch.stats.migrated_bytes > 0
        assert stats.migrations == orch.stats.migrations
        assert stats.migrated_bytes == orch.stats.migrated_bytes
        # the migrants' sealed restores landed on the survivor's boundary
        assert survivor.td.channel.stats.restore_events > ch0
        assert_no_leaks(survivor.engine)

    def test_kill_with_prefix_sharing_backend(self, small_model):
        """Migration off a prefix-sharing pool: a by-reference shared-page
        entry only resolves against the SOURCE pool's content index and
        parked blobs, so migration seals detach (by value). The blob is
        self-contained on the survivor, outputs stay byte-identical, and
        neither pool leaks pages."""
        _, model, params = small_model
        kw = dict(max_slots=2, max_len=96, prefill_buckets=(32,),
                  kv_backend="paged", page_size=8, prefix_sharing=True)
        rng = np.random.default_rng(5)
        head = rng.integers(1, 100, 24).astype(np.int32)
        specs = [(np.concatenate([head,
                                  rng.integers(1, 100, 8).astype(np.int32)]),
                  6, 70 + i, "ab"[i % 2]) for i in range(4)]
        solo = []
        for p, mnt, seed, _ in specs:
            eng = Engine(model, params, max_slots=1, max_len=96,
                         prefill_buckets=(32,))
            solo.append(list(eng.generate(_gen(p.copy(), mnt, seed)).tokens))
        workers = [EngineWorker(f"w{i}", model, params, engine_kw=kw)
                   for i in range(2)]
        gateway = Gateway(config_repr="test")
        gateway.register_tenant("a")
        gateway.register_tenant("b")
        orch = Orchestrator(gateway, workers, placement="tenant_affinity")
        handles = [orch.submit(_gen(p.copy(), mnt, seed, tenant))
                   for p, mnt, seed, tenant in specs]
        for _ in range(3):
            orch.step()
        victim = max(orch.ready_workers(), key=lambda w: w.load())
        orch.kill(victim.name)
        orch.run()
        assert [list(h.output) for h in handles] == solo
        assert orch.stats.migrations > 0
        for w in workers:
            assert_no_leaks(w.engine)

    def test_drain_then_respawn(self, small_model, solo_reference):
        _, model, params = small_model
        _, orch, workers = make_fleet(
            model, params,
            worker_factory=lambda name: EngineWorker(
                name, model, params, engine_kw=ENGINE_KW))
        handles = [orch.submit(g) for g in fleet_requests()]
        for _ in range(2):
            orch.step()
        orch.drain("w0")
        assert workers[0].state == DEAD
        assert orch.stats.drains == 1
        orch.run()
        assert [list(h.output) for h in handles] == solo_reference
        spawned = orch.respawn("w0")            # a NEW enclave, re-attested
        assert spawned is not workers[0]
        assert spawned.state == READY
        assert spawned.tenant_keys["a"].key == \
            workers[1].tenant_keys["a"].key
        h = orch.submit(_gen(np.arange(1, 6, dtype=np.int32), tenant="a"))
        orch.run()
        assert h.finished

    def test_worker_state_machine(self, small_model):
        _, model, params = small_model
        w = EngineWorker("w9", model, params, engine_kw=ENGINE_KW)
        assert w.state == ATTESTING
        gateway = Gateway(config_repr="test")
        gateway.admit(w)
        assert w.state == READY
        orch = Orchestrator(gateway, [w])
        with pytest.raises(ValueError):         # live name reuse forbidden
            orch.add_worker(EngineWorker("w9", model, params,
                                         engine_kw=ENGINE_KW))
        orch.kill("w9")
        assert w.state == DEAD


class TestCrossTenantIsolation:
    def test_cross_tenant_restore_fails_mac_without_leaking(
            self, small_model, solo_reference):
        """Tenant A's migrated KV presented under tenant B's domain must
        fail MAC — isolation by key derivation, not naming convention — and
        the failed restore must leave the destination pool untouched. The
        same blob then restores cleanly under the right domain and finishes
        byte-identically."""
        _, model, params = small_model
        _, orch, (w0, w1) = make_fleet(model, params)
        p, mnt, seed, _ = fleet_specs()[0]
        req = w0.engine.submit(_gen(p.copy(), mnt, seed, tenant="a"))
        for _ in range(2):
            w0.engine.step()
        assert req.output and not req.finished   # mid-decode
        migrants, _ = w0.export_state()
        assert len(migrants) == 1
        blob = migrants[0]
        with pytest.raises(IntegrityError):
            w1.engine.restore_slot(blob.sealed, blob.req,
                                   key=w1.tenant_keys["b"],
                                   prefix=blob.prefix)
        assert_no_leaks(w1.engine)               # failed restore rolled back
        w1.engine.import_sealed_state([blob])
        w1.engine.run()
        assert req.finished
        assert list(req.output) == solo_reference[0]
        assert_no_leaks(w1.engine)


class TestBudgetsAndAdmission:
    def test_tenant_budget_holds_then_serves(self, small_model):
        _, model, params = small_model
        _, orch, _ = make_fleet(model, params, n=1,
                                tenant_budgets={"a": 10.0})
        h1 = orch.submit(_gen(np.arange(1, 5, dtype=np.int32), mnt=6,
                              seed=1, tenant="a"))
        h2 = orch.submit(_gen(np.arange(1, 5, dtype=np.int32), mnt=6,
                              seed=2, tenant="a"))
        assert h1 is not None
        assert h2 is None, "second request must park on the tenant budget"
        assert orch.stats.held_budget == 1
        orch.run()
        handles = list(orch.handles.values())
        assert len(handles) == 2 and all(h.finished for h in handles)

    def test_infeasible_deadline_rejected_before_boundary(self, small_model):
        _, model, params = small_model
        td = TrustDomain("tdx")
        eng = Engine(model, params, trust_domain=td, reject_infeasible=True,
                     step_time_hint_s=0.05, **ENGINE_KW)
        doomed = eng.submit(_gen(np.arange(1, 5, dtype=np.int32), mnt=8,
                                 deadline_s=0.01))
        assert doomed.finished
        assert doomed.finish_reason == FINISH_REJECTED
        # rejection happened BEFORE any crossing: no ingress, no stream
        assert td.channel.stats.messages_in == 0
        ok = eng.submit(_gen(np.arange(1, 5, dtype=np.int32), mnt=8,
                             deadline_s=100.0))
        stats = eng.run()
        assert ok.finished and ok.finish_reason != FINISH_REJECTED
        assert stats.rejected_infeasible == 1
        assert stats.total_requests == 1         # the rejected one is not served


class TestDedicatedPlanHandoff:
    def test_tight_deadline_late_arrival_hands_off_first(self, small_model):
        """Slack-ordered handoff regression: on the dedicated prefill plan a
        tight-deadline request submitted LAST must still cross to the
        decode plan — and emit its first token — before a slack request
        submitted first. Slot order is an arrival artifact; slack is not."""
        _, model, params = small_model
        emitted = []
        eng = Engine(model, params, max_slots=2, max_len=64,
                     prefill_buckets=(4, 8), prefill_plan="dedicated")
        slack = _gen(np.arange(1, 5, dtype=np.int32), mnt=4, seed=1)
        tight = _gen(np.arange(2, 6, dtype=np.int32), mnt=4, seed=2,
                     deadline_s=0.5)
        slack.on_token = lambda r, t: emitted.append("slack")
        tight.on_token = lambda r, t: emitted.append("tight")
        eng.submit(slack)
        eng.submit(tight)
        eng.run()
        assert "tight" in emitted and "slack" in emitted
        assert emitted.index("tight") < emitted.index("slack"), \
            f"tight-deadline first token must lead, got {emitted}"

    def test_batched_handoff_same_tokens_fewer_crossings(self, small_model):
        _, model, params = small_model
        outs, crossings, seals = [], [], []
        for batch in (1, 2):
            td = TrustDomain("tdx")
            eng = Engine(model, params, max_slots=2, max_len=64,
                         prefill_buckets=(4,), prefill_plan="dedicated",
                         handoff_batch=batch, trust_domain=td)
            reqs = [eng.submit(_gen(np.full(4, 3 + i, np.int32), mnt=4,
                                    seed=10 + i)) for i in range(4)]
            eng.run()
            assert all(r.finished for r in reqs)
            outs.append([list(r.output) for r in reqs])
            crossings.append(eng.handoff_crossings)
            seals.append(td.channel.stats.seal_events)
        assert outs[0] == outs[1], "handoff batching changed decoded output"
        assert crossings[1] < crossings[0]
        assert seals[1] < seals[0]
