"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles in
kernels/ref.py, external ground truth (RFC 8439), and property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.chacha20 import BLOCKS_PER_TILE, chacha20_xor_blocked
from repro.kernels.flash_attention import flash_attention
from repro.kernels.qmatmul import qmatmul
from repro.quant import quantize_int8, dequantize, qmatmul_ref


# ---------------------------------------------------------------------------
# chacha20
# ---------------------------------------------------------------------------

class TestChaCha20:
    def test_rfc8439_keystream_vector(self):
        """RFC 8439 §2.4.2 — the canonical test vector, counter=1."""
        key = bytes(range(32))
        nonce = bytes([0, 0, 0, 0, 0, 0, 0, 0x4A, 0, 0, 0, 0])
        ks = ref.chacha20_keystream_bytes_ref(key, nonce, 114, counter_base=1)
        plaintext = (b"Ladies and Gentlemen of the class of '99: If I could "
                     b"offer you only one tip for the future, sunscreen would be it.")
        cipher = bytes(a ^ b for a, b in zip(plaintext, ks))
        expected = bytes.fromhex(
            "6e2e359a2568f98041ba0728dd0d6981e97e7aec1d4360c20a27afccfd9fae0b"
            "f91b65c5524733ab8f593dabcd62b3571639d624e65152ab8f530c359f0861d8"
            "07ca0dbf500d6a6156a38e088a22b65e52bc514d16ccf806818ce91ab7793736"
            "5af90bbf74a35be6b40b8eedf2785e42874d")
        assert cipher == expected

    @pytest.mark.parametrize("n_tiles", [1, 2, 5])
    def test_kernel_matches_ref(self, n_tiles):
        n = n_tiles * BLOCKS_PER_TILE
        rng = np.random.default_rng(n_tiles)
        data = jnp.asarray(rng.integers(0, 2**32, (16, n), dtype=np.uint32))
        kw = jnp.asarray(rng.integers(0, 2**32, 8, dtype=np.uint32))
        nw = jnp.asarray(rng.integers(0, 2**32, 3, dtype=np.uint32))
        out = chacha20_xor_blocked(kw, nw, data, counter_base=7)
        expect = ref.chacha20_xor_ref(kw, nw, data, counter_base=7)
        assert jnp.all(out == expect)

    @given(seed=st.integers(0, 2**31 - 1), nbytes=st.integers(1, 5000))
    @settings(max_examples=15, deadline=None)
    def test_pack_seal_roundtrip_property(self, seed, nbytes):
        rng = np.random.default_rng(seed)
        raw = rng.integers(0, 256, nbytes, dtype=np.uint8)
        blocked, n = ops.pack_u32(raw)
        kw = jnp.asarray(rng.integers(0, 2**32, 8, dtype=np.uint32))
        nw = jnp.asarray(rng.integers(0, 2**32, 3, dtype=np.uint32))
        sealed = ops.seal_u32(kw, nw, blocked)
        # involution
        opened = ops.unseal_u32(kw, nw, sealed)
        assert np.array_equal(ops.unpack_u32(opened, n), raw)
        # ciphertext differs from plaintext (overwhelmingly likely)
        if nbytes > 8:
            assert not np.array_equal(np.asarray(sealed), np.asarray(blocked))

    def test_counter_continuation_across_tiled_calls(self):
        """Two tiled calls entering the stream at counter_base 0 and N must
        reproduce one contiguous single-call keystream — the fused-unseal
        decode kernel relies on mid-stream counter entry (layer l decrypts
        at counter_base = l * blocks_per_page)."""
        rng = np.random.default_rng(5)
        n = 2 * BLOCKS_PER_TILE
        data = jnp.asarray(rng.integers(0, 2**32, (16, n), dtype=np.uint32))
        kw = jnp.asarray(rng.integers(0, 2**32, 8, dtype=np.uint32))
        nw = jnp.asarray(rng.integers(0, 2**32, 3, dtype=np.uint32))
        whole = chacha20_xor_blocked(kw, nw, data)
        lo = chacha20_xor_blocked(kw, nw, data[:, :BLOCKS_PER_TILE])
        hi = chacha20_xor_blocked(kw, nw, data[:, BLOCKS_PER_TILE:],
                                  counter_base=BLOCKS_PER_TILE)
        assert jnp.array_equal(whole, jnp.concatenate([lo, hi], axis=1))
        # and the ref agrees block-for-block at an arbitrary entry point
        ks = ref.chacha20_keystream_ref(kw, nw, 8)
        ks_mid = ref.chacha20_keystream_ref(kw, nw, 3, counter_base=5)
        assert jnp.array_equal(ks[:, 5:], ks_mid)

    def test_counter_wraps_uint32(self):
        """The 32-bit block counter wraps modulo 2**32 (RFC 8439 keeps the
        counter a single u32 word): counter_base at the top of the range
        continues into 0, 1, ... rather than overflowing."""
        kw = jnp.arange(8, dtype=jnp.uint32)
        nw = jnp.arange(3, dtype=jnp.uint32)
        top = (1 << 32) - 2
        wrapped = ref.chacha20_keystream_ref(kw, nw, 4, counter_base=top)
        # blocks at counters [2**32-2, 2**32-1, 0, 1]
        lo = ref.chacha20_keystream_ref(kw, nw, 2, counter_base=0)
        assert jnp.array_equal(wrapped[:, 2:], lo)
        assert not jnp.array_equal(wrapped[:, :2], lo)
        # kernel path agrees with the ref across the wrap
        data = jnp.zeros((16, BLOCKS_PER_TILE), jnp.uint32)
        out = chacha20_xor_blocked(kw, nw, data, counter_base=top)
        expect = ref.chacha20_xor_ref(kw, nw, data, counter_base=top)
        assert jnp.array_equal(out, expect)

    def test_keystream_differs_across_nonces_and_counters(self):
        kw = jnp.arange(8, dtype=jnp.uint32)
        n1 = jnp.arange(3, dtype=jnp.uint32)
        n2 = n1 + 1
        ks1 = ref.chacha20_keystream_ref(kw, n1, 4)
        ks2 = ref.chacha20_keystream_ref(kw, n2, 4)
        ks3 = ref.chacha20_keystream_ref(kw, n1, 4, counter_base=4)
        assert not jnp.array_equal(ks1, ks2)
        assert not jnp.array_equal(ks1, ks3)


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

class TestQMatmul:
    @pytest.mark.parametrize("m,k,n,bm,bn,bk", [
        (128, 128, 128, 128, 128, 128),
        (256, 384, 128, 128, 128, 128),
        (128, 256, 256, 64, 128, 64),
        (512, 128, 384, 128, 128, 128),
    ])
    def test_kernel_exact_vs_ref(self, m, k, n, bm, bn, bk):
        kx, kw = jax.random.split(jax.random.key(m + n))
        xq = jax.random.randint(kx, (m, k), -127, 128, jnp.int8)
        wq = jax.random.randint(kw, (k, n), -127, 128, jnp.int8)
        scale = jax.random.uniform(kx, (1, n), jnp.float32, 0.01, 1.0)
        out = qmatmul(xq, wq, scale, bm=bm, bn=bn, bk=bk)
        expect = ref.qmatmul_ref(xq, wq, scale)
        np.testing.assert_array_equal(np.asarray(out, np.float32),
                                      np.asarray(expect, np.float32))

    @pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
    def test_out_dtypes(self, out_dtype):
        xq = jnp.ones((128, 128), jnp.int8)
        wq = jnp.ones((128, 128), jnp.int8)
        scale = jnp.full((1, 128), 0.5, jnp.float32)
        out = qmatmul(xq, wq, scale, out_dtype=out_dtype)
        assert out.dtype == out_dtype
        assert float(out[0, 0]) == 64.0

    @pytest.mark.parametrize("m,k,n", [(100, 200, 300), (7, 130, 129), (1, 64, 32)])
    def test_qmm_wrapper_close_to_float(self, m, k, n):
        x = jax.random.normal(jax.random.key(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
        out = ops.qmm(x, quantize_int8(w))
        oracle = x @ w
        rel = float(jnp.max(jnp.abs(out.astype(jnp.float32) - oracle))
                    / (jnp.max(jnp.abs(oracle)) + 1e-9))
        assert rel < 0.05, rel

    @given(seed=st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_quantize_roundtrip_error_bound(self, seed):
        w = jax.random.normal(jax.random.key(seed), (64, 96), jnp.float32)
        q = quantize_int8(w)
        back = dequantize(q, jnp.float32)
        # per output channel, max error <= scale/2 (+ rounding slack)
        err = jnp.max(jnp.abs(back - w), axis=0)
        bound = q.scale[0] * 0.5 + 1e-6
        assert bool(jnp.all(err <= bound))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("bh,s,d,bq,bkv", [
        (2, 128, 64, 64, 64),
        (4, 256, 64, 128, 64),
        (1, 256, 128, 64, 128),
        (8, 128, 32, 128, 128),
    ])
    def test_matches_ref(self, bh, s, d, bq, bkv):
        ks = jax.random.split(jax.random.key(s + d), 3)
        q = jax.random.normal(ks[0], (bh, s, d), jnp.float32)
        k = jax.random.normal(ks[1], (bh, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (bh, s, d), jnp.float32)
        out = flash_attention(q, k, v, bq=bq, bkv=bkv)
        expect = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(jax.random.key(9), 3)
        q, k, v = (jax.random.normal(kk, (2, 128, 64), jnp.bfloat16) for kk in ks)
        out = flash_attention(q, k, v, bq=64, bkv=64)
        expect = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   atol=2e-2, rtol=2e-2)

    def test_causality(self):
        """Changing future K/V must not affect earlier outputs."""
        ks = jax.random.split(jax.random.key(3), 3)
        q, k, v = (jax.random.normal(kk, (1, 128, 32), jnp.float32) for kk in ks)
        out1 = flash_attention(q, k, v, bq=64, bkv=64)
        k2 = k.at[:, 100:].set(99.0)
        v2 = v.at[:, 100:].set(-99.0)
        out2 = flash_attention(q, k2, v2, bq=64, bkv=64)
        np.testing.assert_allclose(np.asarray(out1[:, :100]),
                                   np.asarray(out2[:, :100]), atol=1e-6)

    def test_mha_wrapper_gqa(self):
        b, s, h, hk, hd = 2, 128, 8, 2, 32
        ks = jax.random.split(jax.random.key(4), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hk, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hk, hd), jnp.float32)
        out = ops.mha_flash(q, k, v, bq=64, bkv=64)
        # oracle via repeat + ref
        kr = jnp.repeat(k, h // hk, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        vr = jnp.repeat(v, h // hk, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        expect = ref.flash_attention_ref(qr, kr, vr).reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("s", [1, 7, 129, 200, 250])
    def test_mha_wrapper_odd_lengths(self, s):
        """Non-block-multiple sequence lengths (s=200 with bq=128 used to
        trip flash_attention's s % bq assert): padded to the block
        multiple, padded kv masked causally, output sliced back."""
        b, h, hd = 2, 4, 32
        ks = jax.random.split(jax.random.key(s), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
        out = ops.mha_flash(q, k, v)
        qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        kr = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        vr = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        expect = ref.flash_attention_ref(qr, kr, vr).reshape(
            b, h, s, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)
