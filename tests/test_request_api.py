"""Engine request-object API: per-request SamplingParams (temperature /
top-k / top-p / seed), coalesced egress frames (FramePolicy), SLO policies
(deadline drop, mid-flight abort, rate budgets), and RequestOutput
accounting. The v2 kwargs shim was removed in v4 — these entry points are
GenerationRequest-only."""

import math
import time

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.models import build_model
from repro.runtime import (FINISH_DROPPED, FINISH_LENGTH, FINISH_STOP, Engine,
                           FramePolicy, GenerationRequest, RequestOutput,
                           SamplingParams)
from repro.runtime import sampling


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(model, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_len", 8)
    return Engine(model, params, **kw)


def gen(prompt=PROMPT, **kw):
    return GenerationRequest(prompt=np.asarray(prompt, np.int32), **kw)


class TestRequestObjects:
    def test_generate_returns_request_output(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params, trust_domain=TrustDomain("tdx"))
        out = eng.generate(gen(max_new_tokens=5))
        assert isinstance(out, RequestOutput)
        assert len(out.tokens) == 5
        assert out.finish_reason == FINISH_LENGTH
        assert out.ttft_s > 0 and out.e2e_s >= out.ttft_s
        # boundary accounting: 1 ingress message, per-token frames by default
        assert out.ingress_messages == 1
        assert out.egress_frames == 5
        assert out.egress_tokens == 5
        assert not out.deadline_missed

    def test_eos_finish_reason_is_stop(self, small_model):
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(gen(max_new_tokens=6))
        eng = make_engine(model, params)
        out = eng.generate(gen(max_new_tokens=6, eos_id=ref.tokens[2]))
        assert out.finish_reason == FINISH_STOP
        assert out.tokens == ref.tokens[:3]

    def test_kwargs_form_is_gone(self, small_model):
        """The deprecated v2 kwargs shim was removed one release after its
        DeprecationWarning (as promised): raw-array submission is a
        TypeError now, not a warning."""
        cfg, model, params = small_model
        eng = make_engine(model, params)
        with pytest.raises(TypeError, match="GenerationRequest"):
            eng.submit(PROMPT)
        with pytest.raises(TypeError):
            eng.submit(PROMPT, 3)
        with pytest.raises(TypeError, match="GenerationRequest"):
            eng.generate(PROMPT)
        with pytest.raises(TypeError, match="GenerationRequest"):
            list(eng.stream(PROMPT))
        assert eng.idle                 # nothing was half-admitted

    def test_validation_errors(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(gen(max_new_tokens=0))
        with pytest.raises(ValueError, match="top_k"):
            eng.submit(gen(params=SamplingParams(temperature=1.0,
                                                 top_k=cfg.vocab_size)))
        with pytest.raises(ValueError, match="top_p"):
            eng.submit(gen(params=SamplingParams(temperature=1.0, top_p=0.0)))
        with pytest.raises(ValueError, match="top_p"):
            eng.submit(gen(params=SamplingParams(temperature=1.0, top_p=1.5)))
        with pytest.raises(ValueError, match="coalesce"):
            eng.submit(gen(frame=FramePolicy(coalesce=0)))
        with pytest.raises(ValueError, match="on_deadline"):
            eng.submit(gen(deadline_s=1.0, on_deadline="explode"))
        with pytest.raises(ValueError, match="deadline_s"):
            eng.submit(gen(deadline_s=-1.0))


class TestPerRequestSampling:
    def test_seeded_request_is_reproducible(self, small_model):
        cfg, model, params = small_model
        sp = SamplingParams(temperature=0.8, top_k=8, seed=123)
        outs = [make_engine(model, params).generate(
                    gen(max_new_tokens=8, params=sp)).tokens
                for _ in range(2)]
        assert outs[0] == outs[1]
        assert len(outs[0]) == 8

    def test_different_seeds_diverge(self, small_model):
        """High temperature + different seeds should (overwhelmingly) give
        different token sequences — i.e. sampling actually happens."""
        cfg, model, params = small_model
        outs = [make_engine(model, params).generate(
                    gen(max_new_tokens=10,
                        params=SamplingParams(temperature=5.0, seed=s))).tokens
                for s in (1, 2, 3)]
        assert len({tuple(o) for o in outs}) > 1

    def test_greedy_and_sampled_coexist_in_one_batch(self, small_model):
        """A sampled request in the batch must not perturb a greedy one."""
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(gen(max_new_tokens=6)).tokens
        eng = make_engine(model, params, max_slots=2)
        greedy_req = eng.submit(gen(max_new_tokens=6))
        eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=6,
                       params=SamplingParams(temperature=1.5, seed=7)))
        eng.run()
        assert greedy_req.output == ref

    def test_seeded_output_identical_across_preemption(self, small_model):
        """Acceptance: a seeded temperature>0 request reproduces
        byte-identical output across a forced seal/restore preemption —
        fold_in-per-token keys depend on (seed, index), not engine steps."""
        cfg, model, params = small_model
        sp = SamplingParams(temperature=0.9, top_k=16, seed=42)
        ref = make_engine(model, params, max_slots=1).generate(
            gen(max_new_tokens=10, params=sp)).tokens
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(gen(max_new_tokens=10, params=sp))
        for _ in range(3):
            eng.step()
        # force a preemption mid-request with a high-priority interloper
        eng.submit(gen(np.full(8, 7, np.int32), max_new_tokens=3, priority=9))
        eng.run()
        assert low.n_preemptions == 1
        assert low.output == ref

    def test_explicit_seal_restore_reproducible(self, small_model):
        cfg, model, params = small_model
        sp = SamplingParams(temperature=1.2, seed=5)
        ref = make_engine(model, params, max_slots=1).generate(
            gen(max_new_tokens=8, params=sp)).tokens
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        req = eng.submit(gen(max_new_tokens=8, params=sp))
        for _ in range(3):
            eng.step()
        sealed, evicted = eng.seal_slot(0)
        eng.restore_slot(sealed, evicted)
        eng.run()
        assert req.output == ref

    def test_unseeded_sampled_request_gets_recorded_seed(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params)
        out = eng.generate(gen(max_new_tokens=4,
                               params=SamplingParams(temperature=1.0)))
        assert out.seed is not None
        # replaying with the recorded seed reproduces the output
        replay = make_engine(model, params).generate(
            gen(max_new_tokens=4,
                params=SamplingParams(temperature=1.0, seed=out.seed)))
        assert replay.tokens == out.tokens

    def test_top_k_one_is_greedy(self, small_model):
        """top_k=1 restricts the support to the argmax regardless of
        temperature, so it must reproduce the greedy sequence."""
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(gen(max_new_tokens=6)).tokens
        out = make_engine(model, params).generate(
            gen(max_new_tokens=6,
                params=SamplingParams(temperature=2.0, top_k=1, seed=0)))
        assert out.tokens == ref

    def test_tiny_top_p_is_greedy(self, small_model):
        """A vanishing nucleus keeps only the argmax (the first sorted token
        is always retained), so top_p→0 must reproduce greedy even at high
        temperature."""
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(gen(max_new_tokens=6)).tokens
        out = make_engine(model, params).generate(
            gen(max_new_tokens=6,
                params=SamplingParams(temperature=3.0, top_p=1e-9, seed=0)))
        assert out.tokens == ref

    def test_top_p_seeded_reproducible_and_distinct(self, small_model):
        """top_p < 1 actually changes the sampled distribution (vs the same
        seed unrestricted) and stays seed-reproducible."""
        cfg, model, params = small_model
        sp = SamplingParams(temperature=2.0, top_p=0.3, seed=9)
        outs = [make_engine(model, params).generate(
                    gen(max_new_tokens=10, params=sp)).tokens
                for _ in range(2)]
        assert outs[0] == outs[1]
        free = make_engine(model, params).generate(
            gen(max_new_tokens=10,
                params=SamplingParams(temperature=2.0, seed=9))).tokens
        assert outs[0] != free      # the nucleus restriction had an effect

    def test_top_p_and_greedy_coexist_in_one_batch(self, small_model):
        """A nucleus-sampled request must not perturb a greedy slot-mate
        (the top_p row threads through the batched sample path)."""
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(gen(max_new_tokens=6)).tokens
        eng = make_engine(model, params, max_slots=2)
        greedy_req = eng.submit(gen(max_new_tokens=6))
        eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=6,
                       params=SamplingParams(temperature=1.5, top_p=0.7,
                                             seed=7)))
        eng.run()
        assert greedy_req.output == ref


class TestBatchedSamplingFn:
    def test_sample_matches_temperature_per_row(self):
        """sampling.sample with uniform state must agree with the scalar
        temperature() path row-by-row (same fold_in(key, step) keys)."""
        logits = jax.random.normal(jax.random.key(3), (4, 32))
        base = jax.random.PRNGKey(11)
        keys = np.stack([np.asarray(jax.random.fold_in(base, s))
                         for s in range(4)]).astype(np.uint32)
        state = sampling.SamplingState(
            temp=np.full(4, 0.7, np.float32), top_k=np.full(4, 5, np.int32),
            key=np.stack([np.asarray(base, np.uint32)] * 4),
            step=np.arange(4, dtype=np.int32))
        batched = sampling.sample(logits, state, kmax=8)
        for row in range(4):
            one = sampling.temperature(logits[row:row + 1],
                                       keys[row], temp=0.7, top_k=5)
            assert int(batched[row]) == int(one[0])

    def test_sample_mixed_greedy_rows(self):
        logits = jax.random.normal(jax.random.key(4), (3, 16))
        state = sampling.SamplingState(
            temp=np.asarray([0.0, 1.0, 0.0], np.float32),
            top_k=np.zeros(3, np.int32),
            key=np.stack([np.asarray(jax.random.PRNGKey(i), np.uint32)
                          for i in range(3)]),
            step=np.zeros(3, np.int32))
        out = sampling.sample(logits, state, kmax=0)
        g = sampling.greedy(logits)
        assert int(out[0]) == int(g[0]) and int(out[2]) == int(g[2])

    def test_sample_top_k_support(self):
        logits = np.asarray([[10.0, 9.0, -5.0, -6.0]] * 32, np.float32)
        state = sampling.SamplingState(
            temp=np.full(32, 1.0, np.float32), top_k=np.full(32, 2, np.int32),
            key=np.stack([np.asarray(jax.random.PRNGKey(i), np.uint32)
                          for i in range(32)]),
            step=np.zeros(32, np.int32))
        toks = sampling.sample(jax.numpy.asarray(logits), state, kmax=2)
        assert set(np.asarray(toks).tolist()) <= {0, 1}

    def test_sample_top_p_support(self):
        """Two tokens carry ~all the mass; top_p=0.9 must never sample the
        tail, while a top_p=1 row in the same batch remains unrestricted in
        principle (its support includes everything)."""
        logits = np.asarray([[8.0, 8.0, -20.0, -20.0]] * 32, np.float32)
        state = sampling.SamplingState(
            temp=np.full(32, 1.0, np.float32), top_k=np.zeros(32, np.int32),
            key=np.stack([np.asarray(jax.random.PRNGKey(i), np.uint32)
                          for i in range(32)]),
            step=np.zeros(32, np.int32),
            top_p=np.full(32, 0.9, np.float32))
        toks = sampling.sample(jax.numpy.asarray(logits), state, kmax=0)
        assert set(np.asarray(toks).tolist()) <= {0, 1}

    def test_sample_top_p_composes_with_top_k(self):
        """top_k=3 admits token 2; top_p then cuts it: the intersection is
        {0, 1}."""
        logits = np.asarray([[5.0, 4.9, 0.0, -1.0]] * 32, np.float32)
        state = sampling.SamplingState(
            temp=np.full(32, 1.0, np.float32), top_k=np.full(32, 3, np.int32),
            key=np.stack([np.asarray(jax.random.PRNGKey(i), np.uint32)
                          for i in range(32)]),
            step=np.zeros(32, np.int32),
            top_p=np.full(32, 0.9, np.float32))
        toks = sampling.sample(jax.numpy.asarray(logits), state, kmax=4)
        assert set(np.asarray(toks).tolist()) <= {0, 1}

    def test_scalar_temperature_top_p_matches_batched(self):
        logits = jax.random.normal(jax.random.key(5), (4, 64))
        base = jax.random.PRNGKey(3)
        keys = np.stack([np.asarray(jax.random.fold_in(base, s))
                         for s in range(4)]).astype(np.uint32)
        state = sampling.SamplingState(
            temp=np.full(4, 0.9, np.float32), top_k=np.zeros(4, np.int32),
            key=np.stack([np.asarray(base, np.uint32)] * 4),
            step=np.arange(4, dtype=np.int32),
            top_p=np.full(4, 0.6, np.float32))
        batched = sampling.sample(logits, state, kmax=0)
        for row in range(4):
            one = sampling.temperature(logits[row:row + 1], keys[row],
                                       temp=0.9, top_p=0.6)
            assert int(batched[row]) == int(one[0])

    def test_temperature_rejects_top_k_at_vocab(self):
        logits = jax.random.normal(jax.random.key(0), (2, 8))
        with pytest.raises(ValueError, match="top_k"):
            sampling.temperature(logits, jax.random.PRNGKey(0), 1.0, top_k=8)
        with pytest.raises(ValueError, match="top_k"):
            sampling.temperature(logits, jax.random.PRNGKey(0), 1.0, top_k=9)


class TestCoalescedEgress:
    @pytest.mark.parametrize("coalesce", [1, 3, 4, 16])
    def test_frames_are_ceil_tokens_over_n(self, small_model, coalesce):
        """Acceptance: coalesce=N ⇒ ceil(tokens/N) frames, same tokens."""
        cfg, model, params = small_model
        n_tokens = 7
        ref = make_engine(model, params).generate(
            gen(max_new_tokens=n_tokens)).tokens
        eng = make_engine(model, params, trust_domain=TrustDomain("tdx"))
        out = eng.generate(gen(max_new_tokens=n_tokens,
                               frame=FramePolicy(coalesce=coalesce)))
        assert out.tokens == ref
        want_frames = math.ceil(n_tokens / coalesce)
        assert out.egress_frames == want_frames
        assert out.egress_tokens == n_tokens
        assert eng.td.channel.stats.messages_out == want_frames
        assert eng.td.channel.stats.tokens_out == n_tokens

    def test_coalesced_stream_yields_in_bursts(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params, trust_domain=TrustDomain("tdx"))
        seen = []
        it = eng.stream(gen(max_new_tokens=6, frame=FramePolicy(coalesce=3)))
        toks = list(it)
        assert len(toks) == 6
        # two frames of 3 tokens each crossed the boundary
        assert eng.td.channel.stats.messages_out == 2
        assert eng.td.channel.stats.tokens_out == 6

    def test_flush_on_finish_partial_frame(self, small_model):
        """5 tokens at coalesce=4: one full frame + one flush-on-finish."""
        cfg, model, params = small_model
        eng = make_engine(model, params, trust_domain=TrustDomain("tdx"))
        out = eng.generate(gen(max_new_tokens=5, frame=FramePolicy(coalesce=4)))
        assert out.egress_frames == 2
        details = [e.detail for e in eng.td.audit if e.kind == "egress_frame"]
        sizes = [int(d.split("n=")[1].split()[0]) for d in details]
        assert sizes == [4, 1]

    def test_coalesced_frames_still_replay_protected(self, small_model):
        """Coalescing must not weaken the channel: frames stay sequenced
        per stream and a replay is rejected."""
        cfg, model, params = small_model
        from repro.core.bounce import BounceBuffer
        from repro.core.sealing import IntegrityError, SealingKey
        bb = BounceBuffer(SealingKey.generate(b"coal"))
        sid = bb.open_stream()
        f0 = bb.device_send_frame(sid, np.arange(4, dtype=np.int32))
        f1 = bb.device_send_frame(sid, np.arange(4, 8, dtype=np.int32))
        assert bb.host_recv_frame(f0).tolist() == [0, 1, 2, 3]
        with pytest.raises(IntegrityError):
            bb.host_recv_frame(f0)          # verbatim replay of a coalesced frame
        assert bb.host_recv_frame(f1).tolist() == [4, 5, 6, 7]
        assert bb.stats.messages_out == 2 and bb.stats.tokens_out == 8
        assert bb.stats.crossings_per_token == pytest.approx(0.25)

    def test_coalescing_survives_preemption(self, small_model):
        """A preempted request's partially-filled egress buffer travels with
        it: tokens, frame count, and order are unchanged."""
        cfg, model, params = small_model
        ref = make_engine(model, params, max_slots=1).generate(
            gen(max_new_tokens=9)).tokens
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(gen(max_new_tokens=9, frame=FramePolicy(coalesce=4)))
        for _ in range(2):
            eng.step()
        eng.submit(gen(np.full(8, 7, np.int32), max_new_tokens=2, priority=5))
        eng.run()
        assert low.n_preemptions == 1
        assert low.output == ref
        assert low.result().egress_frames == math.ceil(9 / 4)


class TestSLO:
    def test_deadline_drop_while_queued(self, small_model):
        """A drop-policy request whose deadline passes in the queue is
        dropped, counted, and never touches the device."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        keep = eng.submit(gen(max_new_tokens=6))
        doomed = eng.submit(gen(np.full(8, 5, np.int32), max_new_tokens=6,
                                deadline_s=0.01, on_deadline="drop"))
        time.sleep(0.03)                    # deadline passes while queued
        stats = eng.run()
        assert keep.finished and not keep.dropped
        assert doomed.dropped and doomed.output == []
        assert doomed.result().finish_reason == FINISH_DROPPED
        assert stats.dropped_requests == 1
        assert stats.total_requests == 1    # dropped ≠ served
        # the dropped request's egress stream was retired on the channel
        assert doomed.stream_id not in eng.td.channel._stream_seq

    def test_serve_policy_counts_deadline_miss(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params)
        late = eng.submit(gen(max_new_tokens=5, deadline_s=1e-4))  # "serve"
        stats = eng.run()
        assert late.finished and not late.dropped
        assert late.deadline_missed
        assert stats.deadline_misses == 1
        assert stats.dropped_requests == 0
        assert late.result().deadline_missed

    def test_abort_mid_flight_bounds_victim_and_frees_slot(self, small_model):
        """on_deadline='abort' terminates a running request at the next step
        after its deadline: partial tokens are flushed, the slot frees for
        the queue, and the miss is counted (queued-only dropping would let
        this request hog its slot to max_new_tokens)."""
        from repro.runtime import FINISH_ABORTED
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        doomed = eng.submit(gen(max_new_tokens=50, deadline_s=5.0,
                                on_deadline="abort"))
        waiter = eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=3))
        for _ in range(3):
            eng.step()                  # doomed claims the only slot
        assert not doomed.finished
        doomed.t_submit -= 10.0         # deadline passes mid-flight
        stats = eng.run(max_steps=2000)
        assert doomed.finished and doomed.finish_reason == FINISH_ABORTED
        assert 0 < len(doomed.output) < 50       # partial result delivered
        assert doomed.result().finish_reason == FINISH_ABORTED
        assert doomed.deadline_missed
        assert stats.aborted_requests == 1
        assert stats.deadline_misses == 1
        assert waiter.finished and len(waiter.output) == 3
        # the aborted stream was retired on the channel
        assert doomed.stream_id not in eng.td.channel._stream_seq

    def test_abort_discards_sealed_preempted_request(self, small_model):
        """A sealed-out (preempted) abort-policy request whose deadline
        passes is discarded instead of restored — no restore crossing, no
        decode steps wasted on a dead request."""
        from repro.runtime import FINISH_ABORTED
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        victim = eng.submit(gen(max_new_tokens=50, priority=0,
                                deadline_s=5.0, on_deadline="abort"))
        for _ in range(2):
            eng.step()
        high = eng.submit(gen(np.full(8, 7, np.int32), max_new_tokens=3,
                              priority=5))
        eng.step()                      # victim sealed out for the high-prio
        assert victim.n_preemptions == 1
        victim.t_submit -= 10.0         # deadline passes while sealed
        restores_before = [e for e in eng.td.audit if e.kind == "restore_kv"]
        stats = eng.run(max_steps=2000)
        assert high.finished
        assert victim.finished and victim.finish_reason == FINISH_ABORTED
        restores = [e for e in eng.td.audit if e.kind == "restore_kv"]
        assert len(restores) == len(restores_before)   # never restored
        assert stats.aborted_requests == 1

    def test_abort_policy_drops_while_queued_too(self, small_model):
        """abort subsumes drop for queued requests: one that would be killed
        mid-flight is not worth starting after its deadline."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1)
        keep = eng.submit(gen(max_new_tokens=6))
        doomed = eng.submit(gen(np.full(8, 5, np.int32), max_new_tokens=6,
                                deadline_s=0.01, on_deadline="abort"))
        time.sleep(0.03)
        stats = eng.run()
        assert keep.finished
        assert doomed.dropped and doomed.output == []
        assert stats.dropped_requests == 1

    def test_rate_budget_throttles_class_without_starving_others(self, small_model):
        """Priority 0 has a tiny token budget; after it is spent, priority-1
        requests (unbudgeted) must still be admitted ahead of it."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1,
                          rate_budgets={0: 2.0})   # ~2 tokens/s for class 0
        a = eng.submit(gen(max_new_tokens=4, priority=0))       # spends budget
        b = eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=4,
                           priority=0))                          # now blocked
        c = eng.submit(gen(np.full(8, 5, np.int32), max_new_tokens=4,
                           priority=1))                          # unthrottled
        eng.run(max_steps=2000)
        assert a.finished and b.finished and c.finished
        # the throttled class-0 follower finished LAST even though it was
        # submitted before the class-1 request
        assert c.t_done < b.t_done

    def test_rate_budget_eventually_serves(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params, rate_budgets={0: 50.0})
        reqs = [eng.submit(gen(np.full(8, i + 1, np.int32), max_new_tokens=3))
                for i in range(3)]
        stats = eng.run(max_steps=20_000)
        assert all(r.finished for r in reqs)
        assert stats.total_requests == 3

    def test_zero_rate_budget_rejected(self, small_model):
        cfg, model, params = small_model
        with pytest.raises(ValueError, match="rate budget"):
            make_engine(model, params, rate_budgets={0: 0.0})


class TestServeStatsV3:
    def test_p50_and_guarded_percentiles(self):
        from repro.runtime.scheduler import ServeStats, _pct
        s = ServeStats()
        assert s.p50_latency_s == 0.0 and s.p99_ttft_s == 0.0
        assert _pct([], 99) == 0.0
        assert _pct([0.25], 99) == 0.25     # <2 samples: the sample itself
        s.latencies_s = [0.1, 0.2, 0.3, 0.4]
        assert s.p50_latency_s == pytest.approx(0.25)
        assert s.p99_latency_s <= 0.4

    def test_stats_count_preemptions(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1)
        eng.submit(gen(max_new_tokens=8, priority=0))
        for _ in range(2):
            eng.step()
        eng.submit(gen(np.full(8, 9, np.int32), max_new_tokens=2, priority=5))
        stats = eng.run()
        assert stats.preemptions == 1
        assert stats.total_requests == 2
        assert stats.p50_ttft_s > 0


class TestPenalties:
    """Repetition/presence penalties: [slots] rows behind static None gates
    (the top_p pattern) with host-side generated-token history that follows
    the request across seal/restore preemption."""

    def test_neutral_penalties_change_nothing(self, small_model):
        """rep=1.0 / presence=0.0 must reproduce the un-penalized stream —
        the gate stays closed and the math is a no-op either way."""
        cfg, model, params = small_model
        base = SamplingParams(temperature=1.5, top_k=8, seed=11)
        neutral = SamplingParams(temperature=1.5, top_k=8, seed=11,
                                 repetition_penalty=1.0, presence_penalty=0.0)
        a = make_engine(model, params).generate(
            gen(max_new_tokens=10, params=base)).tokens
        b = make_engine(model, params).generate(
            gen(max_new_tokens=10, params=neutral)).tokens
        assert a == b

    def test_penalties_change_output_and_reproduce(self, small_model):
        """A strongly negative presence penalty REWARDS seen tokens — the
        continuation must collapse toward repeats (guaranteed divergence
        from the free stream) while staying seed-reproducible."""
        cfg, model, params = small_model
        free = make_engine(model, params).generate(
            gen(max_new_tokens=12,
                params=SamplingParams(temperature=1.5, seed=4))).tokens
        outs = [make_engine(model, params).generate(
                    gen(max_new_tokens=12,
                        params=SamplingParams(temperature=1.5, seed=4,
                                              presence_penalty=-30.0))).tokens
                for _ in range(2)]
        assert outs[0] == outs[1]       # seeded => reproducible
        assert outs[0] != free          # the penalty had an effect
        # -30 on a smoke-scale logit makes every seen token dominate: the
        # stream must revisit its first token essentially immediately
        assert outs[0][1] == outs[0][0]

    def test_sample_unit_penalties_deterministic(self):
        """Unit-level determinism: rep_pen shrinks a dominant SEEN logit
        below the runner-up; presence subtracts it below; unseen rows are
        untouched."""
        import jax.numpy as jnp
        from repro.runtime import sampling
        v = 64
        logits = np.full((2, v), -100.0, np.float32)
        logits[:, 5] = 50.0      # dominant
        logits[:, 9] = 20.0      # runner-up
        hist = np.zeros((2, v), np.int32)
        hist[1, 5] = 1           # row 1 has generated token 5 before
        keys = np.stack([np.asarray(jax.random.PRNGKey(0), np.uint32)] * 2)
        base = dict(temp=jnp.ones(2), top_k=jnp.zeros(2, jnp.int32),
                    key=jnp.asarray(keys), step=jnp.zeros(2, jnp.int32),
                    hist=jnp.asarray(hist))
        rep = sampling.SamplingState(
            rep_pen=jnp.asarray([25.0, 25.0], jnp.float32), **base)
        toks = np.asarray(sampling.sample(jnp.asarray(logits), rep))
        assert toks[0] == 5      # unseen: dominant survives
        assert toks[1] == 9      # seen: 50/25 = 2 < 20 → runner-up wins
        pres = sampling.SamplingState(
            presence=jnp.asarray([0.0, 100.0], jnp.float32), **base)
        toks = np.asarray(sampling.sample(jnp.asarray(logits), pres))
        assert toks[0] == 5
        assert toks[1] == 9      # seen: 50 - 100 = -50 < 20

    def test_repetition_penalty_reduces_repeats(self, small_model):
        """A strong repetition penalty must not emit more duplicate tokens
        than the unpenalized stream at the same seed/temperature."""
        cfg, model, params = small_model
        sp = lambda rp: SamplingParams(temperature=1.0, seed=2,
                                       repetition_penalty=rp)
        def dupes(tokens):
            return len(tokens) - len(set(tokens))
        free = make_engine(model, params).generate(
            gen(max_new_tokens=16, params=sp(1.0))).tokens
        pen = make_engine(model, params).generate(
            gen(max_new_tokens=16, params=sp(50.0))).tokens
        assert dupes(pen) <= dupes(free)

    def test_penalized_output_identical_across_preemption(self, small_model):
        """Seeded parity across seal/restore: the penalty history is rebuilt
        from the request's own output list, so the post-restore continuation
        re-samples byte-identically."""
        cfg, model, params = small_model
        sp = SamplingParams(temperature=1.2, top_k=16, seed=21,
                            repetition_penalty=2.0, presence_penalty=1.0)
        ref = make_engine(model, params, max_slots=1).generate(
            gen(max_new_tokens=10, params=sp)).tokens
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(gen(max_new_tokens=10, params=sp))
        for _ in range(4):
            eng.step()              # some penalized history exists
        eng.submit(gen(np.full(8, 7, np.int32), max_new_tokens=3, priority=9))
        eng.run()
        assert low.n_preemptions == 1
        assert low.output == ref

    def test_penalized_and_greedy_coexist(self, small_model):
        """A penalized slot-mate must not perturb a greedy request (the
        penalty rows are per-slot; greedy rows ignore them)."""
        cfg, model, params = small_model
        ref = make_engine(model, params).generate(gen(max_new_tokens=6)).tokens
        eng = make_engine(model, params, max_slots=2)
        greedy_req = eng.submit(gen(max_new_tokens=6))
        eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=6,
                       params=SamplingParams(temperature=1.5, seed=7,
                                             repetition_penalty=4.0)))
        eng.run()
        assert greedy_req.output == ref

    def test_state_gating(self, small_model):
        """The penalty rows (and hist) only enter the jitted state when some
        live slot actually penalizes — the top_p static-gate pattern."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=2)
        eng.submit(gen(max_new_tokens=4,
                       params=SamplingParams(temperature=1.0, seed=0)))
        eng._admit_ready()
        state, _ = eng._sampling_state(np.zeros(2, np.int32))
        assert state.rep_pen is None and state.presence is None \
            and state.hist is None
        eng2 = make_engine(model, params, max_slots=2)
        eng2.submit(gen(max_new_tokens=4,
                        params=SamplingParams(temperature=1.0, seed=0,
                                              repetition_penalty=1.5)))
        eng2._admit_ready()
        state2, _ = eng2._sampling_state(np.zeros(2, np.int32))
        assert state2.rep_pen is not None and state2.hist is not None
        assert state2.presence is None      # only the used penalty compiles

    def test_hist_mirror_released_after_penalized_work_drains(self, small_model):
        """Once no live slot penalizes, the device history mirror and its
        pending-increment queue are dropped — a greedy-only follow-up
        workload must not accumulate queued tokens forever."""
        cfg, model, params = small_model
        eng = make_engine(model, params)
        eng.generate(gen(max_new_tokens=4,
                         params=SamplingParams(temperature=1.0, seed=0,
                                               repetition_penalty=1.5)))
        for _ in range(3):
            eng.generate(gen(max_new_tokens=4))      # greedy-only traffic
        assert eng._hist_dev is None
        assert eng._hist_pending == []

    def test_validation(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params)
        with pytest.raises(ValueError, match="repetition_penalty"):
            eng.submit(gen(params=SamplingParams(temperature=1.0,
                                                 repetition_penalty=0.0)))
        with pytest.raises(ValueError, match="repetition_penalty"):
            eng.submit(gen(params=SamplingParams(temperature=1.0,
                                                 repetition_penalty=float("nan"))))
        with pytest.raises(ValueError, match="presence_penalty"):
            eng.submit(gen(params=SamplingParams(temperature=1.0,
                                                 presence_penalty=float("inf"))))

    def test_frequency_weighting_compounds(self):
        """Count-weighted CTRL: a token seen c times is penalized by
        rep_pen**c, so a count too weak to flip the argmax at c=1 still
        flips it at c=2; unseen rows (count 0) stay exactly untouched."""
        import jax.numpy as jnp
        from repro.runtime import sampling
        v = 64
        logits = np.full((3, v), -100.0, np.float32)
        logits[:, 5] = 50.0      # dominant
        logits[:, 9] = 20.0      # runner-up
        hist = np.zeros((3, v), np.int32)
        hist[1, 5] = 1           # 50/2 = 25  > 20: survives one occurrence
        hist[2, 5] = 2           # 50/4 = 12.5 < 20: two occurrences flip it
        keys = np.stack([np.asarray(jax.random.PRNGKey(0), np.uint32)] * 3)
        state = sampling.SamplingState(
            temp=jnp.ones(3), top_k=jnp.zeros(3, jnp.int32),
            key=jnp.asarray(keys), step=jnp.zeros(3, jnp.int32),
            hist=jnp.asarray(hist),
            rep_pen=jnp.full(3, 2.0, jnp.float32))
        toks = np.asarray(sampling.sample(jnp.asarray(logits), state))
        assert toks[0] == 5      # unseen: rp**0 == 1, untouched
        assert toks[1] == 5      # seen once: still dominant
        assert toks[2] == 9      # seen twice: compounded below runner-up


class TestLogitBias:
    """Per-request logit-bias maps: [slots, vocab] additive rows behind the
    same static None gate as the penalties, rebuilt with the sampling row so
    seeded requests reproduce across seal/restore preemption."""

    def test_bias_forces_and_bans_tokens(self, small_model):
        """A huge positive bias forces its token every step; banning that
        token with a huge negative bias keeps it out of the stream."""
        cfg, model, params = small_model
        sp = lambda b: SamplingParams(temperature=1.2, seed=5, logit_bias=b)
        forced = make_engine(model, params).generate(
            gen(max_new_tokens=6, params=sp({7: 1000.0}))).tokens
        assert forced == [7] * 6
        banned = make_engine(model, params).generate(
            gen(max_new_tokens=8, params=sp({forced[0]: -1000.0,
                                             7: -1000.0}))).tokens
        assert 7 not in banned

    def test_bias_applies_to_the_prefill_first_token(self, small_model):
        """_first_tokens threads the bias rows too — the very first sampled
        token (from prefill logits) honors the map, not just decode steps."""
        cfg, model, params = small_model
        out = make_engine(model, params).generate(
            gen(max_new_tokens=1,
                params=SamplingParams(temperature=1.0, seed=9,
                                      logit_bias={11: 1000.0}))).tokens
        assert out == [11]

    def test_biased_and_unbiased_coexist(self, small_model):
        """Bias rows are per-slot: a biased slot-mate must not perturb a
        seeded unbiased request sharing the decode batch."""
        cfg, model, params = small_model
        sp = SamplingParams(temperature=1.5, top_k=8, seed=13)
        ref = make_engine(model, params).generate(
            gen(max_new_tokens=6, params=sp)).tokens
        eng = make_engine(model, params, max_slots=2)
        plain = eng.submit(gen(max_new_tokens=6,
                               params=SamplingParams(temperature=1.5,
                                                     top_k=8, seed=13)))
        eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=6,
                       params=SamplingParams(temperature=1.2, seed=7,
                                             logit_bias={3: 1000.0})))
        eng.run()
        assert plain.output == ref

    def test_biased_output_identical_across_preemption(self, small_model):
        """Seeded parity across seal/restore: the bias matrix is rebuilt
        from SamplingParams whenever the sampling row is set, so the
        post-restore continuation re-samples byte-identically."""
        cfg, model, params = small_model
        sp = SamplingParams(temperature=1.2, top_k=16, seed=21,
                            logit_bias={5: 6.0, 9: -4.0})
        ref = make_engine(model, params, max_slots=1).generate(
            gen(max_new_tokens=10, params=sp)).tokens
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        low = eng.submit(gen(max_new_tokens=10, params=sp))
        for _ in range(4):
            eng.step()
        eng.submit(gen(np.full(8, 7, np.int32), max_new_tokens=3, priority=9))
        eng.run()
        assert low.n_preemptions == 1
        assert low.output == ref

    def test_state_gating_and_mirror_release(self, small_model):
        """The bias matrix only enters the jitted state while some live slot
        biases, and the device mirror drops once biased work drains."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=2)
        eng.submit(gen(max_new_tokens=4,
                       params=SamplingParams(temperature=1.0, seed=0)))
        eng._admit_ready()
        state, _ = eng._sampling_state(np.zeros(2, np.int32))
        assert state.bias is None
        eng2 = make_engine(model, params, max_slots=2)
        eng2.submit(gen(max_new_tokens=4,
                        params=SamplingParams(temperature=1.0, seed=0,
                                              logit_bias={2: 5.0})))
        eng2._admit_ready()
        state2, _ = eng2._sampling_state(np.zeros(2, np.int32))
        assert state2.bias is not None
        assert state2.rep_pen is None       # only the used feature compiles
        eng2.run()
        for _ in range(2):
            eng2.generate(gen(max_new_tokens=3))    # greedy-only traffic
        assert eng2._bias_dev is None

    def test_validation(self, small_model):
        cfg, model, params = small_model
        eng = make_engine(model, params)
        with pytest.raises(ValueError, match="logit_bias"):
            eng.submit(gen(params=SamplingParams(logit_bias={1: 1.0})))
        with pytest.raises(ValueError, match="out of range"):
            eng.submit(gen(params=SamplingParams(
                temperature=1.0, logit_bias={10 ** 9: 1.0})))
        with pytest.raises(ValueError, match="finite"):
            eng.submit(gen(params=SamplingParams(
                temperature=1.0, logit_bias={1: float("nan")})))


class TestSlackScheduling:
    """Deadline-aware (slack/EDF) admission ordering — the default — serves
    tight deadlines while they are still meetable, so on_deadline='abort'
    fires rarely; priority-only ordering is kept as the measurable
    baseline."""

    def test_deadline_less_requests_keep_priority_order(self, small_model):
        """With no deadlines anywhere, slack order degrades to exactly the
        v4 priority-then-arrival order."""
        cfg, model, params = small_model
        done = []
        for order in ("slack", "priority"):
            eng = make_engine(model, params, max_slots=1,
                              admission_order=order)
            lo = eng.submit(gen(max_new_tokens=3, priority=0))
            hi = eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=3,
                                priority=5))
            eng.run()
            assert hi.t_done < lo.t_done or lo.n_preemptions > 0
            done.append((lo.output, hi.output))
        assert done[0] == done[1]

    def test_bad_order_rejected(self, small_model):
        cfg, model, params = small_model
        with pytest.raises(ValueError, match="order"):
            make_engine(model, params, admission_order="fifo")

    def test_restore_gate_stays_priority_based_under_slack(self, small_model):
        """A high-priority sealed-out request must be restored before a
        mid-priority waiting request is admitted, even when a LOWER-priority
        sealed request carries the tightest deadline (slack picks the
        restore ORDER among eligible candidates; eligibility itself stays
        priority-based, or mid-priority traffic would starve the sealed
        high-priority request indefinitely)."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        b = eng.submit(gen(np.full(8, 2, np.int32), max_new_tokens=12,
                           priority=0, deadline_s=30.0))
        for _ in range(2):
            eng.step()                 # b runs
        a = eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=12,
                           priority=9))
        for _ in range(2):
            eng.step()                 # a preempts b, runs
        top = eng.submit(gen(np.full(8, 4, np.int32), max_new_tokens=3,
                             priority=11))
        eng.step()                     # top preempts a: sealed = {b(0), a(9)}
        assert b.n_preemptions == 1 and a.n_preemptions == 1
        h = eng.submit(gen(np.full(8, 5, np.int32), max_new_tokens=3,
                           priority=5))
        eng.run()
        assert all(r.finished for r in (a, b, h, top))
        assert a.t_done < h.t_done     # a(9) restored before h(5) admitted

    def test_high_priority_waiting_gates_despite_edf_head(self, small_model):
        """Priority gates must see the strongest WAITING request, not the
        slack-ordered queue head: with a deadline-bearing prio-0 request
        holding the EDF head, a deadline-less prio-9 arrival must still (a)
        block the restore of a sealed prio-5 request and (b) exercise its
        preemption right — otherwise it is starved behind everything."""
        cfg, model, params = small_model
        eng = make_engine(model, params, max_slots=1,
                          trust_domain=TrustDomain("tdx"))
        x = eng.submit(gen(np.full(8, 2, np.int32), max_new_tokens=12,
                           priority=5))
        for _ in range(2):
            eng.step()                 # x runs
        top = eng.submit(gen(np.full(8, 3, np.int32), max_new_tokens=3,
                             priority=11))
        eng.step()                     # top preempts x: sealed = {x(5)}
        assert x.n_preemptions == 1
        w_tight = eng.submit(gen(np.full(8, 4, np.int32), max_new_tokens=3,
                                 priority=0, deadline_s=60.0))
        w_high = eng.submit(gen(np.full(8, 5, np.int32), max_new_tokens=3,
                                priority=9))
        eng.run()
        assert all(r.finished for r in (x, top, w_tight, w_high))
        # w_high(9) must not be starved behind the restored x(5)
        assert w_high.t_done < x.t_done
        assert w_high.t_done < w_tight.t_done or w_tight.n_preemptions > 0

    def test_slack_order_aborts_fewer_than_priority_order(self, small_model):
        """Forced contention (1 slot, loose-deadline wave submitted ahead of
        a tight-deadline wave): priority-only ordering serves in arrival
        order and the tight requests die at or past their deadlines; slack
        ordering serves tightest-first and everything meets its deadline."""
        from repro.runtime import stats_from_requests
        cfg, model, params = small_model
        results = {}
        for order in ("slack", "priority"):
            eng = make_engine(model, params, max_slots=1,
                              admission_order=order,
                              trust_domain=TrustDomain("tdx"))
            eng.generate(gen(max_new_tokens=8))          # pay compiles
            t0 = time.monotonic()
            for _ in range(2):
                eng.generate(gen(max_new_tokens=8))
            est = max((time.monotonic() - t0) / 2, 1e-3)  # warm serve time
            # tight_i deadline (2.5 + 1.5i)*est: under EDF it finishes at
            # ~(1+i)*est — headroom up to ~1.8x slowdown after calibration —
            # while under FIFO it cannot even START before ~(3+i)*est and
            # finishes a full serve past its deadline at nominal speed.
            wave = []
            for i in range(3):                           # loose, arrive first
                wave.append(eng.submit(gen(
                    np.full(8, 2 + i, np.int32), max_new_tokens=8,
                    deadline_s=60.0, on_deadline="abort")))
            for i in range(3):                           # tight, arrive later
                wave.append(eng.submit(gen(
                    np.full(8, 10 + i, np.int32), max_new_tokens=8,
                    deadline_s=est * (2.5 + 1.5 * i), on_deadline="abort")))
            eng.run(max_steps=200_000)
            assert all(r.finished for r in wave)
            results[order] = stats_from_requests(wave)
        slack, prio = results["slack"], results["priority"]
        slack_c = slack.aborted_requests + slack.dropped_requests
        prio_c = prio.aborted_requests + prio.dropped_requests
        assert prio_c >= 1, "contention failed to force any deadline kill"
        # the acceptance claim: slack ordering kills strictly fewer
        # deadline-bound requests than priority-only ordering (nominally 0
        # vs 3; the inequality absorbs wall-clock noise in either tail)
        assert slack_c < prio_c, (slack_c, prio_c)
        assert slack.aborted_requests <= prio.aborted_requests
