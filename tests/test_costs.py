"""Cost model: reproduces the paper's Figs 12-13 structure."""

import dataclasses

import pytest

from repro.costs.model import (Workload, best_cpu_cost, crossover_batch,
                               tokens_per_second, usd_per_mtok, vcpu_sweep)
from repro.costs.pricing import SKUS


@pytest.fixture
def w7b():
    return Workload(n_params=6.7e9, batch=1, in_tokens=128, out_tokens=128)


class TestCostModel:
    def test_cpu_tee_cheaper_at_batch_1(self, w7b):
        """Fig 12: CPU TEEs ~2x cheaper than cGPU at batch 1."""
        cpu = best_cpu_cost(w7b, "emr-amx-tdx")
        gpu = usd_per_mtok(w7b, "h100-cc")
        assert gpu / cpu > 1.5

    def test_crossover_exists_and_in_band(self, w7b):
        """Fig 12: cGPU wins somewhere in the tens-to-hundreds batch range."""
        x = crossover_batch(w7b, "emr-amx-tdx", "h100-cc",
                            [1, 2, 4, 8, 16, 32, 64, 128, 256, 512])
        assert x is not None and 16 <= x <= 256

    def test_vcpu_throughput_plateaus(self, w7b):
        """Fig 12: memory-bound beyond ~32 cores -> diminishing returns."""
        w = dataclasses.replace(w7b, batch=64)
        sweep = vcpu_sweep(w, "emr-amx-tdx", [8, 16, 32, 64])
        gain_8_16 = sweep[16]["tokens_per_s"] / sweep[8]["tokens_per_s"]
        gain_32_64 = sweep[64]["tokens_per_s"] / sweep[32]["tokens_per_s"]
        assert gain_8_16 > gain_32_64

    def test_tee_costs_more_than_plain(self, w7b):
        assert (usd_per_mtok(w7b, "emr-amx-tdx", 32)
                > usd_per_mtok(w7b, "emr-amx", 32))
        assert usd_per_mtok(w7b, "h100-cc") >= usd_per_mtok(w7b, "h100")

    def test_input_scaling_erodes_cpu_advantage(self, w7b):
        """Fig 13: larger inputs help the GPU more than the CPU."""
        adv = {}
        for s in [128, 4096]:
            w = dataclasses.replace(w7b, batch=4, in_tokens=s)
            adv[s] = usd_per_mtok(w, "h100-cc") / best_cpu_cost(w, "emr-amx-tdx")
        assert adv[4096] < adv[128] * 1.5  # advantage does not explode with input

    def test_throughput_monotone_in_batch(self, w7b):
        tps = [tokens_per_second(dataclasses.replace(w7b, batch=b), SKUS["h100-cc"])
               for b in [1, 8, 64]]
        assert tps[0] < tps[1] < tps[2]

    def test_tpu_rows_present(self, w7b):
        """Our platform extension: v5e-cc prices a confidential deployment."""
        assert usd_per_mtok(w7b, "v5e-cc") > usd_per_mtok(w7b, "v5e") > 0
