"""Unit tests for the persistent content-addressed sealed-page store.

No engine here: these drive :class:`repro.runtime.pagestore.SealedPageStore`
directly with hand-sealed blobs — the retention policies, the per-key-domain
namespacing, the republish no-op contract, and the restore-vs-recompute
pricing the cost policy scores with. The engine-integrated behavior
(publish on release, MAC-gated restore, admission discounts) lives in
tests/test_differential.py and tests/test_paged_properties.py.
"""

import numpy as np
import pytest

from repro.core.overheads import store_restore_savings
from repro.core.sealing import (IntegrityError, SealingKey, seal_tensor,
                                shared_page_name, unseal_tensor)
from repro.runtime.pagestore import (POLICIES, SealedPageStore, StoreEntry,
                                     _cost, _lru)

KEY_A = SealingKey.generate(b"tenant-a")
KEY_B = SealingKey.generate(b"tenant-b")


def ck(i: int) -> bytes:
    """A distinct 16-byte content key (what prefix_page_keys mints)."""
    return bytes([i]) * 16


def blobs_for(key: SealingKey, content_key: bytes, fill: float = 1.0):
    """One sealed page under the canonical content-derived name — the same
    (name => nonce) binding the paged backend publishes with."""
    data = np.full((4, 8), fill, np.float32)
    return {kp: seal_tensor(key, shared_page_name(content_key, kp), data)
            for kp in ("/l0/k", "/l0/v")}


class TestStoreBasics:
    def test_publish_contains_lookup_roundtrip(self):
        store = SealedPageStore()
        blobs = blobs_for(KEY_A, ck(1), fill=3.0)
        assert store.publish(KEY_A, ck(1), blobs, tokens=8) == []
        assert store.contains(KEY_A, ck(1))
        got = store.lookup(KEY_A, ck(1))
        assert got is blobs
        for kp, st in got.items():
            np.testing.assert_array_equal(
                np.asarray(unseal_tensor(KEY_A, st)),
                np.full((4, 8), 3.0, np.float32))
        assert store.hits == 1 and store.misses == 0
        assert store.resident_pages == 1

    def test_lookup_miss_counts_and_returns_none(self):
        store = SealedPageStore()
        assert store.lookup(KEY_A, ck(9)) is None
        assert store.misses == 1 and store.hits == 0

    def test_republish_is_a_membership_noop(self):
        """Same content key, same domain: the second publish must not
        replace the entry, mint ciphertext, or count as a publish — the
        content-derived name guarantees the bytes are already identical."""
        store = SealedPageStore()
        blobs = blobs_for(KEY_A, ck(1))
        store.publish(KEY_A, ck(1), blobs, tokens=8)
        again = blobs_for(KEY_A, ck(1))   # byte-identical by construction
        assert store.publish(KEY_A, ck(1), again, tokens=8) == []
        assert store.publishes == 1
        assert store.republish_noops == 1
        assert store.lookup(KEY_A, ck(1)) is blobs   # original retained
        # and the caller's re-sealed blobs really were byte-identical:
        for kp in blobs:
            assert bytes(np.asarray(blobs[kp].ciphertext).tobytes()) == \
                bytes(np.asarray(again[kp].ciphertext).tobytes())

    def test_rejects_unknown_policy_and_negative_budget(self):
        with pytest.raises(ValueError, match="unknown store policy"):
            SealedPageStore(policy="fifo")
        with pytest.raises(ValueError, match=">= 0"):
            SealedPageStore(budget_pages=-1)
        assert sorted(POLICIES) == ["cost", "lru"]


class TestRetention:
    def test_lru_evicts_least_recently_touched(self):
        store = SealedPageStore(budget_pages=2, policy="lru")
        store.publish(KEY_A, ck(1), blobs_for(KEY_A, ck(1)), tokens=8)
        store.publish(KEY_A, ck(2), blobs_for(KEY_A, ck(2)), tokens=8)
        store.lookup(KEY_A, ck(1))        # touch 1: now 2 is the LRU victim
        evicted = store.publish(KEY_A, ck(3), blobs_for(KEY_A, ck(3)),
                                tokens=8)
        assert [e.content_key for e in evicted] == [ck(2)]
        assert store.contains(KEY_A, ck(1))
        assert not store.contains(KEY_A, ck(2))
        assert store.evictions == 1 and store.evicted_bytes > 0
        assert store.resident_pages == 2

    def test_cost_policy_sheds_cheap_to_recompute_first(self):
        """An entry whose prefill is free to redo (tokens=0) scores below
        one whose hit saves real recompute — recency does not save it."""
        store = SealedPageStore(budget_pages=2, policy="cost")
        store.publish(KEY_A, ck(1), blobs_for(KEY_A, ck(1)), tokens=64)
        store.publish(KEY_A, ck(2), blobs_for(KEY_A, ck(2)), tokens=0)
        evicted = store.publish(KEY_A, ck(3), blobs_for(KEY_A, ck(3)),
                                tokens=64)
        assert [e.content_key for e in evicted] == [ck(2)], \
            "the worthless (recompute-wins) entry must be the first victim"
        assert store.contains(KEY_A, ck(1))

    def test_cost_chooser_weights_observed_hits(self):
        """Directly on the chooser: a lower-saving entry that keeps hitting
        outranks a higher-saving entry that never does."""
        hot = StoreEntry(ck(1), "d", {}, 1024, 8, hits=9, stamp=1,
                         net_saving_s=1e-4)
        cold = StoreEntry(ck(2), "d", {}, 1024, 64, hits=0, stamp=2,
                          net_saving_s=5e-4)
        assert _cost([hot, cold]) is cold     # (0+1)*5e-4 < (9+1)*1e-4
        assert _lru([hot, cold]) is hot       # recency alone says otherwise
        fresh = StoreEntry(ck(3), "d", {}, 1024, 64, hits=0, stamp=3,
                           net_saving_s=5e-4)
        assert _cost([cold, fresh]) is cold   # equal score: stamp breaks tie

    def test_publish_prices_a_positive_saving_for_real_pages(self):
        store = SealedPageStore(policy="cost", profile="tdx")
        store.publish(KEY_A, ck(1), blobs_for(KEY_A, ck(1)), tokens=64)
        entry = next(iter(store._domains[KEY_A.key_id()].values()))
        assert entry.net_saving_s > 0, \
            "64 prefill tokens must out-cost restoring one sealed page"


class TestDomainIsolation:
    def test_other_domain_is_a_clean_miss_not_a_mac_failure(self):
        store = SealedPageStore()
        store.publish(KEY_A, ck(1), blobs_for(KEY_A, ck(1)), tokens=8)
        assert not store.contains(KEY_B, ck(1))
        assert store.lookup(KEY_B, ck(1)) is None
        assert store.misses == 1
        # and even an offered blob fails MAC under the other domain's key
        blob = next(iter(store.lookup(KEY_A, ck(1)).values()))
        with pytest.raises(IntegrityError):
            unseal_tensor(KEY_B, blob)

    def test_budget_spans_domains_but_entries_do_not(self):
        store = SealedPageStore(budget_pages=2)
        store.publish(KEY_A, ck(1), blobs_for(KEY_A, ck(1)), tokens=8)
        store.publish(KEY_B, ck(1), blobs_for(KEY_B, ck(1)), tokens=8)
        assert store.resident_pages == 2      # same content key, two domains
        assert store.resident_count(KEY_A, [ck(1), ck(2)]) == 1
        evicted = store.publish(KEY_A, ck(2), blobs_for(KEY_A, ck(2)),
                                tokens=8)
        assert len(evicted) == 1              # global budget crosses domains
        assert store.resident_pages == 2
        assert "domains" in store.describe()


class TestRestoreVsRecomputePricing:
    def test_zero_pages_is_the_none_line(self):
        restore, recompute, line = store_restore_savings(0, 0, 0, "tdx")
        assert restore is None and recompute is None
        assert "none" in line

    def test_priced_line_carries_both_sides(self):
        restore, recompute, line = store_restore_savings(
            4, 65536, 256, "tdx")
        assert restore is not None and recompute is not None
        assert restore.t_tee_s > 0 and recompute.t_tee_s > 0
        assert "4 pages" in line and "256 prefill tokens" in line
        assert ("store wins" in line) == \
            (recompute.t_tee_s > restore.t_tee_s)

    def test_breakeven_flips_with_prefill_cost(self):
        """The verdict is a real breakeven, not a constant: make recompute
        nearly free and restore must lose; make it expensive and win."""
        _, _, cheap = store_restore_savings(4, 65536, 4, "tdx",
                                            prefill_token_s=1e-9)
        _, _, dear = store_restore_savings(4, 65536, 4096, "tdx",
                                           prefill_token_s=1e-3)
        assert "recompute wins" in cheap
        assert "store wins" in dear
