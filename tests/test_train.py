"""Training substrate: optimizer, loop, checkpointing, fault tolerance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.data.pipeline import PackedLMDataset
from repro.models import build_model
from repro.train.checkpoint import (CheckpointManager, CorruptCheckpoint,
                                    restore_checkpoint, save_checkpoint)
from repro.train.optimizer import (AdamWConfig, adamw_update, init_opt_state,
                                   lr_schedule, global_norm)
from repro.train.train_loop import (StragglerMonitor, init_train_state,
                                    make_train_step, train_loop)
from repro.distributed.fault_tolerance import (FailureInjector,
                                               run_with_restarts)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    state = init_train_state(model, opt, jax.random.key(0))
    return cfg, model, opt, state


def data_iter(batch_size=4, seq_len=32, seed=0):
    return iter(PackedLMDataset(batch_size=batch_size, seq_len=seq_len, seed=seed))


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=100)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = init_opt_state(cfg, params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.5

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
        assert abs(float(lr_schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
        assert abs(float(lr_schedule(cfg, jnp.asarray(100))) - 0.1) < 1e-3

    def test_grad_clip_bounds_update_norm(self):
        cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
        params = {"w": jnp.zeros((10,))}
        state = init_opt_state(cfg, params)
        big = {"w": jnp.full((10,), 1e6)}
        _, _, metrics = adamw_update(cfg, params, big, state)
        assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        state = init_opt_state(cfg, {"w": jnp.zeros((4,), jnp.float32)})
        assert state.m["w"].dtype == jnp.bfloat16


class TestTrainLoop:
    def test_loss_decreases(self, setup):
        cfg, model, opt, state = setup
        step = make_train_step(model, opt)
        state2, hist = train_loop(model, state, step, data_iter(), num_steps=8)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_microbatching_matches_full_batch_loss(self, setup):
        """Grad accumulation: same data -> nearly identical first-step loss."""
        cfg, model, opt, state = setup
        batch = next(data_iter(batch_size=4))
        s1, m1 = jax.jit(make_train_step(model, opt))(state, batch)
        s2, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(state, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
        # params should end up close
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)))
        assert d < 5e-2

    def test_straggler_monitor(self):
        mon = StragglerMonitor(deadline_s=0.1)
        assert not mon.observe(0, 0.05)
        assert mon.observe(1, 0.5)
        assert mon.straggles == 1


class TestCheckpoint:
    def test_atomic_save_restore(self, setup, tmp_path):
        cfg, model, opt, state = setup
        save_checkpoint(tmp_path, 3, state)
        back = restore_checkpoint(tmp_path, 3, jax.eval_shape(lambda: state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            assert bool(jnp.all(a == b))

    def test_corruption_detected(self, setup, tmp_path):
        cfg, model, opt, state = setup
        path = save_checkpoint(tmp_path, 1, state)
        victim = sorted(path.glob("leaf_*.npy"))[2]
        raw = np.load(victim)
        flat = raw.reshape(-1).copy()
        flat[0] += 1
        np.save(victim, flat.reshape(raw.shape))
        with pytest.raises(CorruptCheckpoint):
            restore_checkpoint(tmp_path, 1, jax.eval_shape(lambda: state))

    def test_sealed_checkpoint_roundtrip_and_key_binding(self, setup, tmp_path):
        cfg, model, opt, state = setup
        td = TrustDomain("tdx")
        mgr = CheckpointManager(tmp_path, trust_domain=td)
        mgr.save(5, state)
        step, back = mgr.resume(jax.eval_shape(lambda: state))
        assert step == 5
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            assert bool(jnp.all(a == b))
        # wrong trust domain key -> integrity failure
        from repro.core.sealing import IntegrityError
        bad = CheckpointManager(tmp_path, trust_domain=TrustDomain("tdx"))
        with pytest.raises(IntegrityError):
            bad.resume(jax.eval_shape(lambda: state))

    def test_retention_gc(self, setup, tmp_path):
        cfg, model, opt, state = setup
        mgr = CheckpointManager(tmp_path, keep_n=2)
        for s in [1, 2, 3, 4]:
            mgr.save(s, {"x": jnp.ones((2,))})
        kept = sorted(p.name for p in tmp_path.glob("step_*"))
        assert kept == ["step_3", "step_4"]


class TestFaultTolerance:
    def test_restart_resume_bitwise_identical(self, setup, tmp_path):
        """Interrupted-and-resumed run == uninterrupted run, loss for loss."""
        cfg, model, opt, state = setup
        step = make_train_step(model, opt)

        def data_factory(cursor):
            ds = PackedLMDataset(batch_size=4, seq_len=32, seed=0)
            it = iter(ds)
            for _ in range(cursor):
                next(it)
            return it

        mgr1 = CheckpointManager(tmp_path / "a")
        _, losses_clean, r0 = run_with_restarts(
            state=state, train_step=step, data_factory=data_factory,
            num_steps=8, manager=mgr1, checkpoint_every=2, injector=None)
        assert r0 == 0

        mgr2 = CheckpointManager(tmp_path / "b")
        inj = FailureInjector(fail_at={3, 6})
        _, losses_faulty, r = run_with_restarts(
            state=state, train_step=step, data_factory=data_factory,
            num_steps=8, manager=mgr2, checkpoint_every=2, injector=inj)
        assert r == 2
        np.testing.assert_allclose(losses_clean, losses_faulty, rtol=1e-6)
