"""Offline-safe facade over ``hypothesis``.

The tier-1 suite must run in containers with no network and no
``hypothesis`` wheel baked in. Property-test modules import ``given``,
``settings`` and ``st`` from here instead of from ``hypothesis`` directly:

  * when hypothesis is installed, this module re-exports the real thing and
    property tests run as usual;
  * when it is missing, ``given`` turns the test into a clean ``pytest.skip``
    (not a collection error), ``settings`` is a no-op decorator, and ``st``
    is a stub whose strategy constructors accept anything and return None.

Only the strategy *constructors* used by this repo's tests need to exist on
the stub; the decorated bodies never execute without hypothesis.
"""

from __future__ import annotations

try:  # pragma: no cover - trivially one branch per environment
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction (st.integers(...), st.lists(...))."""

        def __getattr__(self, name):
            def _make(*args, **kwargs):
                return None
            _make.__name__ = name
            return _make

    st = _StrategyStub()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*_args, **_kwargs):
        def deco(fn):
            # *args-only shim: pytest must not see the strategy parameter
            # names, or it would try to resolve them as fixtures.
            def _skipped(*args, **kwargs):
                pytest.skip("hypothesis not installed (offline shim)")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
