"""Serving runtime: engine, continuous batching, slots, sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import smoke_config
from repro.core import TrustDomain
from repro.models import build_model
from repro.runtime import Engine, GenerationRequest, sampling
from repro.runtime.kvcache import SlotState


def G(prompt, max_new_tokens=32, **kw):
    return GenerationRequest(prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=max_new_tokens, **kw)


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("deepseek-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


class TestEngine:
    def test_batched_equals_sequential(self, small_model):
        cfg, model, params = small_model
        prompts = [np.arange(1, 9, dtype=np.int32),
                   np.arange(9, 1, -1, dtype=np.int32),
                   np.full(8, 5, np.int32)]
        eng = Engine(model, params, max_slots=3, max_len=64, prefill_len=8)
        reqs = [eng.submit(G(p, 5)) for p in prompts]
        eng.run()
        batched = [r.output for r in reqs]
        sequential = []
        for p in prompts:
            e = Engine(model, params, max_slots=1, max_len=64, prefill_len=8)
            sequential.append(e.generate(G(p, 5)).tokens)
        assert batched == sequential

    def test_continuous_refill(self, small_model):
        """More requests than slots: all finish, slots recycled."""
        cfg, model, params = small_model
        eng = Engine(model, params, max_slots=2, max_len=64, prefill_len=8)
        reqs = [eng.submit(G(np.full(8, i + 1, np.int32), 3))
                for i in range(5)]
        stats = eng.run()
        assert stats.total_requests == 5
        assert all(len(r.output) == 3 for r in reqs)

    def test_confidential_engine_same_tokens(self, small_model):
        """TEE mode must not change results — only protect them."""
        cfg, model, params = small_model
        p = np.arange(2, 10, dtype=np.int32)
        plain = Engine(model, params, max_slots=1, max_len=64,
                       prefill_len=8).generate(G(p, 5)).tokens
        conf_eng = Engine(model, params, max_slots=1, max_len=64, prefill_len=8,
                          trust_domain=TrustDomain("tdx"))
        conf = conf_eng.generate(G(p, 5)).tokens
        assert plain == conf
        assert conf_eng.td.channel.stats.messages_in == 1
        # streaming egress: every sampled token leaves as its own frame
        assert conf_eng.td.channel.stats.messages_out == 5

    def test_throughput_latency_stats(self, small_model):
        cfg, model, params = small_model
        eng = Engine(model, params, max_slots=2, max_len=64, prefill_len=8)
        for i in range(3):
            eng.submit(G(np.full(8, i + 1, np.int32), 4))
        stats = eng.run()
        assert stats.total_tokens == 12
        assert stats.throughput_tps > 0
        assert stats.mean_latency_s > 0
        assert stats.p99_latency_s >= stats.mean_latency_s


class TestSlots:
    def test_acquire_release(self):
        s = SlotState.create(2)
        a = s.acquire(100)
        b = s.acquire(101)
        assert {a, b} == {0, 1}
        assert s.acquire(102) is None
        s.release(a)
        assert s.acquire(102) == a

    @given(ops=st.lists(st.tuples(st.booleans(), st.integers(0, 3)),
                        max_size=40))
    @settings(max_examples=25, deadline=None)
    def test_no_double_assignment_property(self, ops):
        s = SlotState.create(4)
        held = set()
        rid = 0
        for is_acquire, slot_hint in ops:
            if is_acquire:
                got = s.acquire(rid)
                rid += 1
                if got is not None:
                    assert got not in held
                    held.add(got)
                else:
                    assert len(held) == 4
            elif held:
                victim = sorted(held)[slot_hint % len(held)]
                s.release(victim)
                held.remove(victim)
        assert s.num_active == len(held)


class TestSampling:
    def test_greedy(self):
        logits = jnp.asarray([[0.0, 5.0, 1.0], [9.0, 0.0, 0.0]])
        assert sampling.greedy(logits).tolist() == [1, 0]

    def test_temperature_zero_is_greedy(self):
        logits = jax.random.normal(jax.random.key(0), (4, 16))
        t0 = sampling.temperature(logits, jax.random.key(1), temp=0.0)
        assert t0.tolist() == sampling.greedy(logits).tolist()

    def test_top_k_restricts_support(self):
        logits = jnp.asarray([[10.0, 9.0, -5.0, -6.0]] * 64)
        keys = jax.random.split(jax.random.key(2), 64)
        toks = jnp.stack([sampling.temperature(logits[i:i + 1], keys[i], 1.0, top_k=2)[0]
                          for i in range(64)])
        assert set(np.asarray(toks).tolist()) <= {0, 1}


class TestSealedPreemption:
    def test_seal_restore_slot_preserves_generation(self, small_model):
        """Preempt a running request (sealed KV eviction), restore it, and
        the final output must equal the uninterrupted run."""
        cfg, model, params = small_model
        from repro.core import TrustDomain
        prompt = np.arange(1, 9, dtype=np.int32)
        # uninterrupted reference
        ref = Engine(model, params, max_slots=1, max_len=64,
                     prefill_len=8).generate(G(prompt, 8)).tokens
        # interrupted run: 3 tokens, seal out, restore, finish
        eng = Engine(model, params, max_slots=1, max_len=64, prefill_len=8,
                     trust_domain=TrustDomain("tdx"))
        req = eng.submit(G(prompt, 8))
        for _ in range(3):
            eng.step()
        sealed, evicted = eng.seal_slot(0)
        assert eng.slots.num_active == 0
        eng.restore_slot(sealed, evicted)
        eng.run()
        out = list(eng.td.egress(np.asarray(req.output, np.int32)))
        # outputs recorded pre-egress are plaintext already in this path
        assert req.output == ref

    def test_sealed_slot_rejects_tampering(self, small_model):
        cfg, model, params = small_model
        from repro.core import TrustDomain
        from repro.core.sealing import IntegrityError
        eng = Engine(model, params, max_slots=1, max_len=64, prefill_len=8,
                     trust_domain=TrustDomain("tdx"))
        req = eng.submit(G(np.arange(1, 9, dtype=np.int32), 6))
        eng.step()
        sealed, evicted = eng.seal_slot(0)
        victim = next(iter(sealed.values()))
        ct = np.asarray(victim.ciphertext).copy()
        ct[0, 0] ^= 1
        victim.ciphertext = jnp.asarray(ct)
        with pytest.raises(IntegrityError):
            eng.restore_slot(sealed, evicted)
