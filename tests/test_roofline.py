"""Roofline extraction: HLO collective parsing, term math, dry-run path."""

import json
from pathlib import Path

import pytest

from repro.core.overheads import RooflineTerms
from repro.roofline.analysis import (CellRoofline, HBM_BW, PEAK_FLOPS,
                                     _shape_bytes, model_flops_for,
                                     parse_collectives)
from repro.configs import SHAPES, get_config

RESULTS = Path(__file__).resolve().parents[1] / "results"


class TestHLOParsing:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[32,256]") == 32 * 256 * 4
        assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
        assert _shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8
        assert _shape_bytes("pred[16]") == 16

    def test_parse_synthetic_hlo(self):
        hlo = """
  %all-reduce.1 = f32[32,256]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = bf16[64,64]{1,0} all-gather(%x), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
  %rs = f32[8,8]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[1,8]<=[8], to_apply=%add
  %cp = f32[16]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
"""
        ops = parse_collectives(hlo)
        kinds = {o.kind for o in ops}
        assert kinds == {"all-reduce", "all-gather", "reduce-scatter",
                         "collective-permute"}
        ar = next(o for o in ops if o.kind == "all-reduce")
        assert ar.group_size == 4
        assert ar.moved_bytes == 32 * 256 * 4 * 2 * 3 / 4  # 2(s-1)/s factor
        rs = next(o for o in ops if o.kind == "reduce-scatter")
        assert rs.moved_bytes == 8 * 8 * 4 * 7  # (s-1) * result

    def test_done_ops_not_double_counted(self):
        hlo = """
  %ag0 = bf16[64]{0} all-gather-start(%x), channel_id=1, replica_groups=[4,2]<=[8]
  %ag1 = bf16[64]{0} all-gather-done(%ag0)
"""
        ops = parse_collectives(hlo)
        assert len(ops) == 1


class TestTermMath:
    def _cell(self, **kw):
        base = dict(arch="a", shape="s", mesh="16x16", n_chips=256,
                    flops_per_dev=1e12, bytes_per_dev=1e9,
                    collective_bytes_per_dev=1e8, collective_breakdown={},
                    arg_bytes=10**9, temp_bytes=10**9, out_bytes=0,
                    model_flops=2e14)
        base.update(kw)
        return CellRoofline(**base)

    def test_terms(self):
        c = self._cell()
        assert abs(c.compute_s - 1e12 / PEAK_FLOPS) < 1e-12
        assert abs(c.memory_s - 1e9 / HBM_BW) < 1e-12
        assert c.bound == "compute"
        assert 0 < c.roofline_fraction <= 1.0

    def test_fits_hbm(self):
        assert self._cell().fits_hbm
        assert not self._cell(temp_bytes=17 * 1024**3).fits_hbm

    def test_bw_fraction_decode_metric(self):
        c = self._cell(flops_per_dev=1e9, bytes_per_dev=2e9, arg_bytes=10**9)
        assert 0 < c.bw_fraction <= 1.0


class TestModelFlops:
    def test_train_vs_decode_scale(self):
        cfg = get_config("deepseek-7b")
        tr = model_flops_for(cfg, SHAPES["train_4k"])
        de = model_flops_for(cfg, SHAPES["decode_32k"])
        # train: 6*N*B*S; decode: 2*N*B  -> ratio 3*S*(256/128)
        assert tr / de == pytest.approx(3 * 4096 * 256 / 128, rel=0.01)

    def test_moe_uses_active_params(self):
        cfg = get_config("deepseek-v3-671b")
        total, active = cfg.params_count()
        assert active < 0.1 * total  # 37B active of 671B
        assert model_flops_for(cfg, SHAPES["decode_32k"]) == 2.0 * active * 128


@pytest.mark.skipif(not (RESULTS / "dryrun_single_pod.json").exists(),
                    reason="dry-run results not generated")
class TestDryRunResults:
    """Validates the committed dry-run sweeps (deliverable e)."""

    def _load(self, name):
        return json.loads((RESULTS / name).read_text())

    @pytest.mark.parametrize("fname", ["dryrun_single_pod.json",
                                       "dryrun_multi_pod.json"])
    def test_all_cells_compiled(self, fname):
        recs = self._load(fname)
        archs = {r["arch"] for r in recs}
        assert len(archs) == 10
        assert sum(1 for r in recs if "error" in r) == 0
        # 40 cells: 32 lowered + 8 documented skips
        assert len(recs) == 40
        skips = [r for r in recs if r.get("skipped")]
        assert len(skips) == 8
        assert all(r["shape"] == "long_500k" for r in skips)

    def test_multi_pod_uses_512_chips(self):
        recs = self._load("dryrun_multi_pod.json")
        lowered = [r for r in recs if not r.get("skipped")]
        assert all(r["n_chips"] == 512 for r in lowered)

    def test_terms_present_and_positive(self):
        recs = self._load("dryrun_single_pod.json")
        for r in recs:
            if r.get("skipped"):
                continue
            assert r["compute_s"] > 0 and r["memory_s"] > 0
            assert r["bound"] in ("compute", "memory", "collective")
