"""Distributed layer: sharding parity, overlap collectives, pipeline,
compression, elastic rescale. Multi-device tests run in subprocesses with
forced host device counts so this process keeps its single-device view."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

# the subprocess-based classes below each jit-compile in an 8-device
# subprocess and carry pytest.mark.slow; TestCompression runs in-process
# and stays in the fast tier.
_slow = pytest.mark.slow

from repro.distributed.compression import (compress_decompress,
                                           compressed_bytes,
                                           make_grad_compressor)


class TestCompression:
    @given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 10.0))
    @settings(max_examples=15, deadline=None)
    def test_error_feedback_bounded_bias(self, seed, scale):
        """With error feedback, accumulated compressed grads track the true
        accumulation (residual never grows unboundedly)."""
        err = jnp.zeros((128,))
        acc_t = jnp.zeros((128,))
        acc_c = jnp.zeros((128,))
        for i in range(30):
            g = jax.random.normal(jax.random.key(seed * 100 + i), (128,)) * scale
            acc_t += g
            deq, err = compress_decompress(g, err)
            acc_c += deq
        # residual bounded by one quantization step of the last grad
        denom = float(jnp.linalg.norm(acc_t)) + 1e-9
        assert float(jnp.linalg.norm(acc_c - acc_t)) / denom < 0.05

    def test_wire_bytes_4x_smaller(self):
        grads = {"a": jnp.zeros((1024, 1024), jnp.float32)}
        raw, wire = compressed_bytes(grads)
        assert raw / wire > 3.9

    def test_transform_stateful(self):
        tr, get_state = make_grad_compressor()
        g = {"w": jnp.asarray([0.001, 0.5, -0.3])}
        out = tr(g)
        assert get_state() is not None
        assert out["w"].shape == (3,)


class TestShardingRules:
    pytestmark = _slow
    def test_specs_cover_all_archs(self, subproc):
        out = subproc("""
import jax
from jax.sharding import PartitionSpec as P
from repro.configs import smoke_config
from repro.models import build_model
from repro.distributed import sharding
from repro.distributed.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
for name in ["deepseek-7b", "qwen3-32b", "rwkv6-3b", "dbrx-132b",
             "deepseek-v3-671b", "jamba-v0.1-52b", "chameleon-34b",
             "whisper-small", "mistral-nemo-12b", "deepseek-67b"]:
    cfg = smoke_config(name)
    m = build_model(cfg)
    specs = sharding.param_specs(cfg, m.abstract_params(), mesh)
    for path, s in jax.tree_util.tree_flatten_with_path(specs)[0]:
        assert isinstance(s, P), (name, path)
    c = sharding.cache_specs(cfg, m.abstract_cache(4, 16), mesh)
print("OK")
""", devices=8)
        assert "OK" in out

    def test_sharded_train_step_matches_single_device(self, subproc):
        out = subproc("""
import jax, jax.numpy as jnp
from repro.configs import smoke_config
from repro.models import build_model
from repro.distributed import sharding
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step, abstract_train_state
from repro.distributed.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = smoke_config("qwen3-32b")
m = build_model(cfg)
opt = AdamWConfig(lr=1e-3)
state = init_train_state(m, opt, jax.random.key(0))
batch = {"tokens": jnp.ones((4, 32), jnp.int32), "labels": jnp.ones((4, 32), jnp.int32)}
step = make_train_step(m, opt)
s1, m1 = jax.jit(step)(state, batch)
sspecs = sharding.state_specs(cfg, abstract_train_state(m, opt), mesh)
bspecs = sharding.batch_specs(cfg, jax.eval_shape(lambda: batch), mesh)
with mesh:
    f = jax.jit(step, in_shardings=(sharding.to_named(mesh, sspecs),
                                    sharding.to_named(mesh, bspecs)),
                out_shardings=(sharding.to_named(mesh, sspecs), None))
    s2, m2 = f(state, batch)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"], m2["loss"])
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)))
assert d < 2e-2, d
print("OK")
""", devices=8)
        assert "OK" in out


class TestOverlap:
    pytestmark = _slow
    def test_ring_collective_matmuls(self, subproc):
        out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.compat import make_mesh, shard_map
from repro.distributed.overlap import all_gather_matmul, matmul_reduce_scatter
mesh = make_mesh((8,), ("model",))
x = jax.random.normal(jax.random.key(1), (64, 32))
w = jax.random.normal(jax.random.key(2), (32, 48))
y = shard_map(lambda a, b: all_gather_matmul(a, b, "model"), mesh=mesh,
              in_specs=(P("model", None), P(None, None)),
              out_specs=P(None, None), check_vma=False)(x, w)
assert jnp.allclose(y, x @ w, atol=1e-4)
xk = jax.random.normal(jax.random.key(3), (64, 128))
wk = jax.random.normal(jax.random.key(4), (128, 48))
y2 = shard_map(lambda a, b: matmul_reduce_scatter(a, b, "model"), mesh=mesh,
               in_specs=(P(None, "model"), P("model", None)),
               out_specs=P("model", None), check_vma=False)(xk, wk)
assert jnp.allclose(y2, xk @ wk, atol=1e-3)
print("OK")
""", devices=8)
        assert "OK" in out


class TestPipeline:
    pytestmark = _slow
    def test_gpipe_matches_sequential_and_trains(self, subproc):
        out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.pipeline import make_gpipe
S, d = 4, 16
from repro.distributed.compat import make_mesh
mesh = make_mesh((4, 2), ("pipe", "data"))
ws = jax.random.normal(jax.random.key(5), (S, d, d)) * 0.3
stage = lambda w, x: jnp.tanh(x @ w)
pipe = make_gpipe(mesh, "pipe", stage, P("pipe", None, None),
                  P(None, None, None), P(None, None, None))
mb = jax.random.normal(jax.random.key(6), (6, 8, d))
out = pipe(ws, mb)
ref = mb
for i in range(S):
    ref = jnp.tanh(ref @ ws[i])
assert jnp.allclose(out, ref, atol=1e-4)
g = jax.grad(lambda w: jnp.sum(pipe(w, mb) ** 2))(ws)
gr = jax.grad(lambda w: jnp.sum(
    jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(mb @ w[0]) @ w[1]) @ w[2]) @ w[3]) ** 2))(ws)
assert jnp.allclose(g, gr, atol=1e-3), float(jnp.max(jnp.abs(g - gr)))
print("OK")
""", devices=8)
        assert "OK" in out


class TestElastic:
    pytestmark = _slow
    def test_save_mesh_a_restore_mesh_b(self, subproc, tmp_path):
        out = subproc(f"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import smoke_config
from repro.models import build_model
from repro.distributed import sharding
from repro.train.checkpoint import save_checkpoint, restore_checkpoint
cfg = smoke_config("deepseek-7b")
m = build_model(cfg)
params = m.init_params(jax.random.key(0))
from repro.distributed.compat import make_mesh
mesh_a = make_mesh((2, 4), ("data", "model"))
specs_a = sharding.to_named(mesh_a, sharding.param_specs(cfg, m.abstract_params(), mesh_a))
pa = jax.tree.map(jax.device_put, params, specs_a)
save_checkpoint(r"{tmp_path}", 1, pa)
# "rescale": restore onto a differently-shaped mesh
mesh_b = make_mesh((4, 2), ("data", "model"))
specs_b = sharding.to_named(mesh_b, sharding.param_specs(cfg, m.abstract_params(), mesh_b))
pb = restore_checkpoint(r"{tmp_path}", 1, jax.eval_shape(lambda: params), shardings=specs_b)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(pb)):
    assert bool(jnp.all(a == b))
print("OK")
""", devices=8)
        assert "OK" in out
