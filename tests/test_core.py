"""Confidential core: sealing, attestation, bounce buffers, overhead model."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import (
    AttestationError, BounceBuffer, IntegrityError, PROFILES, RooflineTerms,
    SealingKey, TrustDomain, predict, seal_tensor, unseal_tensor,
)
from repro.core.overheads import sweep_batch


class TestSealing:
    @pytest.mark.parametrize("dtype,shape", [
        (np.float32, (10, 100)), (np.int8, (1000,)), (np.uint32, (3, 5, 7)),
        (np.float32, ()), ("bfloat16", (64, 64)),
    ])
    def test_roundtrip(self, dtype, shape):
        key = SealingKey.generate(b"test-seed")
        if dtype == "bfloat16":
            arr = jnp.ones(shape, jnp.bfloat16) * 1.5
        else:
            arr = jnp.asarray(np.random.default_rng(0).random(shape).astype(dtype)
                              if np.dtype(dtype).kind == "f"
                              else np.ones(shape, dtype))
        sealed = seal_tensor(key, "t", arr)
        back = unseal_tensor(key, sealed)
        assert back.dtype == arr.dtype and back.shape == arr.shape
        assert bool(jnp.all(back == arr))

    def test_tamper_detection_ciphertext(self):
        key = SealingKey.generate(b"k")
        sealed = seal_tensor(key, "w", jnp.arange(100, dtype=jnp.float32))
        ct = np.asarray(sealed.ciphertext).copy()
        ct[5, 17] ^= 1
        sealed.ciphertext = jnp.asarray(ct)
        with pytest.raises(IntegrityError):
            unseal_tensor(key, sealed)

    def test_tamper_detection_header(self):
        key = SealingKey.generate(b"k")
        sealed = seal_tensor(key, "w", jnp.arange(100, dtype=jnp.float32))
        sealed.shape = (50,)  # metadata tamper
        with pytest.raises(IntegrityError):
            unseal_tensor(key, sealed)

    def test_wrong_key_rejected(self):
        sealed = seal_tensor(SealingKey.generate(b"a"), "w",
                             jnp.ones((8,), jnp.float32))
        with pytest.raises(IntegrityError):
            unseal_tensor(SealingKey.generate(b"b"), sealed)

    def test_per_tensor_nonces_differ(self):
        """Same plaintext, different tensor names -> different ciphertext."""
        key = SealingKey.generate(b"k")
        x = jnp.ones((256,), jnp.float32)
        c1 = seal_tensor(key, "a", x).ciphertext
        c2 = seal_tensor(key, "b", x).ciphertext
        assert not bool(jnp.all(c1 == c2))

    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 2000))
    @settings(max_examples=10, deadline=None)
    def test_roundtrip_property(self, seed, n):
        rng = np.random.default_rng(seed)
        arr = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        key = SealingKey.generate(seed.to_bytes(4, "little"))
        assert bool(jnp.all(unseal_tensor(key, seal_tensor(key, "x", arr)) == arr))


class TestAttestation:
    def _domain(self):
        td = TrustDomain("tdx")
        td.seal_params({"w": jnp.ones((4, 4), jnp.float32)})
        return td

    def test_quote_verifies_and_releases_key(self):
        td = self._domain()
        v = td.make_verifier("cfg")
        nonce = v.challenge()
        q = td.quote(nonce, "cfg")
        released = v.release_key(q, td.sealing_key.key)
        assert released == td.sealing_key.key

    def test_replay_rejected(self):
        td = self._domain()
        v = td.make_verifier("cfg")
        nonce = v.challenge()
        q = td.quote(nonce, "cfg")
        v.verify(q)
        with pytest.raises(AttestationError):
            v.verify(q)

    def test_measurement_binds_model(self):
        """Different sealed model -> different measurement -> rejected."""
        td = self._domain()
        v = td.make_verifier("cfg")
        td.seal_params({"w": jnp.zeros((4, 4), jnp.float32)})  # swap model
        nonce = v.challenge()
        with pytest.raises(AttestationError):
            v.verify(td.quote(nonce, "cfg"))

    def test_config_binds_measurement(self):
        td = self._domain()
        v = td.make_verifier("cfg-A")
        nonce = v.challenge()
        with pytest.raises(AttestationError):
            v.verify(td.quote(nonce, "cfg-B"))

    def test_forged_quote_rejected(self):
        td = self._domain()
        v = td.make_verifier("cfg")
        nonce = v.challenge()
        q = td.quote(nonce, "cfg")
        forged = dataclasses.replace(q, signature="00" * 32)
        with pytest.raises(AttestationError):
            v.verify(forged)


class TestBounce:
    def test_roundtrip_and_stats(self):
        bb = BounceBuffer(SealingKey.generate(b"io"))
        toks = np.arange(100, dtype=np.int32)
        out, sealed = bb.roundtrip(toks)
        assert np.array_equal(out, toks)
        assert bb.stats.messages_in == 1 and bb.stats.bytes_in >= 400
        # ciphertext on the wire differs from the plaintext bytes
        assert not np.array_equal(
            np.asarray(sealed.ciphertext).ravel()[:25].astype(np.int64),
            toks[:25].astype(np.int64))

    def test_sequence_numbers_make_unique_ciphertexts(self):
        bb = BounceBuffer(SealingKey.generate(b"io"))
        t = np.ones(64, np.int32)
        s1 = bb.host_send(t)
        s2 = bb.host_send(t)
        assert not bool(np.array_equal(np.asarray(s1.ciphertext),
                                       np.asarray(s2.ciphertext)))


class TestOverheadModel:
    def test_all_profiles_positive(self):
        t = RooflineTerms(compute_s=0.01, memory_s=0.04, collective_s=0.001)
        for name in PROFILES:
            assert predict(t, name).overhead > 0

    def test_memory_bound_worse_than_compute_bound_tdx(self):
        """Insight 9: TDX overhead is lowest when compute-bound."""
        mem_bound = RooflineTerms(compute_s=0.01, memory_s=0.09)
        comp_bound = RooflineTerms(compute_s=0.09, memory_s=0.01)
        assert (predict(mem_bound, "tdx").overhead
                > predict(comp_bound, "tdx").overhead)

    def test_batch_sweep_overhead_decreases(self):
        """Fig 9/11 shape: overhead monotonically falls as batch grows."""
        ovs = sweep_batch("tdx", compute_per_token_s=1e-4, memory_s=0.04,
                          batches=[1, 8, 64, 512])
        vals = list(ovs.values())
        assert all(a >= b for a, b in zip(vals, vals[1:]))

    def test_numa_and_hugepages_penalties(self):
        """Fig 5/6 + Insight 7: broken placement costs real performance."""
        t = RooflineTerms(compute_s=0.01, memory_s=0.05)
        base = predict(t, "tdx").overhead
        no_numa = predict(t, "tdx", numa_bound=False).overhead
        no_huge = predict(t, "tdx", hugepages_fixed=False).overhead
        assert no_numa > base and no_huge > base
        # SGX multi-socket catastrophe (~230%)
        sgx_numa = predict(t, "sgx", numa_bound=False).overhead
        assert sgx_numa > 1.0

    def test_paper_calibration_bands(self):
        """Single-socket inference-like terms land in the paper's bands."""
        t = RooflineTerms(compute_s=0.012, memory_s=0.045, collective_s=0.002)
        assert 0.04 < predict(t, "tdx").overhead < 0.12      # 5.51-10.68%
        assert 0.03 < predict(t, "sgx").overhead < 0.09      # 4.80-6.15%
        assert 0.01 < predict(t, "vm").overhead < 0.06       # 1.82-5.38%
        # cGPU at GPU-scale step times: 4.4-8%
        tg = RooflineTerms(compute_s=0.002, memory_s=0.0045, collective_s=0.0)
        assert 0.03 < predict(tg, "cgpu").overhead < 0.10


class TestTrustDomain:
    def test_non_confidential_passthrough(self):
        td = TrustDomain("none")
        toks = np.arange(10, dtype=np.int32)
        assert td.ingress(toks) is toks
        assert td.predict_overhead(RooflineTerms(0.1, 0.1)) is None

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            TrustDomain("sgx2")

    def test_audit_log_records_boundary_crossings(self):
        td = TrustDomain("tdx")
        td.seal_params({"w": jnp.ones((4,), jnp.float32)})
        td.ingress(np.ones(4, np.int32))
        kinds = [e.kind for e in td.audit]
        assert "seal" in kinds and "ingress" in kinds
