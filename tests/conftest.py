import os
import subprocess
import sys
from collections import Counter
from pathlib import Path

import numpy as np
import pytest

SRC = Path(__file__).resolve().parents[1] / "src"
sys.path.insert(0, str(SRC))

# tests run on CPU with exactly TWO forced host devices (set before jax
# initializes): the differential harness replays its canonical scenario on
# an in-process dp=2 mesh. Single-device engines still place everything on
# device 0, so non-mesh tests are unaffected. Subprocess-based multi-device
# tests override XLA_FLAGS themselves.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2"
                               ).strip()


def run_subprocess(code: str, devices: int = 8, timeout: int = 520) -> str:
    """Run a python snippet with N forced host devices (for multi-device
    tests, which must not pollute this process's jax device state)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess


# ---------------------------------------------------------------------------
# cross-backend differential harness
#
# ONE canonical serving scenario — mixed priorities, forced preemption,
# seeded sampling, chunked prefill, and (where the backend supports it)
# shared prompt prefixes with a partial page that exercises copy-on-write —
# replayed verbatim over every backend configuration. Decoded tokens are a
# pure function of (prompt, sampling params, seed), so every configuration
# must produce byte-identical outputs, each equal to the request served
# alone on an uncontended engine. Backend-specific tests add their own
# assertions (sealed-byte ordering, shared-page counters, pool invariants)
# on top of the same run.
# ---------------------------------------------------------------------------

CANONICAL_CONFIGS = {
    "slot": dict(kv_backend="slot"),
    "paged": dict(kv_backend="paged", page_size=8),
    "paged-sharing": dict(kv_backend="paged", page_size=8,
                          prefix_sharing=True),
    # table-walking Pallas decode kernel: attention is numerically close
    # to the gather reference (f32 online softmax), not bitwise — decoded
    # tokens must still agree at the canonical operating point.
    "paged-kernel": dict(kv_backend="paged", page_size=8,
                         kv_decode="kernel"),
    "sharded-dp2": dict(kv_backend="slot", mesh="dp=2"),
    # two-phase serving: step-level continuous batching (single plan,
    # per-step token budget) and disaggregated prefill (dedicated prefill
    # plan + sealed plan-to-plan KV handoff) — same byte-identity contract.
    "slot-cb": dict(kv_backend="slot", continuous_batching=True),
    "paged-cb": dict(kv_backend="paged", page_size=8,
                     continuous_batching=True),
    "slot-2plan": dict(kv_backend="slot", prefill_plan="dedicated"),
    "paged-2plan": dict(kv_backend="paged", page_size=8,
                        prefill_plan="dedicated"),
    # persistent sealed-page store behind the content index: released
    # full pages are retained as ciphertext and recurring prompts restore
    # them (MAC-verified) instead of re-prefilling — same byte-identity
    # contract across preemption and rerun.
    "paged-store": dict(kv_backend="paged", page_size=8,
                        prefix_sharing=True, page_store=True),
}

# engine shape shared by every configuration (2 slots => the high wave must
# preempt; bucket 4 < page_size 8 => the shared prompt page is partial and
# the first decode append copies-on-write under sharing)
CANONICAL_ENGINE = dict(max_slots=2, max_len=64, prefill_buckets=(4, 8))


def canonical_requests():
    """(prompt, max_new_tokens, priority, seed) for the low wave and the
    preempting high wave. Requests 0 and 1 share a 4-token prompt that only
    part-fills its page (bucket 4 < page 8): batched admission maps both
    onto one partial shared page and the first append copies-on-write.
    Requests 2 and 3 share a full 8-token prompt page (overlapping but not
    batch-simultaneous admission). Request 4 chunks past the largest
    bucket. All lows share priority 0 so admission runs in rid order and
    the p4 pair lands in one batched prefill group — the configuration
    that maps one partial page into two tables at once."""
    p8 = np.arange(1, 9, dtype=np.int32)
    p4 = np.arange(1, 5, dtype=np.int32)
    low = [
        (p4, 8, 0, 100),
        (p4.copy(), 5, 0, 101),
        (p8, 8, 0, 102),
        (p8.copy(), 6, 0, 103),
        (np.arange(1, 13, dtype=np.int32), 6, 0, 104),
    ]
    high = [
        (np.full(8, 7, np.int32), 4, 5, 105),
        (np.full(8, 9, np.int32), 3, 5, 106),
    ]
    return low, high


def _gen(spec):
    from repro.runtime import GenerationRequest, SamplingParams
    prompt, mnt, prio, seed = spec
    return GenerationRequest(prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=mnt, priority=prio,
                             params=SamplingParams(temperature=0.9, top_k=16,
                                                   seed=seed))


def run_canonical_scenario(model, params, **engine_kw):
    """Replay the canonical scenario on one engine configuration. Returns
    (outputs in submission order, engine, TrustDomain) — the engine is
    post-run, so callers can read backend counters and check invariants."""
    from repro.core import TrustDomain
    from repro.runtime import Engine
    td = TrustDomain("tdx")
    kw = dict(CANONICAL_ENGINE)
    kw.update(engine_kw)
    eng = Engine(model, params, trust_domain=td, **kw)
    low_specs, high_specs = canonical_requests()
    reqs = [eng.submit(_gen(s)) for s in low_specs]
    for _ in range(3):
        eng.step()
    reqs += [eng.submit(_gen(s)) for s in high_specs]
    stats = eng.run(max_steps=50_000)
    assert all(r.finished for r in reqs), "scenario did not drain"
    assert stats.preemptions > 0, \
        "the canonical scenario must force sealed preemption"
    return [list(r.output) for r in reqs], eng, td


def burst_requests():
    """A burst of long prompts (each chunking past the largest bucket)
    arriving just ahead of short ones — the TTFT operating point step-level
    continuous batching and disaggregated prefill exist for. All one
    priority so ordering is purely arrival, all seeded so every mode must
    reproduce the same bytes."""
    longs = [(np.arange(1, 13, dtype=np.int32) + i, 6, 0, 200 + i)
             for i in range(3)]
    shorts = [(np.arange(1, 4, dtype=np.int32) + i, 5, 0, 300 + i)
              for i in range(3)]
    return longs + shorts


def run_burst_scenario(model, params, **engine_kw):
    """Replay the long-prompt burst on one engine configuration. Returns
    (outputs in submission order, engine, TrustDomain)."""
    from repro.core import TrustDomain
    from repro.runtime import Engine
    td = TrustDomain("tdx")
    kw = dict(CANONICAL_ENGINE)
    kw.update(engine_kw)
    eng = Engine(model, params, trust_domain=td, **kw)
    reqs = [eng.submit(_gen(s)) for s in burst_requests()]
    eng.run(max_steps=50_000)
    assert all(r.finished for r in reqs), "burst scenario did not drain"
    return [list(r.output) for r in reqs], eng, td


@pytest.fixture(params=sorted(CANONICAL_CONFIGS), scope="session")
def backend_config(request):
    """(name, engine kwargs) for each backend configuration under test."""
    return request.param, dict(CANONICAL_CONFIGS[request.param])


def make_sharing_engine(model, params, **kw):
    """The one prefix-sharing engine shape the suites drive (page 8 >
    bucket 8 prompts => whole-page sharing; override prefill_buckets for
    partial-page/CoW shapes)."""
    from repro.core import TrustDomain
    from repro.runtime import Engine
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_len", 8)
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 8)
    kw.setdefault("prefix_sharing", True)
    kw.setdefault("trust_domain", TrustDomain("tdx"))
    return Engine(model, params, **kw)


def check_pool_invariants(kv) -> None:
    """The paged pool's structural invariants, checkable at any engine-step
    boundary: no leaked or double-freed pages, the null scratch page never
    mapped or freed, refcounts equal to live table mappings, a consistent
    two-way content index, and parked ciphertext only while sealed
    references remain."""
    inner = getattr(kv, "inner", kv)   # unwrap ShardedKVBackend
    if not hasattr(inner, "table"):
        return                         # slot-dense: nothing paged to check
    mapped = []
    for slot in range(inner.max_slots):
        n = int(inner._alloc[slot])
        assert (inner.table[slot, n:] == 0).all(), \
            f"slot {slot}: mappings past its allocation"
        pages = [int(p) for p in inner.table[slot, :n]]
        assert 0 not in pages, f"slot {slot} mapped the null scratch page"
        mapped.extend(pages)
    free = [int(p) for p in inner._free_pages]
    assert 0 not in free, "null scratch page leaked into the free list"
    assert len(set(free)) == len(free), "double-free: duplicate free pages"
    assert not set(free) & set(mapped), "page both free and mapped"
    assert len(free) + len(set(mapped)) == inner.num_pages, \
        "page leak: free + mapped != pool"
    counts = Counter(mapped)
    for p in range(1, inner.num_pages + 1):
        assert int(inner._page_ref[p]) == counts.get(p, 0), \
            f"page {p}: refcount {int(inner._page_ref[p])} != " \
            f"{counts.get(p, 0)} live mappings"
    assert len(inner._index) == len(inner._page_key)
    for key, p in inner._index.items():
        assert inner._page_key.get(p) == key, "content index out of sync"
        assert counts.get(p, 0) >= 1, "indexed page has no live mapping"
    for key in inner._parked:
        assert inner._sealed_refs.get(key, 0) > 0, \
            "parked ciphertext outlived every sealed reference"
    store = getattr(inner, "page_store", None)
    if store is not None and store.budget_pages is not None:
        assert store.resident_pages <= store.budget_pages, \
            "sealed-page store exceeded its retention budget"
    if not inner.on_demand:
        reserved = int(inner._reserved.sum())
        assert inner._reserve_free + reserved == inner.num_pages, \
            "reservation accounting leak"
        assert (inner._alloc <= inner._reserved).all(), \
            "allocation exceeded reservation"
