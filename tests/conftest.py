import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parents[1] / "src"
sys.path.insert(0, str(SRC))

# tests must see exactly 1 device (the dry-run sets its own flags in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def run_subprocess(code: str, devices: int = 8, timeout: int = 520) -> str:
    """Run a python snippet with N forced host devices (for multi-device
    tests, which must not pollute this process's jax device state)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
