"""Render the dry-run/roofline result JSONs as the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun_single_pod.json
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.1f}"


def table(path: str) -> str:
    recs = json.loads(Path(path).read_text())
    out = ["| arch | shape | bound | compute_s | memory_s | collective_s | "
           "roofline_frac | bw_frac | useful_FLOPs | HBM GiB/dev | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | SKIP — {r['reason']} "
                       "| | | | | | | | |")
            continue
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        bw_frac = r.get("bw_fraction") or (r["arg_bytes"] / 819e9 / step
                                           if step else 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['bound']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['roofline_fraction']:.3f} "
            f"| {bw_frac:.3f} | {r['useful_flops_ratio']:.2f} "
            f"| {fmt_bytes(r['hbm_bytes_per_dev'])} "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(out)


def perf_table(path: str) -> str:
    recs = json.loads(Path(path).read_text())
    out = ["| cell | variant | bound | compute_s | memory_s | collective_s | "
           "temp GiB | fits | useful |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        out.append(
            f"| {r['arch']} x {r['shape']} | {r.get('variant', '?')} "
            f"| {r['bound']} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['temp_bytes'] / 2**30:.1f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} "
            f"| {r['useful_flops_ratio']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    for p in sys.argv[1:]:
        print(f"### {p}\n")
        print(perf_table(p) if "perf" in p else table(p))
        print()
