"""Roofline extraction from compiled dry-run artifacts.

Terms (per device, TPU v5e constants from the brief):
    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = link_bytes / ICI_bw             (~50 GB/s/link)

``compiled.cost_analysis()`` and ``memory_analysis()`` are per-device
(post-SPMD) — verified empirically. Collective bytes are parsed from the
compiled HLO: for each all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute we take the RESULT shape and convert to bytes moved per
device with the standard ring factors:

    all-gather          result x (s-1)/s
    all-reduce          2 x result x (s-1)/s
    reduce-scatter      result x (s-1)          (operand = result x s)
    all-to-all          result x (s-1)/s
    collective-permute  result

where s = replica-group size parsed from the op. DCN-spanning groups (the
``pod`` axis) are those whose group size exceeds one pod's chip count along
participating axes; we report total link bytes (single-pod roofline is the
graded table; multi-pod proves lowering).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.overheads import RooflineTerms

# -- hardware constants (TPU v5e) -------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (brief: ~50 GB/s/link)
HBM_BYTES = 16 * 1024**3     # v5e HBM capacity

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_FACTORS = {
    "all-gather": lambda s: (s - 1) / s,
    "all-reduce": lambda s: 2 * (s - 1) / s,
    "reduce-scatter": lambda s: float(s - 1),
    "all-to-all": lambda s: (s - 1) / s,
    "collective-permute": lambda s: 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    moved_bytes: float


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue
        result, kind = m.group(1), m.group(2)
        rb = _shape_bytes(result)
        gm = _GROUPS_RE.search(line)
        if gm:
            group_size = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            group_size = len(gl.group(1).split(",")) if gl else 2
        moved = rb * _COLL_FACTORS[kind](max(group_size, 1))
        ops.append(CollectiveOp(kind, rb, group_size, moved))
    return ops


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_dev: float
    bytes_per_dev: float
    collective_bytes_per_dev: float
    collective_breakdown: Dict[str, float]
    arg_bytes: int
    temp_bytes: int
    out_bytes: int
    model_flops: float           # 6*N*D (or 6*N_active*D) global
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops_per_dev / PEAK_FLOPS
        self.memory_s = self.bytes_per_dev / HBM_BW
        self.collective_s = self.collective_bytes_per_dev / ICI_BW

    @property
    def terms(self) -> RooflineTerms:
        return RooflineTerms(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound(self) -> str:
        return self.terms.bound

    @property
    def step_s(self) -> float:
        """Roofline step time: dominant term (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction: time the chip would spend on MODEL_FLOPS
        at peak, over the roofline step time."""
        ideal = self.model_flops / (self.n_chips * PEAK_FLOPS)
        return ideal / self.step_s if self.step_s > 0 else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_dev * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def bw_fraction(self) -> float:
        """Decode-cell roofline: ideal time to stream the per-device resident
        state (params + cache = the compiled argument bytes) once from HBM,
        over the achieved step time. The right metric where useful-FLOPs is
        inherently tiny (one token per sequence)."""
        ideal = self.arg_bytes / HBM_BW
        return ideal / self.step_s if self.step_s > 0 else 0.0

    @property
    def hbm_bytes_per_dev(self) -> int:
        return self.arg_bytes + self.temp_bytes + self.out_bytes

    @property
    def fits_hbm(self) -> bool:
        return self.hbm_bytes_per_dev <= HBM_BYTES

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(bound=self.bound, step_s=self.step_s,
                 roofline_fraction=self.roofline_fraction,
                 useful_flops_ratio=self.useful_flops_ratio,
                 bw_fraction=self.bw_fraction,
                 hbm_bytes_per_dev=self.hbm_bytes_per_dev,
                 fits_hbm=self.fits_hbm)
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, n_chips: int,
            model_flops: float) -> CellRoofline:
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    breakdown: Dict[str, float] = {}
    for op in colls:
        breakdown[op.kind] = breakdown.get(op.kind, 0.0) + op.moved_bytes
    return CellRoofline(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        collective_bytes_per_dev=float(sum(breakdown.values())),
        collective_breakdown=breakdown,
        arg_bytes=int(ma.argument_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        model_flops=model_flops,
    )


def _encdec_split(cfg) -> Tuple[float, float]:
    """(enc_params, dec_params) excluding embeddings (counted decoder-side)."""
    d, hd = cfg.d_model, cfg.head_dim_
    attn = (d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
            + cfg.num_heads * hd * d)
    ffn = 2 * d * cfg.d_ff
    enc = cfg.encoder_layers * (attn + ffn)
    dec = cfg.decoder_layers * (2 * attn + ffn) + cfg.vocab_size * d
    return float(enc), float(dec)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D for train (fwd+bwd), 2*N*D for inference steps.
    N = active params; D = tokens processed by the step. Enc-dec models split
    the params by which token stream they actually process."""
    total, active = cfg.params_count()
    mult = 6.0 if shape.step_kind == "train" else 2.0
    if cfg.family == "encdec":
        enc_p, dec_p = _encdec_split(cfg)
        enc_tok = shape.global_batch * shape.seq_len
        if shape.step_kind == "decode":
            # one decoder token; cross-attn reads cached enc states (memory,
            # not flops); encoder not run.
            return 2.0 * dec_p * shape.global_batch
        dec_tok = shape.global_batch * cfg.max_target_len
        return mult * (enc_p * enc_tok + dec_p * dec_tok)
    if shape.step_kind == "decode":
        return 2.0 * active * shape.global_batch
    return mult * active * shape.global_batch * shape.seq_len
