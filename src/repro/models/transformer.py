"""Decoder-only LM trunk covering dense / moe / hybrid / ssm / vlm families.

Layer stacks are ``lax.scan``-ed over parameters stacked on a leading layer
axis (keeps compiled HLO compact for 95-layer cells and makes remat policy a
single ``jax.checkpoint`` on the scan body).

Heterogeneous stacks are handled structurally:
  * deepseek-v3: ``first_k_dense`` dense-FFN layers scanned separately from
    the MoE remainder,
  * jamba: a *group* of ``attn_period`` layers (7 mamba + 1 attention,
    alternating dense/MoE FFN) is the scan unit, scanned over groups.

Caches are pytrees with a leading stacked-layer (or group) axis so decode is
the same scan. ``cache["pos"]`` holds per-sequence absolute positions.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, mla, moe, ssm

Params = Any


# ---------------------------------------------------------------------------
# layer-slot helpers
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig, use_rope: Optional[bool] = None) -> layers.AttentionConfig:
    return layers.AttentionConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        use_rope=(cfg.family != "hybrid") if use_rope is None else use_rope,
        chunk=cfg.parallel.attention_chunk,
    )


def _mla_cfg(cfg: ModelConfig) -> mla.MLAConfig:
    m = cfg.mla
    return mla.MLAConfig(d_model=cfg.d_model, num_heads=cfg.num_heads,
                         q_lora_rank=m.q_lora_rank, kv_lora_rank=m.kv_lora_rank,
                         rope_dim=m.rope_dim, nope_dim=m.nope_dim,
                         v_head_dim=m.v_head_dim, rope_theta=cfg.rope_theta,
                         chunk=cfg.parallel.attention_chunk)


def _mamba_cfg(cfg: ModelConfig) -> ssm.MambaConfig:
    s = cfg.ssm
    return ssm.MambaConfig(d_model=cfg.d_model, d_state=s.d_state,
                           d_conv=s.d_conv, expand=s.expand, chunk=s.chunk)


def _rwkv_cfg(cfg: ModelConfig) -> ssm.RWKV6Config:
    s = cfg.ssm
    return ssm.RWKV6Config(d_model=cfg.d_model, head_dim=s.head_dim,
                           lora_rank=s.lora_rank, d_ff=cfg.d_ff)


def _moe_cfg(cfg: ModelConfig) -> moe.MoEConfig:
    m = cfg.moe
    return moe.MoEConfig(num_experts=m.num_experts, top_k=m.top_k,
                         d_ff_expert=m.d_ff_expert,
                         num_shared_experts=m.num_shared_experts,
                         capacity_factor=m.capacity_factor, gating=m.gating)


# ---------------------------------------------------------------------------
# single-layer init / forward for each (mixer, ffn) slot combination
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, mixer: str, ffn: str, key, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Params] = {}
    if mixer == "rwkv":
        p["pre_norm"] = layers.init_layernorm(cfg.d_model, dtype)
        p["post_norm"] = layers.init_layernorm(cfg.d_model, dtype)
    else:
        p["pre_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["post_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)

    if mixer == "attn":
        p["attn"] = layers.init_attention(k1, _attn_cfg(cfg), dtype)
    elif mixer == "mla":
        p["mla"] = mla.init_mla(k1, _mla_cfg(cfg), dtype)
    elif mixer == "mamba":
        p["mamba"] = ssm.init_mamba(k1, _mamba_cfg(cfg), dtype)
    elif mixer == "rwkv":
        p["tmix"] = ssm.init_rwkv6_time_mix(k1, _rwkv_cfg(cfg), dtype)
    else:
        raise ValueError(mixer)

    if ffn == "swiglu":
        p["ffn"] = layers.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["moe"] = moe.init_moe(k2, cfg.d_model, _moe_cfg(cfg), dtype)
    elif ffn == "cmix":
        p["cmix"] = ssm.init_rwkv6_channel_mix(k2, _rwkv_cfg(cfg), dtype)
    else:
        raise ValueError(ffn)
    return p


def _layer_fwd(cfg: ModelConfig, mixer: str, ffn: str, lp: Params,
               x: jax.Array, positions: jax.Array, mode: str,
               cache_sl: Optional[Params]) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """One layer. mode: 'train' | 'prefill' | 'decode'. Returns (x, cache', aux)."""
    aux = jnp.zeros((), jnp.float32)
    norm = layers.layernorm if mixer == "rwkv" else layers.rmsnorm
    h = norm(lp["pre_norm"], x, cfg.norm_eps)
    new_cache = dict(cache_sl) if cache_sl is not None else None

    if mixer == "attn":
        acfg = _attn_cfg(cfg)
        if mode == "train":
            mix = layers.attention_forward(lp["attn"], acfg, h, positions)
        elif mode == "prefill":
            mix, kv = layers.attention_prefill(lp["attn"], acfg, h,
                                               {"k": cache_sl["k"], "v": cache_sl["v"]}, positions)
            new_cache.update(kv)
        else:
            mix, kv = layers.attention_decode(lp["attn"], acfg, h,
                                              {"k": cache_sl["k"], "v": cache_sl["v"]}, positions)
            new_cache.update(kv)
    elif mixer == "mla":
        mcfg = _mla_cfg(cfg)
        if mode == "train":
            mix = mla.mla_forward(lp["mla"], mcfg, h)
        elif mode == "prefill":
            mix, c = mla.mla_prefill(lp["mla"], mcfg, h,
                                     {"ckv": cache_sl["ckv"], "krope": cache_sl["krope"]}, positions)
            new_cache.update(c)
        else:
            mix, c = mla.mla_decode(lp["mla"], mcfg, h,
                                    {"ckv": cache_sl["ckv"], "krope": cache_sl["krope"]}, positions)
            new_cache.update(c)
    elif mixer == "mamba":
        scfg = _mamba_cfg(cfg)
        if mode == "train":
            mix = ssm.mamba_forward(lp["mamba"], scfg, h)
        elif mode == "prefill":
            mix, st = ssm.mamba_prefill(lp["mamba"], scfg, h)
            new_cache.update(st)
        else:
            mix, st = ssm.mamba_step(lp["mamba"], scfg, h,
                                     {"conv": cache_sl["conv"], "ssm": cache_sl["ssm"]})
            new_cache.update(st)
    elif mixer == "rwkv":
        rcfg = _rwkv_cfg(cfg)
        b = h.shape[0]
        if mode == "train":
            x_last = jnp.zeros((b, cfg.d_model), h.dtype)
            state = jnp.zeros((b, rcfg.num_heads, rcfg.head_dim, rcfg.head_dim), jnp.float32)
            mix, _, _ = ssm.rwkv6_time_mix(lp["tmix"], rcfg, h, x_last, state)
        else:  # prefill and decode share the segment-continuation form
            mix, x_last, state = ssm.rwkv6_time_mix(
                lp["tmix"], rcfg, h, cache_sl["tmix_x"], cache_sl["wkv"])
            new_cache.update({"tmix_x": x_last, "wkv": state})
    else:
        raise ValueError(mixer)

    x = x + mix
    h = norm(lp["post_norm"], x, cfg.norm_eps)

    if ffn == "swiglu":
        out = layers.swiglu(lp["ffn"], h)
    elif ffn == "moe":
        out, aux = moe.moe_forward(lp["moe"], _moe_cfg(cfg), h)
    elif ffn == "cmix":
        rcfg = _rwkv_cfg(cfg)
        b = h.shape[0]
        if mode == "train":
            x_last = jnp.zeros((b, cfg.d_model), h.dtype)
            out, _ = ssm.rwkv6_channel_mix(lp["cmix"], rcfg, h, x_last)
        else:
            out, x_last = ssm.rwkv6_channel_mix(lp["cmix"], rcfg, h, cache_sl["cmix_x"])
            new_cache.update({"cmix_x": x_last})
    else:
        raise ValueError(ffn)
    return x + out, new_cache, aux


# ---------------------------------------------------------------------------
# stack descriptors: a model is a sequence of scanned blocks
# ---------------------------------------------------------------------------

def _blocks(cfg: ModelConfig):
    """Returns [(block_name, n_repeats, [(mixer, ffn), ...per-slot...])]."""
    if cfg.family in ("dense", "vlm"):
        return [("layers", cfg.num_layers, [("attn", "swiglu")])]
    if cfg.family == "ssm":  # rwkv6
        return [("layers", cfg.num_layers, [("rwkv", "cmix")])]
    if cfg.family == "moe":
        mixer = "mla" if cfg.mla else "attn"
        fk = cfg.moe.first_k_dense
        blocks = []
        if fk:
            blocks.append(("dense_layers", fk, [(mixer, "swiglu")]))
        blocks.append(("moe_layers", cfg.num_layers - fk, [(mixer, "moe")]))
        return blocks
    if cfg.family == "hybrid":  # jamba group: attn at slot attn_period-1, moe on odd slots
        slots = []
        for i in range(cfg.attn_period):
            mixer = "attn" if i == cfg.attn_period - 1 else "mamba"
            ffn = "moe" if (cfg.moe_period and i % cfg.moe_period == cfg.moe_period - 1) else "swiglu"
            slots.append((mixer, ffn))
        return [("groups", cfg.num_layers // cfg.attn_period, slots)]
    raise ValueError(cfg.family)


def _init_block(cfg: ModelConfig, slots, n: int, key, dtype) -> Params:
    """Stacked params [n, ...] for a block of `slots` layers."""
    def init_one(k):
        ks = jax.random.split(k, len(slots))
        return {f"slot_{i}": _init_layer(cfg, m, f, ks[i], dtype)
                for i, (m, f) in enumerate(slots)}
    return jax.vmap(init_one)(jax.random.split(key, n))


def _init_cache_slot(cfg: ModelConfig, mixer: str, ffn: str, batch: int,
                     max_len: int, dtype) -> Params:
    c: Dict[str, Any] = {}
    if mixer == "attn":
        c.update(layers.init_kv_cache(batch, max_len, cfg.num_kv_heads,
                                      cfg.head_dim_, dtype))
    elif mixer == "mla":
        c.update(mla.init_mla_cache(batch, max_len, _mla_cfg(cfg), dtype))
    elif mixer == "mamba":
        c.update(ssm.init_mamba_state(batch, _mamba_cfg(cfg), dtype))
    elif mixer == "rwkv":
        rcfg = _rwkv_cfg(cfg)
        c["tmix_x"] = jnp.zeros((batch, cfg.d_model), dtype)
        c["wkv"] = jnp.zeros((batch, rcfg.num_heads, rcfg.head_dim, rcfg.head_dim), jnp.float32)
    if ffn == "cmix":
        c["cmix_x"] = jnp.zeros((batch, cfg.d_model), dtype)
    return c


def _stack_cache(cfg: ModelConfig, slots, n: int, batch: int, max_len: int, dtype):
    one = {f"slot_{i}": _init_cache_slot(cfg, m, f, batch, max_len, dtype)
           for i, (m, f) in enumerate(slots)}
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), one)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.blocks = _blocks(cfg)

    # -- params ------------------------------------------------------------
    def init_params(self, key) -> Params:
        cfg = self.cfg
        dtype = cfg.jnp_dtype
        keys = jax.random.split(key, len(self.blocks) + 2)
        p: Dict[str, Params] = {
            "embedding": layers.init_embedding(keys[0], cfg.vocab_size,
                                               cfg.d_model, dtype),
            "final_norm": (layers.init_layernorm(cfg.d_model, dtype)
                           if cfg.family == "ssm"
                           else layers.init_rmsnorm(cfg.d_model, dtype)),
        }
        if cfg.family == "ssm":
            p["ln0"] = layers.init_layernorm(cfg.d_model, dtype)
        for i, (name, n, slots) in enumerate(self.blocks):
            p[name] = _init_block(cfg, slots, n, keys[i + 1], dtype)
        return p

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda: self.init_params(jax.random.key(0)))

    # -- block scan --------------------------------------------------------
    def _run_block(self, name: str, slots, bp: Params, x: jax.Array,
                   positions: jax.Array, mode: str, cache_blk):
        cfg = self.cfg

        def body(carry, xs):
            h, aux = carry
            lp, csl = xs
            new_csl = {} if csl is not None else None
            for i, (m, f) in enumerate(slots):
                sl = csl[f"slot_{i}"] if csl is not None else None
                h, new_sl, a = _layer_fwd(cfg, m, f, lp[f"slot_{i}"], h,
                                          positions, mode, sl)
                aux = aux + a
                if new_csl is not None:
                    new_csl[f"slot_{i}"] = new_sl
            return (h, aux), new_csl

        if cfg.parallel.remat == "full":
            body = jax.checkpoint(body)
        elif cfg.parallel.remat == "dots_saveable":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)

        # §Perf: decode with the cache as scan CARRY — each layer reads and
        # writes only its own [1, ...] slice in place (XLA aliases the
        # dynamic-update-slice), instead of streaming the whole stacked
        # cache through xs/ys (2x full-cache HBM traffic per token).
        if (mode == "decode" and cache_blk is not None
                and cfg.parallel.decode_cache_carry and cfg.parallel.scan_layers):
            def carry_body(carry, lp):
                h, cache_full, i, aux = carry
                csl = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                    cache_full)
                (h, aux), new_csl = body((h, aux), (lp, csl))
                cache_full = jax.tree.map(
                    lambda full, sl: jax.lax.dynamic_update_index_in_dim(
                        full, sl.astype(full.dtype), i, 0),
                    cache_full, new_csl)
                return (h, cache_full, i + 1, aux), None

            (x, new_cache, _, aux), _ = jax.lax.scan(
                carry_body, (x, cache_blk, jnp.int32(0),
                             jnp.zeros((), jnp.float32)), bp)
            return x, aux, new_cache

        if cfg.parallel.scan_layers:
            (x, aux), new_cache = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (bp, cache_blk) if cache_blk is not None else (bp, None))
            return x, aux, new_cache
        # unrolled path (debug / tiny models / cost-analysis lowerings)
        aux = jnp.zeros((), jnp.float32)
        n = jax.tree.leaves(bp)[0].shape[0]
        if (mode == "decode" and cache_blk is not None
                and cfg.parallel.decode_cache_carry):
            # mirror the carry semantics: in-place per-layer slice updates
            new_cache = cache_blk
            for j in range(n):
                lp = jax.tree.map(lambda a: a[j], bp)
                csl = jax.tree.map(lambda a: a[j], new_cache)
                (x, aux), ncs = body((x, aux), (lp, csl))
                new_cache = jax.tree.map(
                    lambda full, sl: full.at[j].set(sl.astype(full.dtype)),
                    new_cache, ncs)
            return x, aux, new_cache
        new_layers = []
        for j in range(n):
            lp = jax.tree.map(lambda a: a[j], bp)
            csl = (jax.tree.map(lambda a: a[j], cache_blk)
                   if cache_blk is not None else None)
            (x, aux), ncs = body((x, aux), (lp, csl))
            new_layers.append(ncs)
        new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
                     if cache_blk is not None else None)
        return x, aux, new_cache

    # -- embedding ---------------------------------------------------------
    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.embedding_inputs:
            x = tokens  # already [b, s, d]
        else:
            x = layers.embed(params["embedding"], tokens)
        if cfg.family == "ssm":
            x = layers.layernorm(params["ln0"], x, cfg.norm_eps)
        return x

    def _unembed(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        norm = layers.layernorm if cfg.family == "ssm" else layers.rmsnorm
        x = norm(params["final_norm"], x, cfg.norm_eps)
        return layers.unembed(params["embedding"], x)

    # -- public entry points -------------------------------------------------
    def _trunk(self, params: Params, tokens: jax.Array):
        """Embed + all blocks; returns (hidden [b,s,d], aux)."""
        b, s = tokens.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = self._embed(params, tokens)
        aux = jnp.zeros((), jnp.float32)
        for name, n, slots in self.blocks:
            x, a, _ = self._run_block(name, slots, params[name], x,
                                      positions, "train", None)
            aux = aux + a
        return x, aux

    def forward(self, params: Params, tokens: jax.Array):
        """Training/teacher-forced full-sequence pass -> (logits, aux)."""
        x, aux = self._trunk(params, tokens)
        return self._unembed(params, x), aux

    def _ce_chunk(self, params: Params, x: jax.Array, labels: jax.Array):
        """Summed masked NLL + token count for a hidden-state chunk."""
        logits = self._unembed(params, x)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mask), jnp.sum(mask)

    def loss(self, params: Params, batch: Dict[str, jax.Array]):
        x, aux = self._trunk(params, batch["tokens"])
        labels = batch["labels"]
        chunk = self.cfg.parallel.loss_chunk
        s = x.shape[1]
        if chunk and s > chunk:
            # never materialize the full [b, s, vocab] logits (§Perf)
            tot = jnp.zeros((), jnp.float32)
            cnt = jnp.zeros((), jnp.float32)
            for start in range(0, s, chunk):
                end = min(start + chunk, s)
                t, c = self._ce_chunk(params, x[:, start:end],
                                      labels[:, start:end])
                tot, cnt = tot + t, cnt + c
        else:
            tot, cnt = self._ce_chunk(params, x, labels)
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss + aux, {"ce_loss": loss, "aux_loss": aux}

    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        cache = {"pos": jnp.zeros((batch,), jnp.int32)}
        for name, n, slots in self.blocks:
            cache[name] = _stack_cache(cfg, slots, n, batch, max_len, cfg.jnp_dtype)
        return cache

    def abstract_cache(self, batch: int, max_len: int) -> Params:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def prefill(self, params: Params, tokens: jax.Array, cache: Params):
        """tokens: [b, s] (or [b, s, d] embeddings). Fills cache[0, s)."""
        b, s = tokens.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = self._embed(params, tokens)
        new_cache = {"pos": jnp.full((b,), s, jnp.int32)}
        aux = jnp.zeros((), jnp.float32)
        for name, n, slots in self.blocks:
            x, a, nc = self._run_block(name, slots, params[name], x,
                                       positions, "prefill", cache[name])
            new_cache[name] = nc
            aux = aux + a
        logits = self._unembed(params, x[:, -1:, :])
        return logits[:, 0], new_cache

    def decode_step(self, params: Params, tokens: jax.Array, cache: Params):
        """tokens: [b, 1] -> (logits [b, vocab], cache')."""
        b = tokens.shape[0]
        positions = cache["pos"][:, None]  # [b,1] absolute position of new token
        x = self._embed(params, tokens)
        new_cache = {"pos": cache["pos"] + 1}
        for name, n, slots in self.blocks:
            x, _, nc = self._run_block(name, slots, params[name], x,
                                       positions, "decode", cache[name])
            new_cache[name] = nc
        logits = self._unembed(params, x)
        return logits[:, 0], new_cache
