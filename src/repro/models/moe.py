"""Mixture-of-Experts FFN with sort-based token dispatch.

A GShard-style dense dispatch tensor [tokens, experts, capacity] is
infeasible at the assigned scales (deepseek-v3: 1M tokens x 256 experts —
the dispatch one-hot alone would be >10^14 elements). We instead use the
sort-based formulation used by modern MoE stacks:

  1. route: top-k expert ids + weights per token,
  2. sort the (token, choice) pairs by expert id,
  3. compute each pair's position inside its expert queue from the sorted
     run-starts; drop pairs beyond ``capacity`` (Switch semantics),
  4. scatter token activations into a [experts * capacity, d] buffer,
  5. batched expert FFN via einsum (experts dim shards over the ``model``
     mesh axis = expert parallelism; pjit inserts the all-to-alls),
  6. gather back and combine with routing weights.

Covers dbrx (16e top-4), deepseek-v3 (1 shared + 256 routed top-8, sigmoid
gating), jamba (16e top-2). Oracle: tests compare against a per-token loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Any


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    gating: str = "softmax"  # "softmax" | "sigmoid" (deepseek-v3)
    router_aux_weight: float = 0.01


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    e, f = cfg.num_experts, cfg.d_ff_expert
    kg, ku, kd = jax.random.split(ke, 3)
    p = {
        "router": layers.dense_init(kr, (d_model, e), dtype=jnp.float32),
        "experts": {
            "w_gate": layers.dense_init(kg, (e, d_model, f), in_axis_size=d_model, dtype=dtype),
            "w_up": layers.dense_init(ku, (e, d_model, f), in_axis_size=d_model, dtype=dtype),
            "w_down": layers.dense_init(kd, (e, f, d_model), in_axis_size=f, dtype=dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.init_swiglu(ks, d_model, f * cfg.num_shared_experts, dtype)
    return p


def route(params: Params, cfg: MoEConfig, x: jax.Array):
    """x: [t, d] -> (weights [t,k], indices [t,k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    if cfg.gating == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    weights, indices = jax.lax.top_k(scores, cfg.top_k)
    weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)

    # Switch-style load-balance loss: E * sum_e(frac_tokens_e * frac_prob_e)
    probs = jax.nn.softmax(logits, axis=-1)
    e = cfg.num_experts
    counts = jnp.zeros((e,), jnp.float32).at[indices.reshape(-1)].add(1.0)
    frac_tokens = counts / (indices.size)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_weight
    return weights, indices, aux


def moe_ffn_tokens(params: Params, cfg: MoEConfig, xf: jax.Array):
    """MoE over flat tokens xf: [t, d] -> ([t, d], aux)."""
    t, d = xf.shape
    e, k = cfg.num_experts, cfg.top_k
    weights, indices, aux = route(params, cfg, xf)

    tk = t * k
    capacity = max(1, int(cfg.capacity_factor * tk / e))

    flat_expert = indices.reshape(tk)                      # [tk]
    flat_weight = weights.reshape(tk).astype(jnp.float32)  # [tk]
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_expert)                       # stable
    s_expert = flat_expert[order]
    s_token = flat_token[order]
    s_weight = flat_weight[order]

    # position within the expert's queue = rank - start_of_run(expert)
    counts = jnp.zeros((e,), jnp.int32).at[flat_expert].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(tk, dtype=jnp.int32) - starts[s_expert]
    keep = pos < capacity
    dest = jnp.where(keep, s_expert * capacity + pos, tk + e * capacity)  # OOB -> dropped

    gathered = jnp.take(xf, s_token, axis=0)               # [tk, d]
    buf = jnp.zeros((e * capacity, d), xf.dtype).at[dest].set(gathered)
    expert_in = buf.reshape(e, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", expert_in, params["experts"]["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["experts"]["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                            params["experts"]["w_down"])
    flat_out = expert_out.reshape(e * capacity, d)

    back = jnp.take(flat_out, jnp.clip(dest, 0, e * capacity - 1), axis=0)
    back = back.astype(jnp.float32) * (s_weight * keep.astype(jnp.float32))[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[s_token].add(back)
    return out.astype(xf.dtype), aux


def moe_forward(params: Params, cfg: MoEConfig, x: jax.Array):
    """x: [b, s, d] -> ([b, s, d], aux_loss)."""
    b, s, d = x.shape
    out, aux = moe_ffn_tokens(params, cfg, x.reshape(b * s, d))
    out = out.reshape(b, s, d)
    if cfg.num_shared_experts:
        out = out + layers.swiglu(params["shared"], x)
    return out, aux
