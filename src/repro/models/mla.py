"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are produced from low-rank latent compressions; the
decode cache stores only the compressed KV latent + decoupled RoPE key:
``kv_lora_rank + rope_dim`` floats per token instead of
``2 * num_heads * head_dim`` — the long-context memory win that makes the
500k-class cells feasible at all on real hardware.

This is the reference jnp path used for training/prefill/decode and the
dry-run. Cache layout: {"ckv": [b, max_len, kv_rank], "krope": [b, max_len, rope_dim]}.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers

Params = Any


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    num_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_dim: int = 64
    nope_dim: int = 128      # per-head non-rope key/query dim
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    chunk: int = 0           # q-chunked attention (see layers.sdpa_chunked)


def init_mla(key, cfg: MLAConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.num_heads
    return {
        "w_dq": layers.dense_init(ks[0], (d, cfg.q_lora_rank), dtype=dtype),
        "q_norm": layers.init_rmsnorm(cfg.q_lora_rank, dtype),
        "w_uq": layers.dense_init(ks[1], (cfg.q_lora_rank, h, cfg.nope_dim + cfg.rope_dim),
                                  in_axis_size=cfg.q_lora_rank, dtype=dtype),
        "w_dkv": layers.dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.rope_dim), dtype=dtype),
        "kv_norm": layers.init_rmsnorm(cfg.kv_lora_rank, dtype),
        "w_uk": layers.dense_init(ks[3], (cfg.kv_lora_rank, h, cfg.nope_dim),
                                  in_axis_size=cfg.kv_lora_rank, dtype=dtype),
        "w_uv": layers.dense_init(ks[4], (cfg.kv_lora_rank, h, cfg.v_head_dim),
                                  in_axis_size=cfg.kv_lora_rank, dtype=dtype),
        "wo": layers.dense_init(ks[5], (h, cfg.v_head_dim, d),
                                in_axis_size=h * cfg.v_head_dim, dtype=dtype),
    }


def _compress(params: Params, cfg: MLAConfig, x: jax.Array, positions: jax.Array):
    """Produce per-token latent ckv [b,s,rank] and rotated shared key [b,s,rope]."""
    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    ckv, krope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    ckv = layers.rmsnorm(params["kv_norm"], ckv)
    krope = layers.apply_rope(krope, positions, cfg.rope_theta)
    return ckv, krope


def _queries(params: Params, cfg: MLAConfig, x: jax.Array, positions: jax.Array):
    cq = layers.rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"]))
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope, q_rope = jnp.split(q, [cfg.nope_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope.swapaxes(1, 2), positions[:, None, :],
                               cfg.rope_theta).swapaxes(1, 2)
    return q_nope, q_rope


def _attend(params: Params, cfg: MLAConfig, q_nope, q_rope, ckv, krope,
            q_positions, kv_valid_len=None):
    """Attention over compressed latents (absorbed-weight formulation).

    scores = q_nope . (W_uk ckv) + q_rope . krope ; values = W_uv ckv.
    We absorb W_uk into the query so the per-key work is rank-dim, keeping the
    latent as the only per-token state (the MLA trick).
    """
    # absorb: q_abs [b,s,h,rank] — bf16 inputs, f32 accumulation (no f32
    # copies of the latent cache; §Perf iteration 1)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])
    scale = 1.0 / np.sqrt(cfg.nope_dim + cfg.rope_dim)
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhk,bsk->bhqs", q_rope, krope,
                           preferred_element_type=jnp.float32)) * scale
    b, sq = q_nope.shape[:2]
    skv = ckv.shape[1]
    kv_pos = jnp.arange(skv)[None, :]
    mask = kv_pos[:, None, :] <= q_positions[:, :, None]
    if kv_valid_len is not None:
        mask &= kv_pos[:, None, :] < kv_valid_len[:, None, None]
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # out latent then decompress with W_uv
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs.astype(ckv.dtype), ckv,
                         preferred_element_type=jnp.float32)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat.astype(q_nope.dtype),
                     params["w_uv"])
    return jnp.einsum("bqhv,hvd->bqd", out, params["wo"])


def _attend_maybe_chunked(params, cfg: MLAConfig, q_nope, q_rope, ckv, krope,
                          positions):
    """Full-sequence attention; q-chunked when cfg.chunk is set so the
    [b, h, s, s] logits are never materialized (§Perf iteration)."""
    s = q_nope.shape[1]
    if not cfg.chunk or s <= cfg.chunk:
        return _attend(params, cfg, q_nope, q_rope, ckv, krope, positions)
    outs = []
    for start in range(0, s, cfg.chunk):
        end = min(start + cfg.chunk, s)
        outs.append(_attend(params, cfg, q_nope[:, start:end],
                            q_rope[:, start:end], ckv[:, :end], krope[:, :end],
                            positions[:, start:end]))
    return jnp.concatenate(outs, axis=1)


def mla_forward(params: Params, cfg: MLAConfig, x: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    ckv, krope = _compress(params, cfg, x, positions)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    return _attend_maybe_chunked(params, cfg, q_nope, q_rope, ckv, krope, positions)


def init_mla_cache(batch: int, max_len: int, cfg: MLAConfig, dtype=jnp.bfloat16) -> Params:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.rope_dim), dtype),
    }


def mla_prefill(params: Params, cfg: MLAConfig, x: jax.Array, cache: Params,
                positions: jax.Array):
    ckv, krope = _compress(params, cfg, x, positions)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
        "krope": jax.lax.dynamic_update_slice(cache["krope"], krope.astype(cache["krope"].dtype), (0, 0, 0)),
    }
    q_nope, q_rope = _queries(params, cfg, x, positions)
    return _attend_maybe_chunked(params, cfg, q_nope, q_rope, ckv, krope,
                                 positions), cache


def mla_decode(params: Params, cfg: MLAConfig, x: jax.Array, cache: Params,
               positions: jax.Array):
    ckv, krope = _compress(params, cfg, x, positions)

    def write(buf, new):
        def upd(buf_b, new_b, pos_b):
            return jax.lax.dynamic_update_slice(buf_b, new_b.astype(buf_b.dtype), (pos_b, 0))
        return jax.vmap(upd)(buf, new, positions[:, 0])

    cache = {"ckv": write(cache["ckv"], ckv), "krope": write(cache["krope"], krope)}
    q_nope, q_rope = _queries(params, cfg, x, positions)
    valid = positions[:, 0] + 1
    out = _attend(params, cfg, q_nope, q_rope, cache["ckv"], cache["krope"],
                  positions, kv_valid_len=valid)
    return out, cache
