"""State-space sequence mixers: Mamba (selective SSM, for Jamba) and RWKV6.

Both are attention-free: per-layer state is O(1) in sequence length, which is
what qualifies jamba/rwkv6 for the ``long_500k`` cells (DESIGN.md §5).

Mamba uses a *chunked* scan: a sequential ``lax.scan`` over chunks with an
associative prefix inside each chunk. This bounds the materialized
[b, chunk, d_inner, d_state] tensor (the naive associative-scan formulation
materializes the full-sequence version, which is what blows up HBM at 4k+).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers

Params = Any


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, (self.d_model + 15) // 16)


def init_mamba(key, cfg: MambaConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 7)
    di, ds, dr = cfg.d_inner, cfg.d_state, cfg.dt_rank_
    # S4D-real initialization for A
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, ds))
    return {
        "w_in": layers.dense_init(ks[0], (cfg.d_model, 2 * di), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                   / np.sqrt(cfg.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_bcdt": layers.dense_init(ks[2], (di, 2 * ds + dr), dtype=dtype),
        "w_dt": layers.dense_init(ks[3], (dr, di), in_axis_size=dr, dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": layers.dense_init(ks[4], (di, cfg.d_model), in_axis_size=di, dtype=dtype),
    }


def _selective_params(params: Params, cfg: MambaConfig, xi: jax.Array):
    """xi: [b, s, d_inner] (post-conv). Returns dA [b,s,di,ds], dBx, C."""
    ds, dr = cfg.d_state, cfg.dt_rank_
    bcdt = jnp.einsum("bsd,de->bse", xi, params["w_bcdt"])
    b_sel, c_sel, dt = jnp.split(bcdt, [ds, 2 * ds], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt, params["w_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])  # [b,s,di]
    a = -jnp.exp(params["a_log"])  # [di,ds]
    dA = jnp.exp(dt[..., None] * a[None, None])  # [b,s,di,ds]
    dBx = (dt * xi.astype(jnp.float32))[..., None] * b_sel.astype(jnp.float32)[:, :, None, :]
    return dA, dBx, c_sel.astype(jnp.float32)


def _chunk_scan(dA, dBx, h0):
    """Associative scan within a chunk given entry state h0 [b,di,ds]."""
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2
    aA, bB = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = aA * h0[:, None] + bB  # [b,c,di,ds]
    return h, h[:, -1]


def _causal_conv(params: Params, cfg: MambaConfig, x: jax.Array,
                 conv_state: jax.Array | None = None):
    """Depthwise causal conv over time. x: [b,s,di]. conv_state: [b,d_conv-1,di]."""
    pad = (jnp.zeros((x.shape[0], cfg.d_conv - 1, x.shape[-1]), x.dtype)
           if conv_state is None else conv_state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    w = params["conv_w"]  # [d_conv, di]
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(cfg.d_conv))
    new_state = xp[:, -(cfg.d_conv - 1):] if cfg.d_conv > 1 else pad
    return jax.nn.silu((out + params["conv_b"]).astype(jnp.float32)).astype(x.dtype), new_state


def _mamba_seq(params: Params, cfg: MambaConfig, x: jax.Array,
               conv_state: jax.Array | None, h0: jax.Array | None):
    """Shared full-sequence path. Returns (y, new_conv_state, h_last)."""
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, new_conv = _causal_conv(params, cfg, xi, conv_state)

    chunk = min(cfg.chunk, s)
    nchunks = (s + chunk - 1) // chunk
    pad_to = nchunks * chunk
    xi_p = jnp.pad(xi, ((0, 0), (0, pad_to - s), (0, 0))) if pad_to != s else xi
    dA, dBx, c_sel = _selective_params(params, cfg, xi_p)
    if pad_to != s:
        # padded positions must be identity steps (dA=1, dBx=0), else they
        # decay the carried state and corrupt the prefill->decode handoff
        valid = (jnp.arange(pad_to) < s)[None, :, None, None]
        dA = jnp.where(valid, dA, 1.0)
        dBx = jnp.where(valid, dBx, 0.0)
    dA = dA.reshape(b, nchunks, chunk, cfg.d_inner, cfg.d_state).swapaxes(0, 1)
    dBx = dBx.reshape(b, nchunks, chunk, cfg.d_inner, cfg.d_state).swapaxes(0, 1)

    def step(h, inputs):
        da, dbx = inputs
        hs, h_last = _chunk_scan(da, dbx, h)
        return h_last, hs

    if h0 is None:
        h0 = jnp.zeros((b, cfg.d_inner, cfg.d_state), jnp.float32)
    h_last, hs = jax.lax.scan(step, h0, (dA, dBx))
    hs = hs.swapaxes(0, 1).reshape(b, pad_to, cfg.d_inner, cfg.d_state)[:, :s]
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_sel[:, :s])
    y = y + params["d_skip"][None, None] * xi.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, params["w_out"]), new_conv, h_last


def mamba_forward(params: Params, cfg: MambaConfig, x: jax.Array) -> jax.Array:
    """Full-sequence training pass. x: [b, s, d]."""
    y, _, _ = _mamba_seq(params, cfg, x, None, None)
    return y


def mamba_prefill(params: Params, cfg: MambaConfig, x: jax.Array):
    """Full-sequence pass that also returns the decode state."""
    y, conv, h_last = _mamba_seq(params, cfg, x, None, None)
    return y, {"conv": conv, "ssm": h_last}


def init_mamba_state(batch: int, cfg: MambaConfig, dtype=jnp.bfloat16) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


def mamba_step(params: Params, cfg: MambaConfig, x: jax.Array, state: Params):
    """Single-token decode. x: [b, 1, d]."""
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv(params, cfg, xi, state["conv"])
    dA, dBx, c_sel = _selective_params(params, cfg, xi)
    h = dA[:, 0] * state["ssm"] + dBx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, c_sel[:, 0])[:, None]
    y = y + params["d_skip"][None, None] * xi.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"])
    return out, {"conv": conv_state.astype(state["conv"].dtype), "ssm": h}


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent decay linear attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_dim: int = 64
    lora_rank: int = 64
    d_ff: int = 0  # channel-mix hidden

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim


def _lora_init(key, d: int, rank: int, out: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "a": layers.dense_init(k1, (d, rank), dtype=dtype),
        "b": (jax.random.normal(k2, (rank, out), jnp.float32) * 0.01).astype(dtype),
    }


def _lora(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("...r,re->...e", jnp.tanh(jnp.einsum("...d,dr->...r", x, p["a"])), p["b"])


def init_rwkv6_time_mix(key, cfg: RWKV6Config, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    return {
        "mix": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),  # r,k,v,w,g mixes
        "w_r": layers.dense_init(ks[1], (d, d), dtype=dtype),
        "w_k": layers.dense_init(ks[2], (d, d), dtype=dtype),
        "w_v": layers.dense_init(ks[3], (d, d), dtype=dtype),
        "w_g": layers.dense_init(ks[4], (d, d), dtype=dtype),
        "w_o": layers.dense_init(ks[5], (d, d), dtype=dtype),
        "decay_lora": _lora_init(ks[6], d, cfg.lora_rank, d, dtype),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "bonus": (jax.random.normal(ks[7], (cfg.num_heads, cfg.head_dim), jnp.float32) * 0.05),
        "ln_out": layers.init_layernorm(d, dtype),
    }


def _rwkv_inputs(params: Params, cfg: RWKV6Config, x: jax.Array, x_prev: jax.Array):
    """Token-shift mixes. x: [b,s,d]; x_prev: [b,s,d] (x shifted right by 1)."""
    mix = params["mix"].astype(jnp.float32)
    xf, xp = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    def mixed(i):
        return (xf + (xp - xf) * mix[i][None, None]).astype(x.dtype)
    r = jnp.einsum("bsd,de->bse", mixed(0), params["w_r"])
    k = jnp.einsum("bsd,de->bse", mixed(1), params["w_k"])
    v = jnp.einsum("bsd,de->bse", mixed(2), params["w_v"])
    w = params["decay_base"] + _lora(params["decay_lora"], mixed(3)).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mixed(4), params["w_g"]).astype(jnp.float32))
    decay = jnp.exp(-jnp.exp(w))  # data-dependent per-channel decay in (0,1)
    return r, k, v, decay, g


def _heads(x: jax.Array, h: int):
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h)


def rwkv6_time_mix(params: Params, cfg: RWKV6Config, x: jax.Array,
                   x_prev_last: jax.Array, wkv_state: jax.Array):
    """Full-sequence pass via scan over time.

    x: [b,s,d]; x_prev_last: [b,d] last token of previous segment;
    wkv_state: [b,h,k,v] running outer-product state.
    Returns (out [b,s,d], new_x_last [b,d], new_state).
    """
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    x_prev = jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)
    r, k, v, decay, g = _rwkv_inputs(params, cfg, x, x_prev)
    rh = _heads(r, h).astype(jnp.float32)
    kh = _heads(k, h).astype(jnp.float32)
    vh = _heads(v, h).astype(jnp.float32)
    dh = _heads(decay, h)  # [b,s,h,hd]
    u = params["bonus"]  # [h, hd]

    def step(state, inputs):
        rt, kt, vt, wt = inputs  # [b,h,hd] each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None, :, :, None] * kv)
        new_state = wt[..., None] * state + kv
        return new_state, out

    xs = (rh.swapaxes(0, 1), kh.swapaxes(0, 1), vh.swapaxes(0, 1),
          dh.swapaxes(0, 1))
    new_state, outs = jax.lax.scan(step, wkv_state, xs)
    out = outs.swapaxes(0, 1).reshape(b, s, d)  # [b,s,h,v] -> [b,s,d]
    out = layers.layernorm(params["ln_out"], out.astype(x.dtype))
    out = (out.astype(jnp.float32) * g).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", out, params["w_o"])
    return out, x[:, -1], new_state


def init_rwkv6_channel_mix(key, cfg: RWKV6Config, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix": jax.random.uniform(ks[0], (2, d), jnp.float32).astype(dtype),
        "w_k": layers.dense_init(ks[1], (d, f), dtype=dtype),
        "w_v": layers.dense_init(ks[2], (f, d), in_axis_size=f, dtype=dtype),
        "w_r": layers.dense_init(jax.random.fold_in(key, 9), (d, d), dtype=dtype),
    }


def rwkv6_channel_mix(params: Params, cfg: RWKV6Config, x: jax.Array,
                      x_prev_last: jax.Array):
    x_prev = jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)
    mix = params["mix"].astype(jnp.float32)
    xf, xp = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    xk = (xf + (xp - xf) * mix[0][None, None]).astype(x.dtype)
    xr = (xf + (xp - xf) * mix[1][None, None]).astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, params["w_k"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, params["w_v"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["w_r"]).astype(jnp.float32))
    return (rr * vv.astype(jnp.float32)).astype(x.dtype), x[:, -1]
