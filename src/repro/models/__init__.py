"""Model zoo: dense/MoE/MLA/SSM/hybrid/enc-dec/VLM transformer families.

All models are pure-functional JAX: ``init_params`` builds a pytree,
``forward``/``prefill``/``decode_step`` are jit-able functions. Layer stacks
are ``jax.lax.scan``-ed over stacked parameters so that compiled HLO stays
compact for the 95-layer dry-run cells.
"""

from repro.models.model import build_model, Model

__all__ = ["build_model", "Model"]
