"""Core transformer layers: norms, RoPE, GQA attention, MLPs.

Pure-functional: each layer is an ``init_*`` returning a param pytree and an
``apply``-style function. Parameters carry no metadata; their sharding specs
are produced structurally by :mod:`repro.distributed.sharding` walking the
same tree layout.

Compute dtype is bf16 by default with f32 softmax/norm accumulation, matching
the paper's AMX-bf16 operating point (Insight 3/8).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict pytree


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.bfloat16):
    """Truncated-normal fan-in init (matches Llama-family practice)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., seq, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm) — reference jnp path
#
# The Pallas flash kernel (kernels/flash_attention.py) is the TPU-targeted
# implementation; this path is the oracle and the dry-run/smoke path.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    use_rope: bool = True
    # >0: full-sequence attention runs in q-chunks of this size so the
    # [b, h, s, s] score matrix is never materialized (flash-style memory
    # behaviour expressed in XLA ops; §Perf iteration)
    chunk: int = 0


def init_attention(key, cfg: AttentionConfig, dtype=jnp.bfloat16) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kq, (d, h, hd), in_axis_size=d, dtype=dtype),
        "wk": dense_init(kk, (d, hk, hd), in_axis_size=d, dtype=dtype),
        "wv": dense_init(kv, (d, hk, hd), in_axis_size=d, dtype=dtype),
        "wo": dense_init(ko, (h, hd, d), in_axis_size=h * hd, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _qkv(params: Params, cfg: AttentionConfig, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cfg.use_rope:
        q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    return q, k, v


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
         q_positions: Optional[jax.Array] = None,
         kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Scaled dot-product attention with GQA broadcast.

    q: [b, sq, h, hd]; k/v: [b, skv, hk, hd]. h must be a multiple of hk.
    ``q_positions``: absolute positions of queries [b, sq] (for causal masking
    against a cache); ``kv_valid_len``: [b] number of valid cache entries.
    """
    b, sq, h, hd = q.shape
    hk = k.shape[2]
    group = h // hk
    qg = q.reshape(b, sq, hk, group, hd)
    scale = 1.0 / np.sqrt(hd)
    # bf16 inputs + f32 accumulation: never materialize f32 copies of the
    # KV tensors (the MXU-native dataflow; §Perf iteration 1)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    skv = k.shape[1]
    kv_pos = jnp.arange(skv)[None, :]  # [1, skv]
    mask = jnp.ones((b, sq, skv), dtype=bool)
    if causal:
        qp = q_positions if q_positions is not None else jnp.broadcast_to(
            jnp.arange(sq)[None, :], (b, sq))
        mask &= kv_pos[:, None, :] <= qp[:, :, None]
    if kv_valid_len is not None:
        mask &= kv_pos[:, None, :] < kv_valid_len[:, None, None]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *, chunk: int,
                 causal: bool, q_positions: jax.Array) -> jax.Array:
    """Q-chunked attention: peak score memory is [b, h, chunk, s] instead of
    [b, h, s, s]; causal chunks only read keys up to their last position.
    Python loop => concrete HLO (costs stay countable in the dry-run)."""
    b, s = q.shape[:2]
    outs = []
    for start in range(0, s, chunk):
        end = min(start + chunk, s)
        kv_end = end if causal else s
        outs.append(sdpa(q[:, start:end], k[:, :kv_end], v[:, :kv_end],
                         causal=causal, q_positions=q_positions[:, start:end]))
    return jnp.concatenate(outs, axis=1)


def attention_forward(params: Params, cfg: AttentionConfig, x: jax.Array,
                      positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence (training / prefill-without-cache) attention."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q, k, v = _qkv(params, cfg, x, positions)
    if cfg.chunk and s > cfg.chunk:
        out = sdpa_chunked(q, k, v, chunk=cfg.chunk, causal=cfg.causal,
                           q_positions=positions)
    else:
        out = sdpa(q, k, v, causal=cfg.causal, q_positions=positions)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Params:
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
    }


def attention_prefill(params: Params, cfg: AttentionConfig, x: jax.Array,
                      cache: Params, positions: jax.Array):
    """Prefill: run full attention AND write k/v into the cache at [0, s)."""
    q, k, v = _qkv(params, cfg, x, positions)
    s = x.shape[1]
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    if cfg.chunk and s > cfg.chunk:
        out = sdpa_chunked(q, k, v, chunk=cfg.chunk, causal=cfg.causal,
                           q_positions=positions)
    else:
        out = sdpa(q, k, v, causal=cfg.causal, q_positions=positions)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache


def attention_decode(params: Params, cfg: AttentionConfig, x: jax.Array,
                     cache: Params, positions: jax.Array):
    """One-token decode: x [b,1,d], positions [b,1] absolute position.

    Appends to cache at ``positions`` then attends over the valid prefix.
    """
    q, k, v = _qkv(params, cfg, x, positions)

    def write(buf, new):
        def upd(buf_b, new_b, pos_b):
            return jax.lax.dynamic_update_slice(buf_b, new_b.astype(buf_b.dtype), (pos_b, 0, 0))
        return jax.vmap(upd)(buf, new, positions[:, 0])

    cache = {"k": write(cache["k"], k), "v": write(cache["v"], v)}
    valid = positions[:, 0] + 1
    out = sdpa(q, cache["k"], cache["v"], causal=True,
               q_positions=positions, kv_valid_len=valid)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache


# ---------------------------------------------------------------------------
# cross-attention (enc-dec decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: AttentionConfig, dtype=jnp.bfloat16) -> Params:
    return init_attention(key, dataclasses.replace(cfg, qk_norm=False), dtype)


def cross_attention(params: Params, cfg: AttentionConfig, x: jax.Array,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """x: [b, sq, d]; enc_k/enc_v: precomputed [b, skv, hk, hd]."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    out = sdpa(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def encode_kv(params: Params, enc_out: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), in_axis_size=d_ff, dtype=dtype),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params["w_down"])


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, (d_ff, d_model), in_axis_size=d_ff, dtype=dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"]) + params["b_out"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> Params:
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Tied unembedding; returns f32 logits for loss stability."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))
