"""Encoder-decoder transformer (Whisper backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs`` feed
precomputed frame embeddings [b, frames, d_model] straight into the encoder.
Positional encoding uses RoPE as a stand-in for Whisper's sinusoidal/learned
tables (noted in DESIGN.md §8); LayerNorm + GELU match Whisper.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Params = Any


def _attn_cfg(cfg: ModelConfig, causal: bool) -> layers.AttentionConfig:
    return layers.AttentionConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
        qk_norm=False, rope_theta=cfg.rope_theta, causal=causal)


def _init_enc_layer(cfg: ModelConfig, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "pre_norm": layers.init_layernorm(cfg.d_model, dtype),
        "attn": layers.init_attention(k1, _attn_cfg(cfg, False), dtype),
        "post_norm": layers.init_layernorm(cfg.d_model, dtype),
        "mlp": layers.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(cfg: ModelConfig, key, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": layers.init_layernorm(cfg.d_model, dtype),
        "self_attn": layers.init_attention(k1, _attn_cfg(cfg, True), dtype),
        "norm2": layers.init_layernorm(cfg.d_model, dtype),
        "cross_attn": layers.init_cross_attention(k2, _attn_cfg(cfg, False), dtype),
        "norm3": layers.init_layernorm(cfg.d_model, dtype),
        "mlp": layers.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init_params(self, key) -> Params:
        cfg = self.cfg
        dtype = cfg.jnp_dtype
        k1, k2, k3 = jax.random.split(key, 3)
        enc = jax.vmap(lambda k: _init_enc_layer(cfg, k, dtype))(
            jax.random.split(k1, cfg.encoder_layers))
        dec = jax.vmap(lambda k: _init_dec_layer(cfg, k, dtype))(
            jax.random.split(k2, cfg.decoder_layers))
        return {
            "embedding": layers.init_embedding(k3, cfg.vocab_size, cfg.d_model, dtype),
            "enc_layers": enc,
            "dec_layers": dec,
            "enc_final_norm": layers.init_layernorm(cfg.d_model, dtype),
            "dec_final_norm": layers.init_layernorm(cfg.d_model, dtype),
        }

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda: self.init_params(jax.random.key(0)))

    # -- encoder -----------------------------------------------------------
    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        b, s, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

        def body(x, lp):
            h = layers.layernorm(lp["pre_norm"], x, cfg.norm_eps)
            x = x + layers.attention_forward(lp["attn"], _attn_cfg(cfg, False),
                                             h, positions)
            h = layers.layernorm(lp["post_norm"], x, cfg.norm_eps)
            return x + layers.gelu_mlp(lp["mlp"], h), None

        if cfg.parallel.remat != "none":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, frames, params["enc_layers"])
        return layers.layernorm(params["enc_final_norm"], x, cfg.norm_eps)

    # -- teacher-forced decoder (training) -----------------------------------
    def forward(self, params: Params, frames: jax.Array, tokens: jax.Array):
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
        x = layers.embed(params["embedding"], tokens)

        def body(x, lp):
            h = layers.layernorm(lp["norm1"], x, cfg.norm_eps)
            x = x + layers.attention_forward(lp["self_attn"], _attn_cfg(cfg, True),
                                             h, positions)
            h = layers.layernorm(lp["norm2"], x, cfg.norm_eps)
            ek, ev = layers.encode_kv(lp["cross_attn"], enc_out)
            x = x + layers.cross_attention(lp["cross_attn"], _attn_cfg(cfg, False),
                                           h, ek, ev)
            h = layers.layernorm(lp["norm3"], x, cfg.norm_eps)
            return x + layers.gelu_mlp(lp["mlp"], h), None

        if cfg.parallel.remat != "none":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        x = layers.layernorm(params["dec_final_norm"], x, cfg.norm_eps)
        return layers.unembed(params["embedding"], x), jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict[str, jax.Array]):
        logits, aux = self.forward(params, batch["frames"], batch["tokens"])
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + aux, {"ce_loss": loss, "aux_loss": aux}

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, enc_len: int) -> Params:
        cfg = self.cfg
        L, hk, hd = cfg.decoder_layers, cfg.num_kv_heads, cfg.head_dim_
        dt = cfg.jnp_dtype
        return {
            "pos": jnp.zeros((batch,), jnp.int32),
            "self_k": jnp.zeros((L, batch, cfg.max_target_len, hk, hd), dt),
            "self_v": jnp.zeros((L, batch, cfg.max_target_len, hk, hd), dt),
            "cross_k": jnp.zeros((L, batch, enc_len, hk, hd), dt),
            "cross_v": jnp.zeros((L, batch, enc_len, hk, hd), dt),
        }

    def abstract_cache(self, batch: int, enc_len: int) -> Params:
        return jax.eval_shape(lambda: self.init_cache(batch, enc_len))

    def prefill(self, params: Params, frames: jax.Array, tokens: jax.Array,
                cache: Params):
        """Encode frames, precompute cross-KV, prefill decoder self-KV."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
        x = layers.embed(params["embedding"], tokens)

        def body(x, xs):
            lp, csl = xs
            h = layers.layernorm(lp["norm1"], x, cfg.norm_eps)
            sa, kv = layers.attention_prefill(
                lp["self_attn"], _attn_cfg(cfg, True), h,
                {"k": csl["self_k"], "v": csl["self_v"]}, positions)
            x = x + sa
            h = layers.layernorm(lp["norm2"], x, cfg.norm_eps)
            ek, ev = layers.encode_kv(lp["cross_attn"], enc_out)
            x = x + layers.cross_attention(lp["cross_attn"], _attn_cfg(cfg, False),
                                           h, ek, ev)
            h = layers.layernorm(lp["norm3"], x, cfg.norm_eps)
            x = x + layers.gelu_mlp(lp["mlp"], h)
            return x, {"self_k": kv["k"], "self_v": kv["v"],
                       "cross_k": ek.astype(csl["cross_k"].dtype),
                       "cross_v": ev.astype(csl["cross_v"].dtype)}

        xs = (params["dec_layers"],
              {"self_k": cache["self_k"], "self_v": cache["self_v"],
               "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]})
        x, new = jax.lax.scan(body, x, xs)
        x = layers.layernorm(params["dec_final_norm"], x[:, -1:], cfg.norm_eps)
        logits = layers.unembed(params["embedding"], x)
        new["pos"] = jnp.full((b,), t, jnp.int32)
        return logits[:, 0], new

    def decode_step(self, params: Params, tokens: jax.Array, cache: Params):
        cfg = self.cfg
        b = tokens.shape[0]
        positions = cache["pos"][:, None]
        x = layers.embed(params["embedding"], tokens)

        def body(x, xs):
            lp, csl = xs
            h = layers.layernorm(lp["norm1"], x, cfg.norm_eps)
            sa, kv = layers.attention_decode(
                lp["self_attn"], _attn_cfg(cfg, True), h,
                {"k": csl["self_k"], "v": csl["self_v"]}, positions)
            x = x + sa
            h = layers.layernorm(lp["norm2"], x, cfg.norm_eps)
            x = x + layers.cross_attention(lp["cross_attn"], _attn_cfg(cfg, False),
                                           h, csl["cross_k"], csl["cross_v"])
            h = layers.layernorm(lp["norm3"], x, cfg.norm_eps)
            x = x + layers.gelu_mlp(lp["mlp"], h)
            return x, {"self_k": kv["k"], "self_v": kv["v"],
                       "cross_k": csl["cross_k"], "cross_v": csl["cross_v"]}

        xs = (params["dec_layers"],
              {"self_k": cache["self_k"], "self_v": cache["self_v"],
               "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]})
        x, new = jax.lax.scan(body, x, xs)
        x = layers.layernorm(params["dec_final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embedding"], x)
        new["pos"] = cache["pos"] + 1
        return logits[:, 0], new
