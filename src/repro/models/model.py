"""Unified model facade: one API over all 10 architecture families.

``build_model(cfg)`` returns a :class:`Model` with uniform entry points used
by the trainer, the serving engine, and the dry-run launcher:

  loss(params, batch)            train_4k cells
  prefill(params, batch, cache)  prefill_32k cells
  decode_step(params, tok, cache) decode_32k / long_500k cells

``*_specs`` methods return ShapeDtypeStruct stand-ins (no allocation) for the
dry-run path.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM

Params = Any


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.family == "encdec"
        self._impl = EncDecLM(cfg) if self.is_encdec else DecoderLM(cfg)

    # -- params --------------------------------------------------------------
    def init_params(self, key) -> Params:
        return self._impl.init_params(key)

    def abstract_params(self) -> Params:
        return self._impl.abstract_params()

    # -- training ------------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jax.Array]):
        return self._impl.loss(params, batch)

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        return self._impl.init_cache(batch, max_len)

    def abstract_cache(self, batch: int, max_len: int) -> Params:
        return self._impl.abstract_cache(batch, max_len)

    def prefill(self, params: Params, batch: Dict[str, jax.Array], cache: Params):
        if self.is_encdec:
            return self._impl.prefill(params, batch["frames"], batch["tokens"], cache)
        return self._impl.prefill(params, batch["tokens"], cache)

    def decode_step(self, params: Params, tokens: jax.Array, cache: Params):
        return self._impl.decode_step(params, tokens, cache)

    # -- dry-run input specs ---------------------------------------------------
    def train_batch_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        if self.is_encdec:
            t = cfg.max_target_len
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.jnp_dtype),
                "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, t), jnp.int32),
            }
        if cfg.embedding_inputs:
            return {
                "tokens": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.jnp_dtype),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }

    def prefill_batch_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        if self.is_encdec:
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.jnp_dtype),
                "tokens": jax.ShapeDtypeStruct((b, cfg.max_target_len), jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}

    def decode_token_specs(self, shape: ShapeConfig) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

    def cache_specs(self, shape: ShapeConfig):
        """Abstract cache sized for the cell: seq_len entries already valid."""
        return self.abstract_cache(shape.global_batch, shape.seq_len)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
