"""TrustDomain — the paper's contribution as a first-class framework feature.

A :class:`TrustDomain` is the deployment-level object that turns a plain
JAX inference/training stack into a *confidential* one (cLLM):

  1. models are loaded only from sealed checkpoints (ChaCha20 + HMAC,
     on-device unseal kernel),
  2. the domain attests itself (measurement -> quote) and the client-side
     :class:`~repro.core.attestation.Verifier` releases the sealing key only
     on a valid quote,
  3. prompt/response token I/O crosses the boundary through an encrypted
     :class:`~repro.core.bounce.BounceBuffer`,
  4. every boundary crossing is recorded in an audit log, and the calibrated
     overhead model prices the configuration for capacity planning.

Modes mirror the paper's platforms: "none" (bare), "vm", "sgx", "tdx",
"cgpu", "tpu_cc". Crypto is real in all confidential modes; the mode selects
the overhead profile used for modeled numbers and which boundary mechanisms
are active.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core import attestation, bounce, overheads, sealing

Params = Any


@dataclasses.dataclass
class AuditEvent:
    ts: float
    kind: str
    detail: str


class TrustDomain:
    def __init__(self, mode: str = "tdx",
                 sealing_key: Optional[sealing.SealingKey] = None,
                 io_key: Optional[sealing.SealingKey] = None,
                 platform_secret: Optional[bytes] = None):
        if mode != "none" and mode not in overheads.PROFILES:
            raise ValueError(f"unknown TEE mode '{mode}'")
        self.mode = mode
        self.confidential = mode != "none"
        self.sealing_key = sealing_key or sealing.SealingKey.generate()
        self.io_key = io_key or sealing.SealingKey.generate()
        self.channel = bounce.BounceBuffer(self.io_key)
        self.root = attestation.HardwareRoot(mode if self.confidential else "none",
                                             platform_secret)
        self.audit: List[AuditEvent] = []
        self._model_digest = ""
        self._code_hash: Optional[str] = None
        self._tenant_keys: Dict[str, sealing.SealingKey] = {}

    # -- audit ---------------------------------------------------------------
    def _log(self, kind: str, detail: str = ""):
        self.audit.append(AuditEvent(time.monotonic(), kind, detail))

    # -- sealing -------------------------------------------------------------
    def seal_params(self, params: Params, prefix: str = "params") -> Dict[str, sealing.SealedTensor]:
        sealed = sealing.seal_tree(self.sealing_key, params, prefix)
        self._model_digest = sealing.tree_digest(sealed)
        self._log("seal", f"{len(sealed)} tensors, digest={self._model_digest[:12]}")
        return sealed

    def load_sealed(self, sealed: Dict[str, sealing.SealedTensor],
                    treedef_like: Params, prefix: str = "params") -> Params:
        if not self.confidential:
            raise RuntimeError("load_sealed requires a confidential mode")
        params = sealing.unseal_tree(self.sealing_key, sealed, treedef_like, prefix)
        self._model_digest = sealing.tree_digest(sealed)
        self._log("unseal", f"{len(sealed)} tensors")
        return params

    # -- attestation ---------------------------------------------------------
    def measurement(self, config_repr: str = "") -> str:
        if self._code_hash is None:
            self._code_hash = attestation.measure_code()
        return attestation.measurement(self._code_hash, config_repr,
                                       self._model_digest)

    def quote(self, nonce: str, config_repr: str = "") -> attestation.Quote:
        q = self.root.quote(self.measurement(config_repr), nonce)
        self._log("quote", f"nonce={nonce[:8]}")
        return q

    def make_verifier(self, config_repr: str = "") -> attestation.Verifier:
        """Client-side verifier pinned to this domain's current measurement."""
        return attestation.Verifier(self.root, self.measurement(config_repr))

    # -- tenant key domains --------------------------------------------------
    def tenant_key(self, tenant: str) -> sealing.SealingKey:
        """The sealing-key domain for one tenant's KV/egress inside this
        worker. Derived (never stored) from the domain's sealing key with an
        HKDF-style label, so a blob sealed for tenant A fails MAC — not just
        decryption — under tenant B's domain or under the worker key itself.
        Workers attested by the same gateway receive identical tenant
        material, so the same derivation yields the same domain fleet-wide
        and sealed KV migrates across workers without re-keying."""
        k = self._tenant_keys.get(tenant)
        if k is None:
            k = self.sealing_key.derive(f"tenant/{tenant}")
            self._tenant_keys[tenant] = k
            self._log("tenant_key", f"derived domain for tenant={tenant}")
        return k

    def adopt_tenant_material(self, tenant: str, material: bytes) -> sealing.SealingKey:
        """Install a gateway-released per-tenant material as this worker's
        domain for ``tenant`` (fleet path: material comes from
        ``Verifier.release_tenant_key`` after this worker attested, so every
        worker in the fleet lands on the *same* tenant domain)."""
        k = sealing.SealingKey.generate(material)
        self._tenant_keys[tenant] = k
        self._log("tenant_key", f"adopted released domain for tenant={tenant}")
        return k

    # -- boundary I/O ----------------------------------------------------------
    def ingress(self, tokens: np.ndarray) -> np.ndarray:
        """Host -> trust domain. Encrypted in confidential modes."""
        if not self.confidential:
            return tokens
        sealed = self.channel.host_send(tokens)
        out = self.channel.device_recv(sealed)
        self._log("ingress", f"{sealed.n_bytes}B")
        return out

    def egress(self, tokens: np.ndarray) -> np.ndarray:
        """Trust domain -> host."""
        if not self.confidential:
            return tokens
        sealed = self.channel.device_send(tokens)
        out = self.channel.host_recv(sealed)
        self._log("egress", f"{sealed.n_bytes}B")
        return out

    def egress_tokens(self, stream_id: int, tokens) -> List[int]:
        """Trust domain -> host, streaming: ONE encrypted frame carrying
        ``tokens`` (a FramePolicy flush — 1 token per frame in the
        SecureChat-style default, N when coalescing). Each frame pays the
        fixed per-crossing cost the cgpu profile's ``fixed_boundary_s``
        models, so ``ChannelStats`` sees crossings (messages_out) and the
        tokens they amortize over (tokens_out) separately."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if not self.confidential:
            return [int(t) for t in toks]
        frame = self.channel.device_send_frame(stream_id, toks)
        out = self.channel.host_recv_frame(frame)
        self._log("egress_frame",
                  f"stream={stream_id} seq={frame.seq} n={toks.size} "
                  f"{frame.sealed.n_bytes}B")
        return [int(t) for t in out]

    def egress_token(self, stream_id: int, token: int) -> int:
        """Single-token convenience wrapper over :meth:`egress_tokens`."""
        return self.egress_tokens(stream_id, [token])[0]

    def record_seal(self, n_bytes: int, n_tensors: int, detail: str = "") -> None:
        """Account one sealed-KV eviction: ``n_bytes`` of ciphertext left the
        domain (page-granular backends move far less of it than whole-slot
        ones — the measurable difference serve_bench reports)."""
        self.channel.stats.seal_events += 1
        self.channel.stats.seal_bytes += int(n_bytes)
        self._log("seal_kv", f"{n_tensors} tensors {n_bytes}B {detail}".strip())

    def record_restore(self, n_bytes: int, n_tensors: int, detail: str = "") -> None:
        self.channel.stats.restore_events += 1
        self.channel.stats.restore_bytes += int(n_bytes)
        self._log("restore_kv", f"{n_tensors} tensors {n_bytes}B {detail}".strip())

    def record_store_hit(self, n_bytes: int, n_tensors: int,
                         detail: str = "") -> None:
        """Account one persistent-store restore: content-named ciphertext
        re-entered the domain instead of the prefill recomputing it —
        priced as a restore crossing (restore_events/bytes) plus the store
        counters the hit-rate and breakeven reports read."""
        self.channel.stats.restore_events += 1
        self.channel.stats.restore_bytes += int(n_bytes)
        self.channel.stats.store_hits += 1
        self.channel.stats.store_restored_bytes += int(n_bytes)
        self._log("store_hit",
                  f"{n_tensors} tensors {n_bytes}B {detail}".strip())

    def record_store_evict(self, n_bytes: int, n_tensors: int,
                           detail: str = "") -> None:
        """Account one store retention eviction. No boundary crossing —
        the host simply forgets ciphertext it was caching — so only the
        store counter moves (plus an audit line: what the retention policy
        sheds is part of the deployment's measurable behavior)."""
        self.channel.stats.store_evictions += 1
        self._log("store_evict",
                  f"{n_tensors} tensors {n_bytes}B {detail}".strip())

    def record_collective(self, n_bytes: int, seconds: float,
                          steps: int = 1) -> None:
        """Account ``steps`` decode steps' cross-device collective traffic
        (a mesh-spanning engine): ``n_bytes`` moved per device over the
        interconnect, taking a *measured* ``seconds`` (the ShardedPlan's
        shard_map all-gather probe). This is the traffic link_tax applies to;
        no audit event per step — the counters are the product."""
        self.channel.stats.collective_steps += int(steps)
        self.channel.stats.collective_bytes += int(n_bytes)
        self.channel.stats.collective_s += float(seconds)

    def open_stream(self) -> int:
        """Allocate a never-reused egress stream id (see BounceBuffer)."""
        return self.channel.open_stream()

    def close_stream(self, stream_id: int) -> None:
        """Release a finished request's per-stream channel state."""
        if self.confidential:
            self.channel.close_stream(stream_id)

    # -- overhead model -----------------------------------------------------
    def predict_overhead(self, terms: overheads.RooflineTerms,
                         **kw) -> Optional[overheads.OverheadBreakdown]:
        if not self.confidential:
            return None
        return overheads.predict(terms, self.mode, **kw)
