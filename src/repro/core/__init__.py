"""Confidential-computing core: the paper's contribution as a framework layer.

TrustDomain (confidential.py) composes sealing (encrypted weights, Pallas
unseal kernel), attestation (measurement/quote/key-release), encrypted token
I/O (bounce.py), and the calibrated TEE overhead model (overheads.py).
"""

from repro.core.confidential import TrustDomain
from repro.core.sealing import (
    SealingKey, SealedTensor, IntegrityError,
    seal_tensor, unseal_tensor, seal_tree, unseal_tree, tree_digest,
)
from repro.core.attestation import (
    Quote, HardwareRoot, Verifier, AttestationError, measurement, measure_code,
)
from repro.core.bounce import BounceBuffer
from repro.core.overheads import RooflineTerms, TEEProfile, PROFILES, predict

__all__ = [
    "TrustDomain", "SealingKey", "SealedTensor", "IntegrityError",
    "seal_tensor", "unseal_tensor", "seal_tree", "unseal_tree", "tree_digest",
    "Quote", "HardwareRoot", "Verifier", "AttestationError", "measurement",
    "measure_code", "BounceBuffer", "RooflineTerms", "TEEProfile", "PROFILES",
    "predict",
]
