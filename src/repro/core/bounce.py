"""Encrypted bounce buffer for host<->device token I/O.

NVIDIA cGPUs route every PCIe transfer through an encrypted+authenticated
bounce buffer (paper §V-A) — the main cGPU overhead source, amortized by
batch/input size (Insight 10). We implement the same structure for the
host<->TPU boundary: prompts enter and tokens leave the trust domain only as
ciphertext; the device side unseals with the ChaCha20 Pallas kernel.

The channel keeps byte/crypto counters so benchmarks can attribute boundary
costs exactly (fig04/fig11 harnesses).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.sealing import (IntegrityError, SealingKey, SealedTensor,
                                seal_tensor, unseal_tensor)


@dataclasses.dataclass
class ChannelStats:
    """Boundary-crossing counters. ``messages_out`` counts *crossings*
    (frames — the unit Insight 10's fixed cost is paid per); ``tokens_out``
    counts the tokens those frames carried. With per-token streaming the two
    are equal; a coalescing FramePolicy drives messages_out/tokens_out
    toward 1/N, which is exactly the amortization curve serve_bench plots."""
    messages_in: int = 0
    messages_out: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    tokens_out: int = 0
    # sealed-KV traffic (preemption evictions/restores): ciphertext that
    # leaves/re-enters the domain outside the token channel. Counted apart
    # from messages so crossings_per_token stays a pure egress metric.
    seal_events: int = 0
    seal_bytes: int = 0
    restore_events: int = 0
    restore_bytes: int = 0
    # cross-device collective traffic inside the domain (mesh-spanning
    # engines): bytes each device moved over the interconnect per decode
    # step, and the *measured* time those collectives took on the real mesh
    # (a shard_map all-gather probe, not the closed-form roofline estimate).
    # This is the traffic the encrypted-interconnect tax (link_tax) applies
    # to — overheads.predict(collective_s=stats.collective_s / steps) prices
    # it from observation instead of the model.
    collective_steps: int = 0
    collective_bytes: int = 0
    collective_s: float = 0.0
    # persistent sealed-page store (prefix-cache tier): hits re-enter the
    # domain as content-named ciphertext (also counted in restore_*, the
    # boundary they cross); evictions are host-side forgetting — no
    # crossing, tracked for the retention experiments.
    store_hits: int = 0
    store_restored_bytes: int = 0
    store_evictions: int = 0

    @property
    def crossings_per_token(self) -> float:
        return self.messages_out / self.tokens_out if self.tokens_out else 0.0

    @property
    def seal_bytes_per_event(self) -> float:
        return self.seal_bytes / self.seal_events if self.seal_events else 0.0

    @property
    def collective_s_per_step(self) -> float:
        return (self.collective_s / self.collective_steps
                if self.collective_steps else 0.0)

    def reset(self):
        self.messages_in = self.messages_out = 0
        self.bytes_in = self.bytes_out = 0
        self.tokens_out = 0
        self.seal_events = self.seal_bytes = 0
        self.restore_events = self.restore_bytes = 0
        self.collective_steps = self.collective_bytes = 0
        self.collective_s = 0.0
        self.store_hits = self.store_restored_bytes = 0
        self.store_evictions = 0


@dataclasses.dataclass
class TokenFrame:
    """One streamed egress message: the token(s) a request released together.

    Frames are the unit the paper's cGPU fixed cost is paid per (Insight 10):
    streaming one token per frame maximizes boundary crossings, which is
    exactly what ``ChannelStats`` must see to price the deployment honestly;
    a coalescing FramePolicy packs N tokens into one frame to amortize it.
    ``(stream_id, seq)`` is bound into the sealed tensor's name, so the nonce
    is unique per frame and the host side can detect replay or reordering.
    """
    stream_id: int
    seq: int
    sealed: SealedTensor

    @staticmethod
    def frame_name(stream_id: int, seq: int) -> str:
        return f"egress/s{stream_id}/{seq}"


class BounceBuffer:
    """Symmetric encrypted channel. ``host_*`` runs outside the trust domain,
    ``device_*`` inside. Sequence numbers make each message's nonce unique."""

    def __init__(self, key: SealingKey):
        self.key = key
        self.stats = ChannelStats()
        self._seq_in = 0
        self._seq_out = 0
        self._stream_seq: Dict[int, int] = {}   # stream id -> next send seq
        self._stream_recv: Dict[int, int] = {}  # stream id -> next expected seq
        self._next_stream = 0                   # ids never reused (nonce safety)
        # closed streams, compact: ids below the watermark are closed;
        # out-of-order closures wait in the set until it advances. The set
        # stays small while streams close roughly in open order — one
        # never-closed stream pins the watermark and the set tracks every
        # later closure, so abandon streams with close_stream, not silence.
        self._closed_lo = 0
        self._closed_set: set = set()

    # host -> device
    def host_send(self, tokens: np.ndarray) -> SealedTensor:
        name = f"ingress/{self._seq_in}"
        self._seq_in += 1
        sealed = seal_tensor(self.key, name, tokens)
        self.stats.messages_in += 1
        self.stats.bytes_in += sealed.n_bytes
        return sealed

    def device_recv(self, sealed: SealedTensor) -> np.ndarray:
        return np.asarray(unseal_tensor(self.key, sealed))

    # device -> host
    def device_send(self, tokens: np.ndarray) -> SealedTensor:
        name = f"egress/{self._seq_out}"
        self._seq_out += 1
        sealed = seal_tensor(self.key, name, tokens)
        self.stats.messages_out += 1
        self.stats.bytes_out += sealed.n_bytes
        return sealed

    def host_recv(self, sealed: SealedTensor) -> np.ndarray:
        return np.asarray(unseal_tensor(self.key, sealed))

    def open_stream(self) -> int:
        """Allocate a channel-global stream id. The channel — not the caller —
        owns the namespace: per-engine request ids restart at 0, and two
        engines sharing one TrustDomain must never land on the same
        ``egress/sN/M`` name (ChaCha20 nonce reuse)."""
        sid = self._next_stream
        self._next_stream += 1
        return sid

    def _stream_closed(self, stream_id: int) -> bool:
        return stream_id < self._closed_lo or stream_id in self._closed_set

    # device -> host, streaming: one frame per FramePolicy flush (1..N tokens)
    def device_send_frame(self, stream_id: int, tokens: np.ndarray) -> TokenFrame:
        if self._stream_closed(stream_id):
            raise IntegrityError(
                f"stream {stream_id} is closed; sending would restart its "
                f"seq at 0 and reuse a nonce")
        tokens = np.asarray(tokens, np.int32)
        seq = self._stream_seq.get(stream_id, 0)
        self._stream_seq[stream_id] = seq + 1
        name = TokenFrame.frame_name(stream_id, seq)
        sealed = seal_tensor(self.key, name, tokens)
        self.stats.messages_out += 1
        self.stats.bytes_out += sealed.n_bytes
        self.stats.tokens_out += int(tokens.size)
        return TokenFrame(stream_id, seq, sealed)

    def host_recv_frame(self, frame: TokenFrame) -> np.ndarray:
        if self._stream_closed(frame.stream_id):
            raise IntegrityError(
                f"stream {frame.stream_id} is closed "
                f"(replayed frame from a finished request)")
        expect = TokenFrame.frame_name(frame.stream_id, frame.seq)
        if frame.sealed.name != expect:
            raise IntegrityError(
                f"frame name mismatch: got '{frame.sealed.name}', "
                f"expected '{expect}'")
        # strict in-order receive per stream: a verbatim-replayed or
        # reordered frame carries a stale seq and is rejected even though
        # its MAC verifies.
        want = self._stream_recv.get(frame.stream_id, 0)
        if frame.seq != want:
            raise IntegrityError(
                f"stream {frame.stream_id}: got frame seq {frame.seq}, "
                f"expected {want} (replayed or reordered frame)")
        out = np.asarray(unseal_tensor(self.key, frame.sealed))
        # advance only after the MAC verified: a forged frame must not burn
        # the seq and lock out the authentic one behind it.
        self._stream_recv[frame.stream_id] = want + 1
        return out

    def close_stream(self, stream_id: int) -> None:
        """Retire a finished stream: its per-stream seq state is dropped
        (bounded memory in a long-running server) while the closed-watermark
        keeps its frames permanently unreplayable and its id unsendable."""
        self._stream_seq.pop(stream_id, None)
        self._stream_recv.pop(stream_id, None)
        self._closed_set.add(stream_id)
        while self._closed_lo in self._closed_set:
            self._closed_set.discard(self._closed_lo)
            self._closed_lo += 1

    def roundtrip(self, tokens: np.ndarray) -> Tuple[np.ndarray, SealedTensor]:
        """Convenience: host->device one message (tests/benchmarks)."""
        sealed = self.host_send(tokens)
        return self.device_recv(sealed), sealed
