"""Encrypted bounce buffer for host<->device token I/O.

NVIDIA cGPUs route every PCIe transfer through an encrypted+authenticated
bounce buffer (paper §V-A) — the main cGPU overhead source, amortized by
batch/input size (Insight 10). We implement the same structure for the
host<->TPU boundary: prompts enter and tokens leave the trust domain only as
ciphertext; the device side unseals with the ChaCha20 Pallas kernel.

The channel keeps byte/crypto counters so benchmarks can attribute boundary
costs exactly (fig04/fig11 harnesses).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.sealing import SealingKey, SealedTensor, seal_tensor, unseal_tensor


@dataclasses.dataclass
class ChannelStats:
    messages_in: int = 0
    messages_out: int = 0
    bytes_in: int = 0
    bytes_out: int = 0

    def reset(self):
        self.messages_in = self.messages_out = 0
        self.bytes_in = self.bytes_out = 0


class BounceBuffer:
    """Symmetric encrypted channel. ``host_*`` runs outside the trust domain,
    ``device_*`` inside. Sequence numbers make each message's nonce unique."""

    def __init__(self, key: SealingKey):
        self.key = key
        self.stats = ChannelStats()
        self._seq_in = 0
        self._seq_out = 0

    # host -> device
    def host_send(self, tokens: np.ndarray) -> SealedTensor:
        name = f"ingress/{self._seq_in}"
        self._seq_in += 1
        sealed = seal_tensor(self.key, name, tokens)
        self.stats.messages_in += 1
        self.stats.bytes_in += sealed.n_bytes
        return sealed

    def device_recv(self, sealed: SealedTensor) -> np.ndarray:
        return np.asarray(unseal_tensor(self.key, sealed))

    # device -> host
    def device_send(self, tokens: np.ndarray) -> SealedTensor:
        name = f"egress/{self._seq_out}"
        self._seq_out += 1
        sealed = seal_tensor(self.key, name, tokens)
        self.stats.messages_out += 1
        self.stats.bytes_out += sealed.n_bytes
        return sealed

    def host_recv(self, sealed: SealedTensor) -> np.ndarray:
        return np.asarray(unseal_tensor(self.key, sealed))

    def roundtrip(self, tokens: np.ndarray) -> Tuple[np.ndarray, SealedTensor]:
        """Convenience: host->device one message (tests/benchmarks)."""
        sealed = self.host_send(tokens)
        return self.device_recv(sealed), sealed
