"""Attestation: measurement, quotes, verification, key release.

Protocol structure follows SGX/TDX remote attestation (paper §II): the
enclave produces a *measurement* (hash chain over code + config + sealed
model digest), a hardware key signs a *quote* over (measurement, verifier
nonce, user data), and the verifier releases the model-sealing key only
after the quote checks out against the expected measurement.

The hardware root of trust is simulated (an HMAC key standing in for the
CPU's attestation key — DESIGN.md §8); everything above it is faithful,
including the freshness nonce and measurement binding.
"""

from __future__ import annotations

import dataclasses
import hashlib
import hmac
import json
import os
from pathlib import Path
from typing import Dict, Optional


class AttestationError(Exception):
    pass


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def measure_code(root: Optional[Path] = None) -> str:
    """Hash chain over the framework's own source files (MRENCLAVE analogue)."""
    root = root or Path(__file__).resolve().parents[1]
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(str(p.relative_to(root)).encode())
        h.update(hashlib.sha256(p.read_bytes()).digest())
    return h.hexdigest()


def measurement(code_hash: str, config_repr: str, model_digest: str) -> str:
    h = hashlib.sha256()
    for part in (code_hash, config_repr, model_digest):
        h.update(part.encode())
        h.update(b"|")
    return h.hexdigest()


# ---------------------------------------------------------------------------
# quotes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Quote:
    measurement: str
    nonce: str
    user_data: str
    platform: str        # "tdx" | "sgx" | "cgpu" | "tpu_cc"
    signature: str

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, s: str) -> "Quote":
        return cls(**json.loads(s))


class HardwareRoot:
    """Simulated per-device attestation key + the vendor's verification
    service that knows the corresponding public side."""

    def __init__(self, platform: str, device_secret: Optional[bytes] = None):
        self.platform = platform
        self._secret = device_secret or os.urandom(32)

    def quote(self, meas: str, nonce: str, user_data: str = "") -> Quote:
        payload = f"{meas}|{nonce}|{user_data}|{self.platform}".encode()
        sig = hmac.new(self._secret, payload, hashlib.sha256).hexdigest()
        return Quote(meas, nonce, user_data, self.platform, sig)

    def verify(self, q: Quote) -> bool:
        payload = f"{q.measurement}|{q.nonce}|{q.user_data}|{q.platform}".encode()
        expect = hmac.new(self._secret, payload, hashlib.sha256).hexdigest()
        return hmac.compare_digest(expect, q.signature)


# ---------------------------------------------------------------------------
# verifier / key broker
# ---------------------------------------------------------------------------

def derive_tenant_material(master: bytes, tenant: str) -> bytes:
    """Per-tenant key material from the broker's master secret. Deterministic
    (same master + tenant -> same bytes on every release), so every attested
    worker a tenant's traffic lands on derives the same sealing domain and
    sealed KV can migrate between them — while two tenants' materials are
    unrelated under the hash."""
    return hashlib.sha256(b"tenant|" + tenant.encode() + b"|" + master).digest()


class Verifier:
    """Client-side: checks quotes and releases sealing keys (key broker)."""

    def __init__(self, root: HardwareRoot, expected_measurement: str):
        self.root = root
        self.expected = expected_measurement
        self._nonces: Dict[str, bool] = {}
        self._released: Dict[str, bytes] = {}

    def challenge(self) -> str:
        nonce = os.urandom(16).hex()
        self._nonces[nonce] = False
        return nonce

    def verify(self, q: Quote) -> None:
        if q.nonce not in self._nonces:
            raise AttestationError("unknown or replayed nonce")
        if self._nonces[q.nonce]:
            raise AttestationError("nonce already used (replay)")
        if not self.root.verify(q):
            raise AttestationError("quote signature invalid")
        if q.measurement != self.expected:
            raise AttestationError(
                f"measurement mismatch: got {q.measurement[:16]}..., "
                f"expected {self.expected[:16]}...")
        self._nonces[q.nonce] = True

    def release_key(self, q: Quote, key_material: bytes) -> bytes:
        """Release the model sealing key only after successful attestation."""
        self.verify(q)
        self._released[q.nonce] = key_material
        return key_material

    def release_tenant_key(self, q: Quote, master: bytes,
                           tenant: str) -> bytes:
        """Release ONE tenant's key domain to an attested worker (the fleet
        gateway's per-tenant key-release flow): the quote is verified like
        any other release — fresh nonce, valid signature, expected
        measurement — and only the derived per-tenant material leaves the
        broker, never the master secret. An unattested or mis-measured
        worker gets :class:`AttestationError`, not a key."""
        self.verify(q)
        material = derive_tenant_material(master, tenant)
        self._released[q.nonce] = material
        return material
