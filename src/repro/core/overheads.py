"""Calibrated TEE overhead model — the paper's measurements as an analytical
performance model over roofline terms.

Each TEE profile decomposes the paper's measured overheads into where they
land on the roofline (DESIGN.md §1):

    t_plain = t_compute + t_memory + t_collective
    t_tee   = t_compute * (1 + compute_tax)
            + t_memory  * (1 + mem_tax)
            + t_coll    * (1 + link_tax)
            + fixed_boundary_s                      (per step)
    overhead = t_tee / t_plain - 1

Calibration targets (from the paper, Llama2-7B on EMR unless noted):
  * TDX single-socket: 5.51–10.68% thr overhead, memory-encryption dominated
    (Fig 4); virtualization tax alone 1.82–5.38% (VM row).
  * SGX: 4.80–6.15% (Fig 4); multi-socket up to 230% (broken NUMA, Fig 5/6 —
    exposed as `numa_broken_tax`).
  * TDX 2-socket: 12.11–23.81% (encrypted UPI + no NUMA binding, Fig 6).
  * Hugepage loss: 3.19–5.20% of raw perf (Insight 7).
  * cGPU (H100): 4.4–8% shrinking with batch/input (Fig 11) — dominated by a
    fixed per-launch bounce-buffer + kernel-launch cost, not memory (HBM is
    NOT encrypted on H100, §V-A).
  * cGPU scale-out: host-routed transfers cap at 3 GB/s vs 40 GB/s RDMA
    (§V-D4) -> link_tax ≈ 12.3.
  * AMX (Insight 8): raises compute share => relative overhead drops; that
    falls out of the model because mem_tax applies to a smaller fraction.

The model reproduces the *paper's* platforms; the `tpu_cc` profile is our
forward-looking TPU estimate (B100-style: HBM + ICI encryption on by
default), used for the confidential roofline in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Per-step times in seconds (from the dry-run roofline extraction)."""
    compute_s: float
    memory_s: float
    collective_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def bound(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)


@dataclasses.dataclass(frozen=True)
class TEEProfile:
    name: str
    compute_tax: float          # TEEs do not slow ALUs; virt tax lands here
    mem_tax: float              # inline memory encryption bandwidth tax
    link_tax: float             # encrypted / host-routed interconnect tax
    fixed_boundary_s: float     # per-step enclave exit / bounce / launch cost
    numa_broken_tax: float = 0.0   # extra mem tax if placement is TEE-default
    hugepage_loss: float = 0.0     # extra mem tax (TDX ignores 1G pages)
    notes: str = ""


PROFILES: Dict[str, TEEProfile] = {
    # virtualization only (the paper's "VM" rows): 1.82-5.38%
    "vm": TEEProfile("vm", compute_tax=0.03, mem_tax=0.03, link_tax=0.03,
                     fixed_boundary_s=0.0,
                     notes="raw VM; virtualization tax only (Fig 4)"),
    # Gramine-SGX: 4.80-6.15% single socket; catastrophic multi-socket
    "sgx": TEEProfile("sgx", compute_tax=0.005, mem_tax=0.085, link_tax=0.10,
                      fixed_boundary_s=8e-5, numa_broken_tax=2.2,
                      notes="EPC paging+enclave exits; no NUMA support (230% 2-socket)"),
    # TDX: 5.51-10.68% single socket; 12.11-23.81% two sockets
    "tdx": TEEProfile("tdx", compute_tax=0.03, mem_tax=0.11, link_tax=0.16,
                      fixed_boundary_s=4e-5, numa_broken_tax=0.35,
                      hugepage_loss=0.042,
                      notes="virt tax + memcrypt + encrypted UPI + no 1G pages"),
    # H100 confidential GPU: 4.4-8%, fixed-cost dominated; HBM unencrypted.
    # link_tax provenance (Insight 12, §V-D4): with CC on, multi-GPU traffic
    # cannot use direct RDMA and is host-routed through encrypted bounce
    # buffers, capping at ~3 GB/s against ~40 GB/s plain — the same bytes
    # take 40/3 ≈ 13.3x longer, i.e. a tax of 40/3 - 1 ≈ 12.3 on whatever
    # time the collectives already cost. That collective time is the one
    # input ``predict`` will happily take *measured* (its ``collective_s``
    # override, fed from ChannelStats on a mesh-spanning engine) instead of
    # from the closed-form roofline estimate.
    "cgpu": TEEProfile("cgpu", compute_tax=0.0, mem_tax=0.0, link_tax=12.3,
                       fixed_boundary_s=3.5e-4,
                       notes="PCIe bounce buffer + launch latency; "
                             "host-routed scale-out 3 vs 40 GB/s (§V-D4)"),
    # forward-looking TPU confidential profile (B100-style full encryption)
    "tpu_cc": TEEProfile("tpu_cc", compute_tax=0.0, mem_tax=0.08, link_tax=0.15,
                         fixed_boundary_s=2e-5,
                         notes="hypothetical: HBM + ICI inline encryption, "
                               "DMA bounce for DCN"),
}


@dataclasses.dataclass(frozen=True)
class OverheadBreakdown:
    profile: str
    t_plain_s: float
    t_tee_s: float
    overhead: float
    per_term: Dict[str, float]

    def as_row(self) -> str:
        parts = ", ".join(f"{k}:{v * 100:.2f}%" for k, v in self.per_term.items())
        return (f"{self.profile}: {self.overhead * 100:.2f}% "
                f"({self.t_plain_s * 1e3:.3f} -> {self.t_tee_s * 1e3:.3f} ms; {parts})")


def predict(terms: RooflineTerms, profile: str | TEEProfile,
            *, numa_bound: bool = True, hugepages_fixed: bool = True,
            steps: int = 1,
            collective_s: Optional[float] = None) -> OverheadBreakdown:
    """TEE overhead for one step given plain roofline terms.

    ``numa_bound=False`` models the paper's broken-NUMA deployments (Fig 5/6);
    ``hugepages_fixed=False`` adds the TDX hugepage loss (Insight 7).

    ``collective_s`` overrides ``terms.collective_s`` with a *measured*
    per-step collective time — e.g. ``ChannelStats.collective_s_per_step``
    from a mesh-spanning engine, where the time comes from a real all-gather
    on the serving mesh rather than the bytes/ICI_BW closed form. link_tax
    then prices the encrypted interconnect from observation: the cgpu value
    of 12.3 is Insight 12's host-routed 3-vs-40 GB/s ratio (see PROFILES),
    and applying it to a measured baseline is exactly the §V-D4 experiment.
    """
    p = PROFILES[profile] if isinstance(profile, str) else profile
    if collective_s is not None:
        terms = dataclasses.replace(terms, collective_s=float(collective_s))
    mem_tax = p.mem_tax
    if not numa_bound:
        mem_tax += p.numa_broken_tax
    if not hugepages_fixed:
        mem_tax += p.hugepage_loss
    d_comp = terms.compute_s * p.compute_tax
    d_mem = terms.memory_s * mem_tax
    d_coll = terms.collective_s * p.link_tax
    d_fixed = p.fixed_boundary_s * steps
    t_plain = terms.total_s * steps
    t_tee = t_plain + (d_comp + d_mem + d_coll) * steps + d_fixed
    # per_term fractions are normalized by t_plain (not by the delta), so
    # they intentionally sum to `overhead` — each entry reads directly as
    # "percentage points of slowdown attributable to this term".
    per_term = {
        "compute": d_comp * steps / t_plain,
        "memory": d_mem * steps / t_plain,
        "collective": d_coll * steps / t_plain,
        "boundary": d_fixed / t_plain,
    }
    return OverheadBreakdown(p.name, t_plain, t_tee, t_tee / t_plain - 1.0, per_term)


# how an observed decode-step latency is apportioned between roofline terms
# when no per-term measurement exists (launchers' standing estimate for a
# decode-bound serving point: mostly memory, some compute, the remainder
# collective/boundary). One definition — serve.py's modeled-overhead block
# and measured_link_tax must price from the same split.
STEP_COMPUTE_FRACTION = 0.3
STEP_MEMORY_FRACTION = 0.65


def measured_link_tax(channel_stats, profile: str, step_s: float
                      ) -> "tuple[OverheadBreakdown, OverheadBreakdown, str]":
    """Measured-vs-modeled link-tax comparison for a mesh-spanning engine.

    ``channel_stats`` is a :class:`~repro.core.bounce.ChannelStats` (duck-
    typed): its ``collective_bytes``/``collective_steps`` give the per-step
    interconnect volume, priced once through the closed-form roofline
    estimate (bytes / ICI_BW) and once through the *measured* per-step
    collective time (``collective_s_per_step``, an all-gather probe on the
    real mesh). ``step_s`` is the observed decode-step latency the
    compute/memory terms are apportioned from (the launcher's standing
    0.3/0.65 split). Returns (modeled, measured, report line) — one
    formatter, shared by serve.py and serve_bench.py, so the pricing cannot
    silently diverge between them.
    """
    from repro.roofline.analysis import ICI_BW   # lazy: core <-/-> roofline
    steps = max(channel_stats.collective_steps, 1)
    per_step_b = channel_stats.collective_bytes // steps
    modeled_s = per_step_b / ICI_BW
    measured_s = channel_stats.collective_s_per_step
    terms = RooflineTerms(compute_s=STEP_COMPUTE_FRACTION * step_s,
                          memory_s=STEP_MEMORY_FRACTION * step_s,
                          collective_s=modeled_s)
    modeled = predict(terms, profile)
    measured = predict(terms, profile, collective_s=measured_s)
    line = (f"{per_step_b} collective B/step over "
            f"{channel_stats.collective_steps} steps; collective_s modeled "
            f"{modeled_s * 1e6:.1f}us vs measured {measured_s * 1e6:.1f}us "
            f"-> TEE overhead {modeled.overhead * 100:.2f}% vs "
            f"{measured.overhead * 100:.2f}% "
            f"(delta {(measured.overhead - modeled.overhead) * 100:+.2f} pts)")
    return modeled, measured, line


def fused_unseal_savings(fused_pages: int, fused_bytes: int,
                         profile: str | TEEProfile
                         ) -> "tuple[Optional[OverheadBreakdown], str]":
    """Price what a sealed-KV restore avoided by admitting pages as
    ciphertext (kernels/paged_attention.py's fused in-kernel unseal)
    instead of host-decrypting them into the pool.

    The host-decrypt path pays, per restored page, (a) a ChaCha20 XOR pass
    that reads the ciphertext and writes the plaintext back — a 2x
    page-bytes round-trip through encrypted memory — and (b) one boundary
    event staging the decrypted page to the device pool. The fused path
    writes the ciphertext into the pool once (a write both paths share)
    and decrypts on the page read the attention kernel performs anyway, so
    the round-trip and the per-page boundary events vanish. That avoided
    work is priced through :func:`predict` itself — one page-sized memory
    term per page, ``steps=pages`` so ``fixed_boundary_s`` lands once per
    page — keeping the savings in the same currency (and under the same
    taxes) as every other number this module emits.

    Returns (breakdown | None, report line); None when nothing went fused.
    """
    from repro.roofline.analysis import HBM_BW   # lazy: core <-/-> roofline
    if fused_pages <= 0 or fused_bytes <= 0:
        return None, "fused-unseal savings: none (no ciphertext-resident pages)"
    per_page = fused_bytes / fused_pages
    terms = RooflineTerms(compute_s=0.0, memory_s=2 * per_page / HBM_BW)
    brk = predict(terms, profile, steps=fused_pages)
    line = (f"fused-unseal savings ({brk.profile}): {fused_pages} pages / "
            f"{fused_bytes} B stayed ciphertext-resident; avoided "
            f"{brk.t_tee_s * 1e6:.1f}us restore cost "
            f"({brk.t_plain_s * 1e6:.1f}us HBM round-trip + "
            f"{(brk.t_tee_s - brk.t_plain_s) * 1e6:.1f}us TEE tax incl. "
            f"{fused_pages} boundary events)")
    return brk, line


# standing per-token prefill compute estimate for the store pricer when the
# caller has no measurement (seconds per prompt token, batch-1 CPU-class
# decode hardware; benches override it with (cold prefill wall / tokens)).
# One definition — serve.py and the retention policy must price recompute
# from the same constant.
PREFILL_TOKEN_COMPUTE_S = 2e-5


def store_restore_savings(pages: int, stored_bytes: int, tokens: int,
                          profile: str | TEEProfile,
                          *, prefill_token_s: Optional[float] = None
                          ) -> "tuple[Optional[OverheadBreakdown], Optional[OverheadBreakdown], str]":
    """Price a sealed-page-store hit both ways: restore vs recompute.

    A store hit moves ``stored_bytes`` of content-named ciphertext back
    across the TEE boundary (one boundary event per page, a decrypt pass
    through encrypted memory) instead of re-running the prefill that
    produced those ``tokens`` positions. Both sides are priced through
    :func:`predict` so the breakeven lands in the same currency — and
    under the same taxes — as every other number this module emits:

    * restore: a page-sized memory term per page (``steps=pages`` so
      ``fixed_boundary_s`` lands once per restored page, like
      :func:`fused_unseal_savings`), zero compute;
    * recompute: ``tokens * prefill_token_s`` of compute plus the single
      KV write-out the prefill performs (the restore path writes the same
      plaintext into the pool, so only the boundary/decrypt side differs).

    Returns (restore, recompute, report line); (None, None, line) when
    nothing was restored. The retention policy's cost score and the
    serve/bench report lines both come from here.
    """
    from repro.roofline.analysis import HBM_BW   # lazy: core <-/-> roofline
    if pages <= 0 or stored_bytes <= 0:
        return None, None, ("store restore-vs-recompute: none "
                            "(no store-restored pages)")
    per_tok = PREFILL_TOKEN_COMPUTE_S if prefill_token_s is None \
        else float(prefill_token_s)
    per_page = stored_bytes / pages
    restore = predict(RooflineTerms(compute_s=0.0,
                                    memory_s=2 * per_page / HBM_BW),
                      profile, steps=pages)
    recompute = predict(RooflineTerms(compute_s=per_tok * tokens,
                                      memory_s=stored_bytes / HBM_BW),
                        profile)
    net = recompute.t_tee_s - restore.t_tee_s
    verdict = "store wins" if net > 0 else "recompute wins"
    line = (f"store restore-vs-recompute ({restore.profile}): {pages} pages / "
            f"{stored_bytes} B sealed across the boundary vs {tokens} prefill "
            f"tokens recomputed -> restore {restore.t_tee_s * 1e6:.1f}us vs "
            f"recompute {recompute.t_tee_s * 1e6:.1f}us "
            f"({verdict}, net {abs(net) * 1e6:.1f}us)")
    return restore, recompute, line


def sweep_batch(profile: str, compute_per_token_s: float, memory_s: float,
                batches: list[int]) -> Dict[int, float]:
    """Paper Fig 9/11 shape: overhead vs batch size. Compute scales with
    batch; weight-streaming memory time is ~flat until saturation."""
    out = {}
    for b in batches:
        terms = RooflineTerms(compute_s=compute_per_token_s * b, memory_s=memory_s)
        out[b] = predict(terms, profile).overhead
    return out
