"""Sealed tensors: encrypt-then-MAC at rest, Pallas-kernel decrypt on device.

The TPU-native analogue of TDX/SGX inline memory encryption (DESIGN.md §2):
model weights and KV pages are stored/moved as ChaCha20 ciphertext in the
kernel-friendly blocked layout and XOR-decrypted on the way into compute by
``kernels/chacha20.py``. Integrity is encrypt-then-MAC with HMAC-SHA256 over
(header || ciphertext) — a flipped ciphertext bit fails verification before
any plaintext is produced (the integrity property HE schemes lack, §II).

Nonces are derived per-tensor from (key id, tensor name) so no (key, nonce)
pair is ever reused across tensors; the block counter spans within a tensor.
"""

from __future__ import annotations

import dataclasses
import hashlib
import hmac
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

Params = Any


class IntegrityError(Exception):
    """MAC verification failed — ciphertext or header was tampered with."""


@dataclasses.dataclass(frozen=True)
class SealingKey:
    key: bytes          # 32-byte ChaCha20 key
    mac_key: bytes      # 32-byte HMAC key (independent)

    @classmethod
    def generate(cls, seed: bytes | None = None) -> "SealingKey":
        if seed is not None:
            k = hashlib.sha256(b"seal" + seed).digest()
            m = hashlib.sha256(b"mac" + seed).digest()
            return cls(k, m)
        return cls(os.urandom(32), os.urandom(32))

    def derive(self, label: str) -> "SealingKey":
        """HKDF-style labeled subkey: a *key domain* carved out of this key.

        Both halves are derived independently (``expand(label, key)`` /
        ``expand(label, mac_key)``) so the MAC domain separates too: a blob
        sealed under ``k.derive("tenant/a")`` fails MAC verification — not
        merely decryption — under ``k.derive("tenant/b")`` or under ``k``
        itself. That is what makes cross-tenant restore fail *by integrity
        check* rather than by convention (the fleet's per-tenant KV
        isolation rests on this). Derivation is deterministic, so two
        attested workers handed the same master material derive the same
        tenant domain and sealed KV migrates between them."""
        lb = label.encode()
        return SealingKey(
            hashlib.sha256(b"derive/key|" + lb + b"|" + self.key).digest(),
            hashlib.sha256(b"derive/mac|" + lb + b"|" + self.mac_key).digest())

    @property
    def key_words(self) -> jax.Array:
        return jnp.asarray(np.frombuffer(self.key, np.uint32))

    def key_id(self) -> str:
        return hashlib.sha256(self.key).hexdigest()[:16]


def _nonce_for(key: SealingKey, name: str) -> bytes:
    return hashlib.sha256(key.key_id().encode() + b"|" + name.encode()).digest()[:12]


def nonce_words_for(key: SealingKey, name: str) -> np.ndarray:
    """The blob's ChaCha20 nonce as uint32[3] — what a ciphertext-resident
    page's crypt sidecar carries so the fused decode kernel can regenerate
    the exact keystream this name was sealed under."""
    return np.frombuffer(_nonce_for(key, name), np.uint32)


def shared_page_name(content_key: bytes, kpath: str) -> str:
    """The canonical sealed-tensor name for content-addressed KV pages
    (shared-page parking and the persistent page store). Derived from the
    page's content key alone, so identical content always seals under the
    same name — and therefore the same nonce AND the same plaintext, the
    pairing that makes a deterministic nonce safe to mint repeatedly: a
    re-seal of the same content can never put two plaintexts under one
    (key, nonce)."""
    return f"kvshared/{content_key.hex()}{kpath}"


@dataclasses.dataclass
class SealedTensor:
    name: str
    ciphertext: jax.Array    # uint32 [16, N] blocked layout
    mac: bytes
    shape: Tuple[int, ...]
    dtype: str
    n_bytes: int

    def header(self) -> bytes:
        return f"{self.name}|{self.shape}|{self.dtype}|{self.n_bytes}".encode()


def _mac(key: SealingKey, sealed_header: bytes, ciphertext: jax.Array) -> bytes:
    h = hmac.new(key.mac_key, sealed_header, hashlib.sha256)
    h.update(np.asarray(ciphertext).tobytes())
    return h.digest()


def seal_tensor(key: SealingKey, name: str, array: jax.Array) -> SealedTensor:
    arr = np.asarray(array)
    raw = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
    blocked, n_bytes = ops.pack_u32(raw)
    nonce = jnp.asarray(np.frombuffer(_nonce_for(key, name), np.uint32))
    ciphertext = ops.seal_u32(key.key_words, nonce, blocked)
    st = SealedTensor(name=name, ciphertext=ciphertext, mac=b"",
                      shape=tuple(arr.shape), dtype=str(arr.dtype),
                      n_bytes=n_bytes)
    st.mac = _mac(key, st.header(), ciphertext)
    return st


def verify_mac(key: SealingKey, sealed: SealedTensor) -> None:
    """MAC-check a sealed tensor *without* decrypting it.

    The fused-unseal decode path (kernels/paged_attention.py) admits
    ciphertext directly into the KV pool and decrypts in-kernel, so the
    usual unseal_tensor gate never runs for those pages — this is the
    integrity gate that must pass before any kernel consumes the bits.
    Raises :class:`IntegrityError` on mismatch, like unseal_tensor.
    """
    expect = _mac(key, sealed.header(), sealed.ciphertext)
    if not hmac.compare_digest(expect, sealed.mac):
        raise IntegrityError(f"MAC mismatch for tensor '{sealed.name}'")


def ciphertext_page_bytes(sealed: SealedTensor) -> bytes:
    """Serialize blocked ciphertext to the linear RFC 8439 byte stream.

    ``[16, N].T.reshape(-1)`` is a pure permutation (linear word i is
    keystream word i%16 of counter block i//16), so the pool can hold the
    ciphertext *bit-for-bit* in the plaintext layout and the in-kernel
    keystream XOR (generated linearly per page) lines up word-for-word.
    """
    lin = np.asarray(sealed.ciphertext).T.reshape(-1)
    return lin.astype("<u4").tobytes()[:sealed.n_bytes]


def unseal_tensor(key: SealingKey, sealed: SealedTensor) -> jax.Array:
    expect = _mac(key, sealed.header(), sealed.ciphertext)
    if not hmac.compare_digest(expect, sealed.mac):
        raise IntegrityError(f"MAC mismatch for tensor '{sealed.name}'")
    nonce = jnp.asarray(np.frombuffer(_nonce_for(key, sealed.name), np.uint32))
    blocked = ops.unseal_u32(key.key_words, nonce, sealed.ciphertext)
    raw = ops.unpack_u32(blocked, sealed.n_bytes)
    arr = raw.view(np.dtype(sealed.dtype)).reshape(sealed.shape)
    return jnp.asarray(arr)


# ---------------------------------------------------------------------------
# pytrees
# ---------------------------------------------------------------------------

def seal_tree(key: SealingKey, tree: Params, prefix: str = "params",
              suffix: str = "") -> Dict[str, SealedTensor]:
    """``suffix`` lands after the leaf path in every derived name
    (``{prefix}{leaf}{suffix}``): sharded backends tag each seal with the
    addressable shard it was read from (``/s{shard}``), so two hosts sealing
    concurrently under one prefix can never collide in nonce space."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = prefix + jax.tree_util.keystr(path) + suffix
        out[name] = seal_tensor(key, name, leaf)
    return out


def unseal_tree(key: SealingKey, sealed: Dict[str, SealedTensor],
                treedef_like: Params, prefix: str = "params",
                suffix: str = "") -> Params:
    flat, treedef = jax.tree_util.tree_flatten_with_path(treedef_like)
    leaves = []
    for path, _ in flat:
        name = prefix + jax.tree_util.keystr(path) + suffix
        leaves.append(unseal_tensor(key, sealed[name]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def sealed_nbytes(sealed: Dict[str, SealedTensor]) -> int:
    """Total plaintext bytes a sealed dict carries (the boundary-crossing
    payload a preemption moves; headers/MACs excluded for comparability)."""
    return sum(st.n_bytes for st in sealed.values())


def tree_digest(sealed: Dict[str, SealedTensor]) -> str:
    """Stable digest over all MACs — bound into the attestation measurement."""
    h = hashlib.sha256()
    for name in sorted(sealed):
        h.update(name.encode())
        h.update(sealed[name].mac)
    return h.hexdigest()
