import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: lower a cell under a named optimization variant
and report the roofline delta vs baseline.

    python -m repro.launch.hillclimb --arch deepseek-7b --shape decode_32k \
        --variant baseline --out results/perf_iterations.json

Variants are declared in VARIANTS as ParallelConfig overrides; each maps to
one hypothesis->change->measure iteration in EXPERIMENTS.md §Perf.
"""

import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.dryrun import lower_cell

# name -> dict of ParallelConfig field overrides
VARIANTS = {
    "baseline": {},
    "attn-chunk": {"attention_chunk": 512},
    "loss-chunk": {"loss_chunk": 512},
    "attn+loss-chunk": {"attention_chunk": 512, "loss_chunk": 512},
    "attn+loss-chunk+mb8": {"attention_chunk": 512, "loss_chunk": 512,
                            "microbatches": 8},
    "attn+loss-chunk+mb4": {"attention_chunk": 512, "loss_chunk": 512,
                            "microbatches": 4},
    "remat-dots": {"remat": "dots_saveable"},
    "attn+loss-chunk+remat-dots": {"attention_chunk": 512, "loss_chunk": 512,
                                   "remat": "dots_saveable"},
    "attn+loss-chunk+mb8+remat-dots": {"attention_chunk": 512,
                                       "loss_chunk": 512, "microbatches": 8,
                                       "remat": "dots_saveable"},
    "dp-over-model": {"dp_over_model": True, "fsdp": True},
    "dp-over-model+loss-chunk": {"dp_over_model": True, "fsdp": True,
                                 "loss_chunk": 512},
    "dp-over-model+loss-chunk+mb4": {"dp_over_model": True, "fsdp": True,
                                     "loss_chunk": 512, "microbatches": 4},
    "opt-bf16": {"optimizer_dtype": "bfloat16"},
    "cache-carry": {"decode_cache_carry": True},
    "dp-over-model+zero1+loss-chunk": {"dp_over_model": True, "zero1": True,
                                       "loss_chunk": 512},
}


def run(arch: str, shape: str, variant: str, multi_pod: bool = False):
    cfg = get_config(arch)
    overrides = VARIANTS[variant]
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, **overrides))
    cell = lower_cell(arch, shape, multi_pod=multi_pod, cfg_override=cfg)
    rec = cell if isinstance(cell, dict) else cell.to_dict()
    rec["variant"] = variant
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/perf_iterations.json")
    args = ap.parse_args()

    rec = run(args.arch, args.shape, args.variant, args.multi_pod)
    out = Path(args.out)
    results = json.loads(out.read_text()) if out.exists() else []
    results = [r for r in results
               if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                       and r.get("variant") == rec["variant"])]
    results.append(rec)
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
