"""Serving launcher: confidential continuous-batching inference for any
registered architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
        --tee tdx --requests 8 --max-new-tokens 16

The full (non-smoke) configs are the production path (TPU slice); smoke
configs serve on CPU. With a confidential mode the launcher performs the
whole paper pipeline: seal -> attest -> key release -> encrypted serving.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_configs, smoke_config
from repro.core import RooflineTerms, TrustDomain
from repro.models import build_model
from repro.runtime.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tee", default="tdx")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-len", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("the token-in/token-out server needs a decoder-family arch")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    td = TrustDomain(args.tee)
    if td.confidential:
        sealed = td.seal_params(params)
        params = td.load_sealed(sealed, params)
        verifier = td.make_verifier(cfg.name)
        quote = td.quote(verifier.challenge(), cfg.name)
        verifier.verify(quote)
        print(f"[{args.tee}] attested; model digest bound "
              f"({quote.measurement[:16]}...)")

    engine = Engine(model, params, max_slots=args.slots, max_len=args.max_len,
                    prefill_len=args.prefill_len, trust_domain=td)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for i in range(args.requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 200),
                              args.prefill_len).astype(np.int32)
        engine.submit(prompt, args.max_new_tokens)
    stats = engine.run()
    wall = time.monotonic() - t0

    print(f"served {stats.total_requests} requests / {stats.total_tokens} "
          f"tokens in {wall:.2f}s")
    print(f"throughput {stats.throughput_tps:.1f} tok/s | next-token latency "
          f"mean {stats.mean_latency_s * 1e3:.1f}ms p99 {stats.p99_latency_s * 1e3:.1f}ms")
    if td.confidential:
        print(f"boundary: {td.channel.stats}")
        step = stats.mean_latency_s or 1e-3
        terms = RooflineTerms(compute_s=0.3 * step, memory_s=0.65 * step,
                              collective_s=0.05 * step)
        print("modeled platform overhead:", td.predict_overhead(terms).as_row())


if __name__ == "__main__":
    main()
