"""Serving launcher: confidential continuous-batching inference for any
registered architecture, on the v3 request-object API.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
        --tee tdx --requests 8 --max-new-tokens 16 \
        --prefill-buckets 8,16,32 --priority-mix 0:3,5:1 \
        --coalesce 4 --sample-temp 0.8 --top-k 40 --top-p 0.9 --seed 7 \
        --kv-backend paged --page-size 16 --mesh dp=2

The full (non-smoke) configs are the production path (TPU slice); smoke
configs serve on CPU. With a confidential mode the launcher performs the
whole paper pipeline: seal -> attest -> key release -> encrypted serving.
``--coalesce N`` packs N tokens per encrypted egress frame (Insight-10
fixed-cost amortization); ``--sample-temp/--top-k/--top-p/--seed`` turn on
seeded per-request sampling; ``--priority-mix`` assigns weighted priorities
so the sealed-KV preemption path is exercised under load. ``--kv-backend
paged`` swaps the dense slot cache for the page-pool layout (page-granular
admission and sealing; see repro.runtime.kvcache for the selection guide).
``--mesh dp=N[,tp=M]`` spans the engine across a device mesh (relaunching
with forced host devices when needed) and reports the measured-vs-modeled
encrypted-interconnect (link_tax) comparison — the collective time is then
a real all-gather on the serving mesh, not the closed-form estimate.
``--prefix-sharing`` (with ``--shared-prefix-len K`` to give the generated
workload a common K-token opening) turns on content-indexed shared prompt
pages with copy-on-write and on-demand page allocation, and reports the
shared-page map / CoW counters next to the sealed-traffic line.
``--page-store`` (implies ``--prefix-sharing``) retains content-named
sealed pages past the last live/parked reference in a persistent
prefix-cache tier (``--store-budget-pages N`` bounds it, ``--store-policy
lru|cost`` picks the retention scoring); with ``--epochs E`` the launcher
replays the same workload E times so a recurring-prompt mix shows the
second epoch hitting the store instead of re-prefilling, and the report
prices the restore-vs-recompute breakeven.
``--continuous-batching`` (optionally ``--step-tokens N``) interleaves
prefill admissions into decode steps under a per-step token budget instead
of filling a bucket first; ``--prefill-plan dedicated`` disaggregates
prefill onto its own compute plan, and the sealed plan-to-plan KV handoff
is reported (and priced in ChannelStats) on its own accounting line;
``--handoff-batch N`` groups N finished prefill rows per sealed crossing.
``--reject-infeasible`` (with ``--deadline-s`` stamping a deadline on every
request) turns on admission-time feasibility rejection: a request whose
deadline cannot be met even under a one-sided lower bound on step time is
rejected before any boundary crossing is spent on it.

``--workers N`` switches the launcher into fleet mode: N engine workers,
each in its own TrustDomain, behind an attested gateway (quote-gated
per-tenant key release, prompt envelopes) and an orchestrator
(``--placement`` policy, ``--tenants M`` round-robin tenancy).
``--kill-worker-at STEP`` forcibly fails a worker mid-serve; its sealed KV
migrates to survivors under the per-tenant key domains and every in-flight
request still completes (byte-identically — seeded sampling travels with
the request).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_configs, smoke_config
from repro.core import RooflineTerms, TrustDomain
from repro.core.overheads import (STEP_COMPUTE_FRACTION,
                                  STEP_MEMORY_FRACTION, fused_unseal_savings,
                                  measured_link_tax, store_restore_savings)
from repro.launch.mesh import ensure_host_devices
from repro.models import build_model
from repro.runtime import (Engine, FramePolicy, GenerationRequest,
                           SamplingParams, parse_mesh)


def parse_buckets(spec: str):
    try:
        return tuple(int(b) for b in spec.split(",") if b.strip())
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--prefill-buckets wants comma-separated ints, got {spec!r}")


def parse_priority_mix(spec: str):
    """``prio:weight,prio:weight`` -> (priorities, weights)."""
    prios, weights = [], []
    try:
        for part in spec.split(","):
            p, w = part.split(":")
            prios.append(int(p))
            weights.append(float(w))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--priority-mix wants 'prio:weight,...', got {spec!r}")
    total = sum(weights)
    if total <= 0:
        raise argparse.ArgumentTypeError("--priority-mix weights must sum > 0")
    return prios, [w / total for w in weights]


def engine_kwargs(args):
    """Engine construction kwargs shared by the single-engine and fleet
    paths (mesh and trust_domain are path-specific)."""
    return dict(max_slots=args.slots, max_len=args.max_len,
                prefill_len=args.prefill_len,
                prefill_buckets=args.prefill_buckets,
                kv_backend=args.kv_backend, page_size=args.page_size,
                num_pages=args.num_pages,
                prefix_sharing=args.prefix_sharing,
                kv_alloc=args.kv_alloc,
                kv_decode=args.kv_decode,
                continuous_batching=args.continuous_batching,
                step_tokens=args.step_tokens,
                prefill_plan=args.prefill_plan,
                handoff_batch=args.handoff_batch,
                reject_infeasible=args.reject_infeasible,
                step_time_hint_s=args.step_time_hint_s,
                page_store=(args.store_policy if args.page_store else None),
                store_budget_pages=(args.store_budget_pages
                                    if args.page_store else None))


def build_requests(args, cfg, tenants: int = 0):
    """The generated workload, identical across both serving paths (same
    rng stream); fleet mode stamps round-robin tenants."""
    rng = np.random.default_rng(0)
    shared_head = rng.integers(
        1, min(cfg.vocab_size, 200),
        min(args.shared_prefix_len, args.prefill_len)).astype(np.int32)
    gens = []
    for i in range(args.requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 200),
                              args.prefill_len).astype(np.int32)
        prompt[:len(shared_head)] = shared_head   # common K-token opening
        priority = 0
        if args.priority_mix is not None:
            prios, weights = args.priority_mix
            priority = int(rng.choice(prios, p=weights))
        sp = SamplingParams(temperature=args.sample_temp, top_k=args.top_k,
                            top_p=args.top_p,
                            seed=None if args.seed is None else args.seed + i)
        gens.append(GenerationRequest(
            prompt=prompt, max_new_tokens=args.max_new_tokens,
            priority=priority, params=sp,
            frame=FramePolicy(coalesce=args.coalesce),
            deadline_s=args.deadline_s,
            tenant=f"t{i % tenants}" if tenants else None))
    return gens


def serve_fleet(args, cfg, model, params):
    """Fleet mode: N attested workers behind a gateway + orchestrator."""
    from repro.fleet import EngineWorker, Gateway, Orchestrator

    kw = engine_kwargs(args)
    workers = [EngineWorker(f"w{i}", model, params, tee=args.tee,
                            engine_kw=kw) for i in range(args.workers)]
    gateway = Gateway(config_repr=cfg.name)
    for t in range(args.tenants):
        gateway.register_tenant(f"t{t}")
    orch = Orchestrator(gateway, workers, placement=args.placement)

    t0 = time.monotonic()
    for gen in build_requests(args, cfg, tenants=args.tenants):
        orch.submit(gen)
    step_i = 0
    while not orch.idle and step_i < 10_000:
        if step_i == args.kill_worker_at:
            live = orch.ready_workers()
            if len(live) > 1:
                victim = max(live, key=lambda w: w.load()).name
                orch.kill(victim)
                print(f"[fleet] killed {victim} at step {step_i}; sealed KV "
                      f"migrated under the tenant key domains")
        orch.step()
        step_i += 1
    stats = orch.fleet_stats()
    wall = time.monotonic() - t0

    gs = gateway.stats
    fs = orch.stats
    print(f"served {stats.total_requests} requests / {stats.total_tokens} "
          f"tokens in {wall:.2f}s "
          f"[fleet={args.workers}x{args.tee}, kv={args.kv_backend}]")
    print(f"throughput {stats.throughput_tps:.1f} tok/s | next-token latency "
          f"p50 {stats.p50_latency_s * 1e3:.1f}ms "
          f"mean {stats.mean_latency_s * 1e3:.1f}ms "
          f"p99 {stats.p99_latency_s * 1e3:.1f}ms")
    print(f"fleet: {gs.attested_workers} workers attested / "
          f"{gs.rejected_quotes} quote rejections / "
          f"{gs.keys_released} tenant keys released / "
          f"{gs.envelopes} prompt envelopes ({gs.envelope_bytes} B)")
    print(f"migration: {fs.migrations} sealed moves / "
          f"{fs.migrated_bytes} B migrated / {fs.kills} kills, "
          f"{fs.drains} drains, {fs.requeued} requeued")
    if stats.rejected_infeasible:
        print(f"admission control: {stats.rejected_infeasible} "
              f"infeasible rejections")
    if stats.handoffs:
        print(f"sealed handoff: {stats.handoffs} prefill->decode handoffs / "
              f"{stats.handoff_bytes} B across the plan boundary "
              f"({stats.handoff_bytes // max(stats.handoffs, 1)} B/handoff)")
    tot = orch.channel_totals()
    print(f"fleet boundary: {tot['messages_out']} egress frames / "
          f"{tot['tokens_out']} tokens, "
          f"{tot['seal_events']} seals / {tot['seal_bytes']} B out, "
          f"{tot['restore_events']} restores / {tot['restore_bytes']} B back")
    if tot["store_hits"] or tot["store_evictions"]:
        print(f"fleet store: {tot['store_hits']} hits / "
              f"{tot['store_restored_bytes']} B restored / "
              f"{tot['store_evictions']} evictions")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tee", default="tdx")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-len", type=int, default=16)
    ap.add_argument("--prefill-buckets", type=parse_buckets, default=None,
                    metavar="B0,B1,...",
                    help="power-of-two prefill buckets (default: one bucket "
                         "of --prefill-len)")
    ap.add_argument("--priority-mix", type=parse_priority_mix, default=None,
                    metavar="PRIO:WEIGHT,...",
                    help="weighted request priorities, e.g. 0:3,5:1")
    ap.add_argument("--coalesce", type=int, default=1,
                    help="tokens per encrypted egress frame (FramePolicy)")
    ap.add_argument("--sample-temp", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the top-k logits (0 = all)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling threshold (1.0 = off)")
    ap.add_argument("--seed", type=int, default=None,
                    help="sampling seed (reproducible per-request streams)")
    ap.add_argument("--kv-backend", default="slot", choices=["slot", "paged"],
                    help="KV layout: dense slots or page pool + page table")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged backend)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size in pages (default: dense-equivalent)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="content-indexed shared prompt pages with "
                         "copy-on-write (paged backend; implies on-demand "
                         "page allocation)")
    ap.add_argument("--kv-alloc", default=None,
                    choices=["reserve", "ondemand"],
                    help="paged page-allocation mode: worst-case admission "
                         "reservations or vLLM-style step-time grants with "
                         "capacity preemption")
    ap.add_argument("--kv-decode", default="gather",
                    choices=["gather", "kernel"],
                    help="paged decode path: per-step dense gather "
                         "(reference) or the table-walking Pallas "
                         "paged-attention kernel with fused in-kernel "
                         "page unseal")
    ap.add_argument("--page-store", action="store_true",
                    help="retain content-named sealed pages past the last "
                         "reference in a persistent prefix-cache tier "
                         "(implies --prefix-sharing); recurring prompts "
                         "restore MAC-verified pages instead of "
                         "re-prefilling")
    ap.add_argument("--store-budget-pages", type=int, default=None,
                    metavar="N",
                    help="page-store retention budget in pages "
                         "(default: unbounded)")
    ap.add_argument("--store-policy", default="lru",
                    choices=["lru", "cost"],
                    help="page-store retention policy: least-recently-used "
                         "or the restore-vs-recompute priced scoring")
    ap.add_argument("--epochs", type=int, default=1,
                    help="replay the generated workload this many times "
                         "(a recurring-prompt mix: epoch 2+ hits the "
                         "page store)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    metavar="K",
                    help="give every generated prompt the same K-token head "
                         "(a shared-prefix workload for --prefix-sharing)")
    ap.add_argument("--mesh", default=None, metavar="dp=N[,tp=M]",
                    help="span the engine across a device mesh (forces host "
                         "devices if needed) and report measured link tax")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="step-level continuous batching: prefill admissions "
                         "interleave into decode steps under a per-step "
                         "token budget instead of filling a bucket first")
    ap.add_argument("--step-tokens", type=int, default=None,
                    help="per-step token budget for --continuous-batching "
                         "(default: largest prefill bucket + --slots)")
    ap.add_argument("--prefill-plan", default=None, choices=["dedicated"],
                    help="disaggregate prefill onto its own compute plan; "
                         "finished KV rows hand off to the decode plan "
                         "through a sealed channel priced in ChannelStats")
    ap.add_argument("--handoff-batch", type=int, default=1,
                    help="finished prefill rows grouped per sealed "
                         "prefill->decode crossing (--prefill-plan dedicated)")
    ap.add_argument("--reject-infeasible", action="store_true",
                    help="reject deadline-infeasible requests at admission, "
                         "before any boundary crossing is spent on them")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds (stamped on every "
                         "generated request)")
    ap.add_argument("--step-time-hint-ms", type=float, default=None,
                    help="prior lower bound on decode step time for "
                         "--reject-infeasible before any step has run")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="fleet mode: N engine workers (own TrustDomain "
                         "each) behind an attested gateway + orchestrator "
                         "(0 = single-engine path)")
    ap.add_argument("--tenants", type=int, default=2, metavar="M",
                    help="fleet mode: round-robin requests over M tenant "
                         "key domains")
    ap.add_argument("--placement", default="least_loaded",
                    choices=["least_loaded", "tenant_affinity",
                             "store_affinity"],
                    help="fleet placement policy")
    ap.add_argument("--kill-worker-at", type=int, default=None, metavar="STEP",
                    help="fleet mode: kill the busiest worker at this step; "
                         "its sealed KV migrates to survivors")
    args = ap.parse_args()
    args.step_time_hint_s = (None if args.step_time_hint_ms is None
                             else args.step_time_hint_ms * 1e-3)
    if args.page_store:
        # the store is the tier behind the content index — it needs page keys
        args.prefix_sharing = True

    if args.workers and args.mesh is not None:
        raise SystemExit("--workers (fleet mode) and --mesh are mutually "
                         "exclusive: a mesh spans one engine")

    if args.mesh is not None:
        dp, tp = parse_mesh(args.mesh)
        ensure_host_devices(dp * tp)
        padded = args.slots + (-args.slots) % dp
        if padded != args.slots:
            # a non-divisible batch silently falls back to a replicated
            # cache — pad instead so the sharded experiment actually runs
            print(f"[mesh] rounding --slots {args.slots} up to {padded} "
                  f"(a dp={dp} mesh shards whole slots per data-shard)")
            args.slots = padded

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("the token-in/token-out server needs a decoder-family arch")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    if args.workers:
        serve_fleet(args, cfg, model, params)
        return

    td = TrustDomain(args.tee)
    if td.confidential:
        sealed = td.seal_params(params)
        params = td.load_sealed(sealed, params)
        verifier = td.make_verifier(cfg.name)
        quote = td.quote(verifier.challenge(), cfg.name)
        verifier.verify(quote)
        print(f"[{args.tee}] attested; model digest bound "
              f"({quote.measurement[:16]}...)")

    engine = Engine(model, params, trust_domain=td, mesh=args.mesh,
                    **engine_kwargs(args))
    if args.mesh is not None:
        print(f"[mesh] engine spans {engine.plan.describe()}")
    t0 = time.monotonic()
    for epoch in range(max(args.epochs, 1)):
        pages0 = getattr(engine.kv, "pages_written", 0)
        hits0 = getattr(engine.kv, "store_hits", 0)
        for gen in build_requests(args, cfg):
            engine.submit(gen)
        stats = engine.run()
        if args.epochs > 1:
            print(f"epoch {epoch}: "
                  f"{getattr(engine.kv, 'pages_written', 0) - pages0} "
                  f"pages written, "
                  f"{getattr(engine.kv, 'store_hits', 0) - hits0} "
                  f"store hits")
    wall = time.monotonic() - t0

    print(f"served {stats.total_requests} requests / {stats.total_tokens} "
          f"tokens in {wall:.2f}s [kv={args.kv_backend}]")
    print(f"throughput {stats.throughput_tps:.1f} tok/s | next-token latency "
          f"p50 {stats.p50_latency_s * 1e3:.1f}ms "
          f"mean {stats.mean_latency_s * 1e3:.1f}ms "
          f"p99 {stats.p99_latency_s * 1e3:.1f}ms")
    if (stats.preemptions or stats.dropped_requests or stats.deadline_misses
            or stats.aborted_requests):
        print(f"SLO: {stats.preemptions} preemptions, "
              f"{stats.dropped_requests} dropped, "
              f"{stats.aborted_requests} aborted, "
              f"{stats.deadline_misses} deadline misses")
    ch = td.channel.stats
    if ch.seal_events:
        print(f"sealed-KV traffic: {ch.seal_events} evictions / "
              f"{ch.seal_bytes} B out ({ch.seal_bytes_per_event:.0f} B/seal), "
              f"{ch.restore_events} restores / {ch.restore_bytes} B back "
              f"[kv={args.kv_backend}]")
    if stats.rejected_infeasible:
        print(f"admission control: {stats.rejected_infeasible} "
              f"infeasible rejections (deadline unmeetable at submit)")
    if stats.handoffs:
        print(f"sealed handoff: {stats.handoffs} prefill->decode handoffs / "
              f"{stats.handoff_bytes} B across the plan boundary "
              f"({stats.handoff_bytes // max(stats.handoffs, 1)} B/handoff, "
              f"{engine.handoff_crossings} sealed crossings @ "
              f"batch={args.handoff_batch})")
    if args.continuous_batching:
        print(f"continuous batching: step budget "
              f"{engine._step_tokens} tokens, "
              f"{stats.backfilled_requests} backfilled admissions")
    if args.kv_backend == "paged":
        print(f"kv decode: mode={engine.kv.decode_mode} | fused-unseal "
              f"{engine.kv.fused_restore_pages} pages / "
              f"{engine.kv.fused_restore_bytes} B admitted as ciphertext")
        _, savings_line = fused_unseal_savings(
            engine.kv.fused_restore_pages, engine.kv.fused_restore_bytes,
            args.tee)
        print(savings_line)
    if getattr(engine.kv, "supports_sharing", False):
        print(f"prefix sharing: {stats.shared_pages} shared-page maps, "
              f"{stats.cow_copies} CoW copies, "
              f"{engine.kv.pages_written} pages written "
              f"[alloc={'ondemand' if engine.kv.on_demand else 'reserve'}]")
    store = getattr(engine.kv, "page_store", None)
    if store is not None:
        print(f"store hits: {engine.kv.store_hits} / "
              f"{engine.kv.store_restored_bytes} B restored / "
              f"{store.publishes} publishes "
              f"({store.republish_noops} republish no-ops) / "
              f"{store.evictions} evictions / "
              f"{store.resident_pages} resident pages "
              f"[policy={store.policy}, budget={store.budget_pages}]")
        profile = args.tee if td.confidential else "cgpu"
        _, _, line = store_restore_savings(
            engine.kv.store_restored_pages, engine.kv.store_restored_bytes,
            engine.kv.store_restored_pages * engine.kv.page_size, profile)
        print(line)
    if args.mesh is not None:
        # measured-vs-modeled encrypted-interconnect (link_tax) comparison:
        # same roofline terms, collective time once from the closed form
        # (bytes / ICI_BW) and once measured on the real mesh collective.
        profile = args.tee if td.confidential else "cgpu"
        _, _, line = measured_link_tax(ch, profile,
                                       stats.mean_latency_s or 1e-3)
        print(f"link-tax [{args.mesh}, {profile}]: {line}")
    if td.confidential:
        print(f"boundary: {ch}")
        print(f"frame coalescing: {ch.messages_out} egress frames / "
              f"{ch.tokens_out} tokens = "
              f"{ch.crossings_per_token:.3f} crossings/token "
              f"(coalesce={args.coalesce})")
        step = stats.mean_latency_s or 1e-3
        terms = RooflineTerms(compute_s=STEP_COMPUTE_FRACTION * step,
                              memory_s=STEP_MEMORY_FRACTION * step,
                              collective_s=0.05 * step)
        print("modeled platform overhead:", td.predict_overhead(terms).as_row())


if __name__ == "__main__":
    main()
