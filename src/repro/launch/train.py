"""Training launcher: build a (possibly sharded, possibly confidential)
training job for any registered architecture.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
        --steps 20 --batch 8 --seq 128 --tee tdx --ckpt-dir /tmp/run1

On a real fleet the same entry point runs with --mesh data,model sizes
matching the slice; on this container it runs smoke-scale on CPU devices.
Resumes automatically from the latest (sealed) checkpoint; injected-failure
drills via --fail-at.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, list_configs, smoke_config
from repro.core import TrustDomain
from repro.data.pipeline import PackedLMDataset
from repro.distributed import sharding
from repro.distributed.fault_tolerance import FailureInjector, run_with_restarts
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import (abstract_train_state, init_train_state,
                                    make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tee", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-family arch for the LM trainer")
    model = build_model(cfg)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 10),
                      moment_dtype=cfg.parallel.optimizer_dtype)

    state = init_train_state(model, opt, jax.random.key(0))
    step_fn = make_train_step(model, opt, microbatches=args.microbatches)

    if args.data_mesh * args.model_mesh > 1:
        mesh = make_host_mesh(args.data_mesh, args.model_mesh)
        sspecs = sharding.to_named(
            mesh, sharding.state_specs(cfg, abstract_train_state(model, opt), mesh))
        state = jax.tree.map(jax.device_put, state, sspecs)
        print(f"mesh: {dict(mesh.shape)}")

    td = TrustDomain(args.tee)
    mgr = (CheckpointManager(args.ckpt_dir, trust_domain=td if td.confidential else None)
           if args.ckpt_dir else None)

    def data_factory(cursor):
        ds = PackedLMDataset(batch_size=args.batch, seq_len=args.seq, seed=0)
        it = iter(ds)
        for _ in range(cursor):
            next(it)
        return it

    total, active = cfg.params_count()
    print(f"arch={cfg.name} params={total / 1e6:.1f}M "
          f"(active {active / 1e6:.1f}M) tee={args.tee}")
    t0 = time.monotonic()
    if mgr is not None:
        injector = FailureInjector(set(args.fail_at)) if args.fail_at else None
        state, losses, restarts = run_with_restarts(
            state=state, train_step=step_fn, data_factory=data_factory,
            num_steps=args.steps, manager=mgr,
            checkpoint_every=args.ckpt_every, injector=injector)
        print(f"restarts: {restarts}")
    else:
        jitted = jax.jit(step_fn)
        data = data_factory(0)
        losses = []
        for step in range(args.steps):
            state, metrics = jitted(state, next(data))
            losses.append(float(metrics["loss"]))
    wall = time.monotonic() - t0
    print(f"{args.steps} steps in {wall:.1f}s "
          f"({args.steps * args.batch * args.seq / wall:.0f} tok/s)")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
