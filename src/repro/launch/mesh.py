"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips/pod (v5e pod); 2 pods over DCN when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return make_mesh((data, model), ("data", "model"))
