"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and tests/benches must keep seeing 1 device.

``ensure_host_devices`` is the CLI affordance for mesh-spanning serving on
one host: XLA's forced host device count must be set before jax first
initializes, which is too late once a launcher module has imported jax — so
the launcher re-execs itself once with the flag set.
"""

from __future__ import annotations

import os
import subprocess
import sys

import jax

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips/pod (v5e pod); 2 pods over DCN when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return make_mesh((data, model), ("data", "model"))


def ensure_host_devices(n: int) -> None:
    """Guarantee jax sees >= ``n`` devices, re-execing this process ONCE
    with ``--xla_force_host_platform_device_count`` if it does not (the flag
    only takes effect before jax initializes). No-op when enough devices
    exist; raises if the relaunch already happened and still fell short
    (a real accelerator platform that cannot be subdivided)."""
    if len(jax.devices()) >= n:
        return
    if os.environ.get("_REPRO_MESH_RELAUNCHED"):
        raise RuntimeError(
            f"need {n} devices but jax sees {len(jax.devices())} even after "
            f"forcing the host platform — shrink the mesh")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["_REPRO_MESH_RELAUNCHED"] = "1"
    print(f"[mesh] {len(jax.devices())} device(s) < {n}: relaunching with "
          f"{n} forced host devices")
    sys.stdout.flush()
    raise SystemExit(subprocess.run(
        [sys.executable, sys.argv[0]] + sys.argv[1:], env=env).returncode)
