import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 host devices back the production meshes:
(data=16, model=16) single pod and (pod=2, data=16, model=16) multi-pod.

Per cell, two kinds of lowering:
  1. FULL, layer-scanned — the deliverable: .lower().compile() must succeed;
     memory_analysis() proves the per-device footprint.
  2. ANALYSIS, small UNROLLED variants — XLA's cost_analysis counts a while
     (scan) body ONCE regardless of trip count (verified empirically), so
     FLOPs/bytes/collective-bytes are extracted from unrolled unit-depth
     lowerings and extrapolated affinely in the per-block-type layer counts
     (exact: stacks are homogeneous per block type by construction).

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Dict

import jax

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig, shape_applicable
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.roofline.analysis import (CellRoofline, model_flops_for,
                                     parse_collectives)
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import abstract_train_state, make_train_step

ASSIGNED = [
    "whisper-small", "deepseek-7b", "qwen3-32b", "deepseek-67b",
    "mistral-nemo-12b", "dbrx-132b", "deepseek-v3-671b", "jamba-v0.1-52b",
    "rwkv6-3b", "chameleon-34b",
]


# ---------------------------------------------------------------------------
# block-count parameterization (for affine cost extrapolation)
# ---------------------------------------------------------------------------

def block_counts(cfg: ModelConfig) -> Dict[str, int]:
    if cfg.family == "encdec":
        return {"enc": cfg.encoder_layers, "dec": cfg.decoder_layers}
    if cfg.family == "hybrid":
        return {"groups": cfg.num_layers // cfg.attn_period}
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return {"dense": cfg.moe.first_k_dense,
                "moe": cfg.num_layers - cfg.moe.first_k_dense}
    return {"layers": cfg.num_layers}


def with_counts(cfg: ModelConfig, counts: Dict[str, int],
                scan: bool) -> ModelConfig:
    par = dataclasses.replace(cfg.parallel, scan_layers=scan)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, encoder_layers=counts["enc"],
                                   decoder_layers=counts["dec"],
                                   num_layers=max(counts.values()), parallel=par)
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, num_layers=counts["groups"] * cfg.attn_period, parallel=par)
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return dataclasses.replace(
            cfg, num_layers=counts["dense"] + counts["moe"],
            moe=dataclasses.replace(cfg.moe, first_k_dense=counts["dense"]),
            parallel=par)
    return dataclasses.replace(cfg, num_layers=counts["layers"], parallel=par)


# ---------------------------------------------------------------------------
# lowering one step program for a given config variant
# ---------------------------------------------------------------------------

def lower_step(cfg: ModelConfig, shape: ShapeConfig, mesh):
    model = build_model(cfg)
    named = lambda tree: sharding.to_named(mesh, tree)
    with mesh:
        if shape.step_kind == "train":
            opt = AdamWConfig(moment_dtype=cfg.parallel.optimizer_dtype)
            astate = abstract_train_state(model, opt)
            sspecs = sharding.state_specs(cfg, astate, mesh)
            batch = model.train_batch_specs(shape)
            bspecs = sharding.batch_specs(cfg, jax.eval_shape(lambda: batch), mesh)
            step = make_train_step(model, opt,
                                   microbatches=cfg.parallel.microbatches,
                                   unroll_microbatches=not cfg.parallel.scan_layers)
            return jax.jit(step,
                           in_shardings=(named(sspecs), named(bspecs)),
                           out_shardings=(named(sspecs), None),
                           donate_argnums=(0,)).lower(astate, batch)
        if shape.step_kind == "prefill":
            aparams = model.abstract_params()
            pspecs = sharding.param_specs(cfg, aparams, mesh)
            batch = model.prefill_batch_specs(shape)
            bspecs = sharding.batch_specs(cfg, jax.eval_shape(lambda: batch), mesh)
            acache = model.cache_specs(shape)
            cspecs = sharding.cache_specs(cfg, acache, mesh)

            def prefill(params, batch, cache):
                return model.prefill(params, batch, cache)

            return jax.jit(prefill,
                           in_shardings=(named(pspecs), named(bspecs), named(cspecs)),
                           out_shardings=(None, named(cspecs)),
                           donate_argnums=(2,)).lower(aparams, batch, acache)
        # decode
        aparams = model.abstract_params()
        pspecs = sharding.param_specs(cfg, aparams, mesh)
        tokens = model.decode_token_specs(shape)
        tspec = sharding.batch_specs(cfg, {"tokens": tokens}, mesh)["tokens"]
        acache = model.cache_specs(shape)
        cspecs = sharding.cache_specs(cfg, acache, mesh)

        def serve_step(params, tokens, cache):
            return model.decode_step(params, tokens, cache)

        return jax.jit(serve_step,
                       in_shardings=(named(pspecs), named(tspec), named(cspecs)),
                       out_shardings=(None, named(cspecs)),
                       donate_argnums=(2,)).lower(aparams, tokens, acache)


def _costs(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    out = {"flops": float(ca.get("flops", 0.0)),
           "bytes": float(ca.get("bytes accessed", 0.0))}
    for op in parse_collectives(compiled.as_text()):
        out[f"coll/{op.kind}"] = out.get(f"coll/{op.kind}", 0.0) + op.moved_bytes
    return out


# ---------------------------------------------------------------------------
# per-cell dry-run
# ---------------------------------------------------------------------------

OPT_BUNDLE = {  # the §Perf optimization bundle, per step kind
    "train": dict(attention_chunk=512, loss_chunk=512, microbatches=8),
    "prefill": dict(attention_chunk=512),
    "decode": dict(decode_cache_carry=True),
}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, cfg_override: ModelConfig | None = None,
               optimized: bool = False):
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    if optimized:
        cfg = dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel,
                                              **OPT_BUNDLE[shape.step_kind]))
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    # 1) FULL scanned lowering: the compile-must-succeed deliverable + memory
    lowered = lower_step(cfg, shape, mesh)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()

    # 2) ANALYSIS: affine extrapolation over unrolled unit-depth variants
    counts = block_counts(cfg)
    base_pt = {k: 1 for k in counts}
    points = [base_pt] + [dict(base_pt, **{k: 2}) for k in counts]
    costs = []
    for pt in points:
        c = lower_step(with_counts(cfg, pt, scan=False), shape, mesh).compile()
        costs.append(_costs(c))
    keys = sorted({k for c in costs for k in c})
    totals: Dict[str, float] = {}
    for key in keys:
        f0 = costs[0].get(key, 0.0)
        total = f0
        for i, bname in enumerate(counts):
            coef = costs[i + 1].get(key, 0.0) - f0
            total += coef * (counts[bname] - 1)
        totals[key] = max(total, 0.0)

    breakdown = {k.split("/", 1)[1]: v for k, v in totals.items()
                 if k.startswith("coll/")}
    cell = CellRoofline(
        arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        flops_per_dev=totals.get("flops", 0.0),
        bytes_per_dev=totals.get("bytes", 0.0),
        collective_bytes_per_dev=float(sum(breakdown.values())),
        collective_breakdown=breakdown,
        arg_bytes=int(ma.argument_size_in_bytes - ma.alias_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        model_flops=model_flops_for(cfg, shape),
    )
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compiled OK")
        print(f"  memory_analysis: args+out={cell.arg_bytes + cell.out_bytes:.3e}B "
              f"temp={cell.temp_bytes:.3e}B fits_16GiB_HBM={cell.fits_hbm}")
        print(f"  cost_analysis (extrapolated): flops/dev={cell.flops_per_dev:.3e} "
              f"bytes/dev={cell.bytes_per_dev:.3e} "
              f"coll_bytes/dev={cell.collective_bytes_per_dev:.3e}")
        print(f"  roofline: compute={cell.compute_s * 1e3:.2f}ms "
              f"memory={cell.memory_s * 1e3:.2f}ms "
              f"collective={cell.collective_s * 1e3:.2f}ms -> {cell.bound}-bound "
              f"fraction={cell.roofline_fraction:.3f} "
              f"useful_flops={cell.useful_flops_ratio:.2f}")
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf optimization bundle per step kind")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = ([(a, s) for a in ASSIGNED for s in SHAPES] if args.all
             else [(args.arch, args.shape)])

    results = []
    out = Path(args.out) if args.out else None
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    if out and out.exists():
        results = json.loads(out.read_text())
        done = {(r["arch"], r["shape"], r.get("mesh")) for r in results}
        cells = [c for c in cells if (c[0], c[1], mesh_name) not in done]

    failures = 0
    for arch, shape in cells:
        t0 = time.time()
        try:
            cell = lower_cell(arch, shape, multi_pod=args.multi_pod,
                              optimized=args.opt)
            rec = cell if isinstance(cell, dict) else cell.to_dict()
        except Exception as e:  # a failure here is a bug in our sharding
            failures += 1
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "error": str(e)[:500]}
        rec["compile_s"] = time.time() - t0
        results.append(rec)
        if out:
            out.write_text(json.dumps(results, indent=1))
        print(f"  ({rec['compile_s']:.1f}s)\n", flush=True)

    print(f"dry-run complete: {len(results)} records, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
