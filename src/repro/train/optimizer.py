"""AdamW built from scratch (no optax), with configurable moment dtype.

``moment_dtype="bfloat16"`` halves optimizer-state HBM — the knob that
decides whether the 671B train cells fit on v5e (EXPERIMENTS.md §Dry-run
memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Params
    v: Params
    step: jax.Array


def init_opt_state(cfg: AdamWConfig, params: Params) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    progress = jnp.clip((s - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(cfg: AdamWConfig, params: Params, grads: Params,
                 state: OptState):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * cfg.b1 + gf * (1 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + jnp.square(gf) * (1 - cfg.b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": gnorm, "lr": lr}
