"""Training loop: jitted train_step with microbatch gradient accumulation,
optional gradient compression, and fault-tolerance hooks.

``make_train_step`` builds the pjit-able step used by both the real trainer
(examples/train_tiny.py) and the dry-run launcher (lowered with
ShapeDtypeStructs on the production mesh).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: OptState


def init_train_state(model: Model, opt_cfg: AdamWConfig, key) -> TrainState:
    params = model.init_params(key)
    return TrainState(params, init_opt_state(opt_cfg, params))


def abstract_train_state(model: Model, opt_cfg: AdamWConfig) -> TrainState:
    return jax.eval_shape(
        lambda: init_train_state(model, opt_cfg, jax.random.key(0)))


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, unroll_microbatches: bool = False,
                    grad_transform: Optional[Callable[[Params], Params]] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 accumulates gradients over the batch shards
    (activation memory / global-batch decoupling) — a lax.scan by default,
    or a concrete python loop with ``unroll_microbatches`` (the dry-run's
    cost analysis counts scan bodies once, so analysis lowerings unroll).
    ``grad_transform`` hooks gradient compression between accumulation and
    the optimizer.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def reshape(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(reshape, batch)

            def acc_body(carry, mbatch):
                (loss_a, grads_a) = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mbatch)
                grads_a = jax.tree.map(jnp.add, grads_a, grads)
                return (loss_a + loss, grads_a), metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            carry = (jnp.zeros((), jnp.float32), zeros)
            if unroll_microbatches:
                for i in range(microbatches):
                    carry, metrics = acc_body(
                        carry, jax.tree.map(lambda x: x[i], mb))
                loss, grads = carry
            else:
                (loss, grads), metrics = jax.lax.scan(acc_body, carry, mb)
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step


@dataclasses.dataclass
class StragglerMonitor:
    """Per-step deadline tracking — the straggler-mitigation hook.

    On real fleets, ``on_straggle`` triggers rebalancing (shrink microbatch,
    exclude slow host from the next allocation, or checkpoint-and-restart on
    a healthy slice). Here it records and (optionally) calls back.
    """
    deadline_s: float
    on_straggle: Optional[Callable[[int, float], None]] = None
    history: list = dataclasses.field(default_factory=list)
    straggles: int = 0

    def observe(self, step: int, duration_s: float) -> bool:
        self.history.append(duration_s)
        if duration_s > self.deadline_s:
            self.straggles += 1
            if self.on_straggle:
                self.on_straggle(step, duration_s)
            return True
        return False

    @property
    def median_s(self) -> float:
        h = sorted(self.history)
        return h[len(h) // 2] if h else 0.0


def train_loop(model: Model, state: TrainState, train_step, data_iter, *,
               num_steps: int, log_every: int = 10,
               checkpoint_cb: Optional[Callable[[int, TrainState], None]] = None,
               checkpoint_every: int = 0,
               monitor: Optional[StragglerMonitor] = None,
               donate: bool = False):
    """Host-side loop: metrics, straggler observation, periodic checkpoints.

    ``donate=True`` donates the state buffers each step (halves peak memory;
    the caller's input state becomes invalid)."""
    history = []
    step_fn = jax.jit(train_step, donate_argnums=(0,) if donate else ())
    for step in range(num_steps):
        t0 = time.monotonic()
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.monotonic() - t0
        if monitor is not None:
            monitor.observe(step, dt)
        metrics["step_s"] = dt
        history.append(metrics)
        if checkpoint_every and checkpoint_cb and (step + 1) % checkpoint_every == 0:
            checkpoint_cb(step + 1, state)
    return state, history
