"""Fault-tolerant checkpointing: atomic, content-addressed, elastic, sealable.

Properties the 1000-node posture needs (DESIGN.md §4):
  * **atomic**: write to a temp dir, fsync manifest, rename — a crash
    mid-save never corrupts the latest-good checkpoint;
  * **verifiable**: every leaf carries a SHA-256; restore refuses silently
    corrupted files;
  * **elastic**: arrays are stored unsharded-logical (host numpy), so a
    restore may target a *different* mesh — re-sharding happens at
    device_put with the new sharding (tested save-on-A/load-on-B);
  * **confidential**: with a TrustDomain, leaves are sealed (ChaCha20+HMAC)
    so checkpoints at rest never expose weights (the paper's LUKS/protected
    -FS requirement, Insight 2/§III-B).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.confidential import TrustDomain
from repro.core.sealing import SealedTensor, seal_tensor, unseal_tensor

Params = Any


def _leaf_paths(tree: Params):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(p) for p, _ in flat]
    return names, [l for _, l in flat], treedef


def save_checkpoint(directory: str | Path, step: int, tree: Params, *,
                    trust_domain: Optional[TrustDomain] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    names, leaves, _ = _leaf_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "sealed": False}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        if trust_domain is not None and trust_domain.confidential:
            st = seal_tensor(trust_domain.sealing_key, f"ckpt/{step}{name}", leaf)
            np.save(tmp / fname, np.asarray(st.ciphertext))
            manifest["sealed"] = True
            entry = {"name": name, "file": fname, "shape": list(st.shape),
                     "dtype": st.dtype, "n_bytes": st.n_bytes,
                     "mac": st.mac.hex()}
        else:
            np.save(tmp / fname, arr)
            entry = {"name": name, "file": fname, "shape": list(arr.shape),
                     "dtype": str(arr.dtype),
                     "sha256": hashlib.sha256(arr.tobytes()).hexdigest()}
        manifest["leaves"][str(i)] = entry
    mpath = tmp / "manifest.json"
    mpath.write_text(json.dumps(manifest))
    with open(mpath) as f:
        os.fsync(f.fileno())
    final = directory / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    (directory / "LATEST.tmp").write_text(str(step))
    (directory / "LATEST.tmp").rename(directory / "LATEST")
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    p = Path(directory) / "LATEST"
    if not p.exists():
        return None
    step = int(p.read_text())
    return step if (Path(directory) / f"step_{step}").exists() else None


class CorruptCheckpoint(Exception):
    pass


def restore_checkpoint(directory: str | Path, step: int, treedef_like: Params, *,
                       trust_domain: Optional[TrustDomain] = None,
                       shardings: Optional[Params] = None) -> Params:
    """Restore into the structure of ``treedef_like``. ``shardings`` (a pytree
    of NamedSharding matching the leaves) enables elastic re-shard on load."""
    d = Path(directory) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    names, like_leaves, treedef = _leaf_paths(treedef_like)
    if len(names) != len(manifest["leaves"]):
        raise CorruptCheckpoint(
            f"leaf count mismatch: {len(names)} vs {len(manifest['leaves'])}")
    leaves = []
    for i, name in enumerate(names):
        entry = manifest["leaves"][str(i)]
        raw = np.load(d / entry["file"])
        if manifest["sealed"]:
            if trust_domain is None:
                raise CorruptCheckpoint("sealed checkpoint requires a TrustDomain")
            st = SealedTensor(name=f"ckpt/{step}{entry['name']}",
                              ciphertext=jax.numpy.asarray(raw),
                              mac=bytes.fromhex(entry["mac"]),
                              shape=tuple(entry["shape"]), dtype=entry["dtype"],
                              n_bytes=entry["n_bytes"])
            arr = np.asarray(unseal_tensor(trust_domain.sealing_key, st))
        else:
            digest = hashlib.sha256(raw.tobytes()).hexdigest()
            if digest != entry["sha256"]:
                raise CorruptCheckpoint(f"digest mismatch for {entry['name']}")
            arr = raw
        leaves.append(arr)
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings)
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    else:
        leaves = [jax.numpy.asarray(a) for a in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class CheckpointManager:
    """Restart-on-failure orchestration: keep_n retention + auto-resume."""
    directory: Path
    keep_n: int = 3
    trust_domain: Optional[TrustDomain] = None

    def __post_init__(self):
        self.directory = Path(self.directory)

    def save(self, step: int, tree: Params) -> Path:
        path = save_checkpoint(self.directory, step, tree,
                               trust_domain=self.trust_domain)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*"))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.directory / f"step_{s}", ignore_errors=True)

    def resume(self, treedef_like: Params,
               shardings: Optional[Params] = None) -> Tuple[Optional[int], Optional[Params]]:
        step = latest_step(self.directory)
        if step is None:
            return None, None
        tree = restore_checkpoint(self.directory, step, treedef_like,
                                  trust_domain=self.trust_domain,
                                  shardings=shardings)
        return step, tree
