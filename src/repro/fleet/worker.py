"""One attested engine worker: an Engine in its own TrustDomain, plus the
fleet-facing surfaces the gateway and orchestrator speak.

State machine (the orchestrator drives the transitions)::

    ATTESTING --admit (quote verifies)--> READY
        |                                   |  drain()/kill()
        +--admit fails (bad quote)--+       v
                                    +--> DRAINING/DEAD

A worker holds three kinds of key material, strictly layered:

  * its domain's own sealing key — local preemption/handoff blobs; never
    leaves the worker, so those blobs can never restore elsewhere;
  * a gateway transport key, released only after this worker's quote
    verified — opens prompt envelopes addressed to exactly this worker;
  * per-tenant key domains, released per (worker, tenant) after a fresh
    quote each — sealed-KV *migration* blobs. The material is derived
    deterministically from the gateway master, so every attested worker
    lands on the same tenant domain and a migrant sealed on worker A
    restores on worker B — while tenant A's blob fails MAC under tenant
    B's domain.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.confidential import TrustDomain
from repro.core.sealing import SealingKey, unseal_tensor
from repro.runtime.engine import Engine, PreemptedRequest
from repro.runtime.scheduler import Request

ATTESTING = "attesting"
READY = "ready"
DRAINING = "draining"
DEAD = "dead"
WORKER_STATES = (ATTESTING, READY, DRAINING, DEAD)


class EngineWorker:
    """One fleet worker: ``Engine`` + ``TrustDomain`` + released keys.

    ``name`` must be fleet-unique — it is embedded in the migration nonce
    namespace (``kvmigrate/{name}/...``), which is what keeps two workers'
    migration seals apart under the *shared* tenant key domains."""

    def __init__(self, name: str, model, params, *, tee: str = "tdx",
                 engine_kw: Optional[Dict[str, Any]] = None):
        self.name = name
        self.td = TrustDomain(tee)
        self.engine = Engine(model, params, trust_domain=self.td,
                             **dict(engine_kw or {}))
        self.state = ATTESTING
        self.tenant_keys: Dict[str, SealingKey] = {}
        self.transport: Optional[SealingKey] = None

    def __repr__(self):
        return f"EngineWorker({self.name!r}, state={self.state})"

    # -- attestation-released material --------------------------------------
    def quote(self, nonce: str, config_repr: str = ""):
        return self.td.quote(nonce, config_repr)

    def install_transport(self, material: bytes) -> None:
        """Adopt the gateway's envelope-transport key (received over the
        attested channel the key release models)."""
        self.transport = SealingKey.generate(material)

    def install_tenant_key(self, tenant: str, material: bytes) -> None:
        self.tenant_keys[tenant] = self.td.adopt_tenant_material(tenant,
                                                                 material)

    def key_for(self, req: Request) -> SealingKey:
        """The sealing domain a migration of ``req`` must use: its tenant's
        fleet-shared key domain. A tenant this worker holds no released key
        for cannot migrate (and could never restore elsewhere); a
        tenant-less request falls back to the worker key — valid only for
        single-worker deployments, where migration never crosses."""
        tenant = req.gen.tenant
        if tenant is None:
            return self.td.sealing_key
        try:
            return self.tenant_keys[tenant]
        except KeyError:
            raise KeyError(f"worker {self.name!r} holds no released key "
                           f"domain for tenant {tenant!r}") from None

    # -- envelopes -----------------------------------------------------------
    def open_envelope(self, env) -> np.ndarray:
        """Unwrap a gateway prompt envelope: the content key unseals under
        this worker's transport key (an envelope addressed to another
        worker, or tampered in transit, fails MAC before any plaintext
        exists), then the prompt unseals under the content key."""
        if self.transport is None:
            raise RuntimeError(f"worker {self.name!r} is not attested — no "
                               f"transport key released")
        blob = np.asarray(unseal_tensor(self.transport, env.key_blob),
                          np.uint8).tobytes()
        content = SealingKey(blob[:32], blob[32:])
        return np.asarray(unseal_tensor(content, env.sealed_prompt), np.int32)

    # -- placement inputs ----------------------------------------------------
    def _live_requests(self) -> List[Request]:
        e = self.engine
        live = list(e.scheduler.running.values())
        live += [r for _, _, r in e.scheduler.queue]
        live += [p.req for p in e._preempted]
        live += [i.req for i in e._inflight.values()]
        return live

    def load(self) -> int:
        """Effective KV demand currently parked on this worker — the
        least-loaded placement metric. ``kv_need`` is already net of
        resident shared pages on a prefix-sharing backend, so affinity
        traffic reads as cheap here, which is exactly right."""
        return sum(r.kv_need for r in self._live_requests())

    def serves_tenant(self, tenant: str) -> bool:
        return any(r.gen.tenant == tenant for r in self._live_requests())

    # -- migration -----------------------------------------------------------
    def export_state(self) -> Tuple[List[PreemptedRequest], List[Request]]:
        """Seal all live state out under the per-tenant key domains, in this
        worker's own ``kvmigrate/{name}`` namespace (see
        :meth:`Engine.export_sealed_state`)."""
        return self.engine.export_sealed_state(
            key_for=self.key_for, namespace=f"kvmigrate/{self.name}")
