"""Multi-tenant confidential serving fleet: attested gateway -> orchestrator
-> engine workers.

The paper measures one engine in one enclave; real privacy-sensitive
deployments interpose an attested service tier where many mutually-
distrusting tenants share a worker fleet. This package is that tier, built
entirely from primitives the repo already has:

  * :class:`~repro.fleet.gateway.Gateway` — the key broker at the front
    door. It verifies each worker's attestation quote (measurement, nonce
    freshness, signature — the :mod:`repro.core.attestation` flow) before
    admitting it, releases **per-tenant key domains** (HKDF-style labels on
    the master secret, so tenant A's sealed KV fails MAC — not merely
    decryption — under tenant B's domain), and envelope-encrypts prompts to
    exactly one attested worker.
  * :class:`~repro.fleet.worker.EngineWorker` — one
    :class:`~repro.runtime.engine.Engine` wrapped in its own
    :class:`~repro.core.confidential.TrustDomain`, stepping through the
    worker state machine ATTESTING -> READY -> DRAINING -> DEAD.
  * :class:`~repro.fleet.orchestrator.Orchestrator` — routes
    :class:`~repro.runtime.api.GenerationRequest`s across the fleet with
    pluggable placement (:mod:`repro.fleet.placement`: least-loaded by
    effective KV demand, tenant-affinity for prefix-sharing locality),
    enforces tenant-aware rate budgets atop the engines' per-priority token
    buckets, and handles worker failure/drain: in-flight sealed KV migrates
    to a surviving worker through the engine's own seal/restore path under
    a ``kvmigrate/{worker}/...`` nonce namespace, priced in
    ``ChannelStats`` like preemption and handoff. Outputs stay
    byte-identical across a migration (seeded sampling; the request object
    itself travels).
"""

from repro.fleet.gateway import Envelope, Gateway, GatewayStats
from repro.fleet.orchestrator import FleetStats, Orchestrator
from repro.fleet.placement import PLACEMENTS, least_loaded, tenant_affinity
from repro.fleet.worker import (ATTESTING, DEAD, DRAINING, READY,
                                EngineWorker, WORKER_STATES)

__all__ = [
    "Envelope", "Gateway", "GatewayStats",
    "FleetStats", "Orchestrator",
    "PLACEMENTS", "least_loaded", "tenant_affinity",
    "ATTESTING", "READY", "DRAINING", "DEAD", "WORKER_STATES",
    "EngineWorker",
]
