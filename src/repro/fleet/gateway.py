"""The fleet's attested front door: quote verification, per-tenant key
release, and prompt envelopes.

The gateway is the *client-side* trust anchor (paper §II's verifier role,
scaled to a fleet): it holds the master secret and an expected measurement,
and a worker gets key material only by presenting a fresh, correctly-signed
quote over that measurement. Three releases, each gated on its own quote:

  1. **admission** — a transport key for prompt envelopes (per worker);
  2. **tenant domains** — ``derive_tenant_material(master, tenant)`` per
     (worker, tenant). Deterministic in (master, tenant), so every attested
     worker derives the *same* tenant sealing domain — that is what lets a
     sealed-KV migrant cross workers — while two tenants' domains are
     unrelated under the hash and cross-tenant restore fails MAC;
  3. **envelopes** — each prompt is sealed under a fresh content key, and
     the content key rides sealed under the target worker's transport key:
     only the one attested worker it was addressed to can open it.

A worker whose quote fails (wrong measurement, replayed nonce, bad
signature) is marked DEAD and counted in ``GatewayStats.rejected_quotes``;
it never sees a key.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import numpy as np

from repro.core import attestation
from repro.core.attestation import AttestationError
from repro.core.sealing import SealedTensor, SealingKey, seal_tensor
from repro.fleet.worker import DEAD, READY, EngineWorker


@dataclasses.dataclass
class GatewayStats:
    attested_workers: int = 0
    rejected_quotes: int = 0
    keys_released: int = 0      # per-tenant key-domain releases
    envelopes: int = 0
    envelope_bytes: int = 0


@dataclasses.dataclass
class Envelope:
    """One prompt, encrypted to one attested worker for one tenant."""
    eid: int
    tenant: str
    worker: str
    sealed_prompt: SealedTensor
    key_blob: SealedTensor


class Gateway:
    def __init__(self, master_secret: Optional[bytes] = None,
                 config_repr: str = ""):
        self._master = master_secret or os.urandom(32)
        self.config_repr = config_repr
        self.tenants: set = set()
        self._verifiers: Dict[str, attestation.Verifier] = {}
        self._transport: Dict[str, SealingKey] = {}
        self._eid = 0    # gateway-global envelope counter (nonce freshness
                         # under each per-worker transport key)
        self.stats = GatewayStats()

    # -- attestation / key release -------------------------------------------
    def admit(self, worker: EngineWorker,
              expected_measurement: Optional[str] = None) -> None:
        """Attest one worker and release its keys: verify a fresh quote
        against the expected measurement (default: the worker's current
        self-measurement — pass a pinned one to model a tampered worker),
        release the envelope transport key, then one tenant key domain per
        registered tenant, each gated on its own fresh quote."""
        expected = (expected_measurement
                    if expected_measurement is not None
                    else worker.td.measurement(self.config_repr))
        v = attestation.Verifier(worker.td.root, expected)
        transport_material = os.urandom(32)
        try:
            q = worker.quote(v.challenge(), self.config_repr)
            v.release_key(q, transport_material)
        except AttestationError:
            self.stats.rejected_quotes += 1
            worker.state = DEAD
            raise
        worker.install_transport(transport_material)
        self._verifiers[worker.name] = v
        self._transport[worker.name] = SealingKey.generate(transport_material)
        for tenant in sorted(self.tenants):
            self._release_tenant(worker, tenant)
        worker.state = READY
        self.stats.attested_workers += 1

    def register_tenant(self, tenant: str, workers=()) -> None:
        """Add a tenant; release its key domain to any already-attested
        workers passed in (new admissions pick it up automatically)."""
        if tenant in self.tenants:
            return
        self.tenants.add(tenant)
        for w in workers:
            if w.name in self._verifiers:
                self._release_tenant(w, tenant)

    def _release_tenant(self, worker: EngineWorker, tenant: str) -> None:
        v = self._verifiers[worker.name]
        q = worker.quote(v.challenge(), self.config_repr)
        material = v.release_tenant_key(q, self._master, tenant)
        worker.install_tenant_key(tenant, material)
        self.stats.keys_released += 1

    # -- prompt envelopes -----------------------------------------------------
    def envelope_seal(self, worker_name: str, tenant: str,
                      prompt: np.ndarray) -> Envelope:
        """Encrypt a prompt to exactly one attested worker: a fresh content
        key seals the tokens; the content key itself rides sealed under
        that worker's transport key. Any other worker — and any tamper —
        fails MAC before plaintext exists."""
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        try:
            transport = self._transport[worker_name]
        except KeyError:
            raise KeyError(f"worker {worker_name!r} is not attested — no "
                           f"transport key was released") from None
        eid = self._eid
        self._eid += 1
        content = SealingKey.generate()
        sealed_prompt = seal_tensor(content, f"envelope/{eid}/prompt",
                                    np.asarray(prompt, np.int32))
        key_blob = seal_tensor(
            transport, f"envelope/{eid}/key",
            np.frombuffer(content.key + content.mac_key, np.uint8).copy())
        self.stats.envelopes += 1
        self.stats.envelope_bytes += sealed_prompt.n_bytes + key_blob.n_bytes
        return Envelope(eid, tenant, worker_name, sealed_prompt, key_blob)
