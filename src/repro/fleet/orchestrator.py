"""Fleet orchestrator: routing, tenant budgets, failure/drain migration.

The orchestrator is *outside* every trust domain — it sees only envelopes,
sealed migration blobs and encrypted egress frames, never plaintext — and
drives the fleet's control plane:

  * **routing**: each submitted request is stamped with its tenant, its
    prompt is envelope-encrypted by the gateway to the placement-chosen
    worker, and the worker's engine admits it through the ordinary
    slack/priority machinery;
  * **tenant budgets**: a token bucket per tenant (the same ``_RateBucket``
    the engines use per priority class) holds a tenant's overflow at the
    orchestrator — queued *before* any boundary crossing — and releases it
    as the budget refills;
  * **failure/drain**: ``kill()`` models an enclave loss whose sealed
    snapshot survives (the TEE property the whole repo prices — state at
    rest is ciphertext); ``drain()`` is the graceful twin. Both export the
    worker's state under per-tenant key domains and redistribute it: sealed
    migrants join surviving workers' restore queues and complete
    byte-identically (seeded sampling; the request object travels), queued
    requests simply re-queue. Migration traffic is priced per request
    (``n_migrations``/``migrated_bytes`` -> ``ServeStats``) and per fleet
    (:class:`FleetStats`).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.core.sealing import sealed_nbytes
from repro.fleet.gateway import Gateway
from repro.fleet.placement import PLACEMENTS
from repro.fleet.worker import DEAD, DRAINING, READY, EngineWorker
from repro.runtime.api import GenerationRequest
from repro.runtime.engine import _RateBucket
from repro.runtime.scheduler import (Request, ServeStats,
                                     stats_from_requests)


@dataclasses.dataclass
class FleetStats:
    submitted: int = 0
    held_budget: int = 0     # submissions parked on a tenant budget
    migrations: int = 0      # sealed cross-worker KV moves
    migrated_bytes: int = 0  # ciphertext bytes those moves carried
    requeued: int = 0        # queued (KV-less) requests moved on drain/kill
    kills: int = 0
    drains: int = 0
    respawns: int = 0


class Orchestrator:
    def __init__(self, gateway: Gateway, workers: List[EngineWorker], *,
                 placement: str = "least_loaded",
                 tenant_budgets: Optional[Dict[str, float]] = None,
                 default_tenant: str = "default",
                 worker_factory=None):
        """``tenant_budgets`` maps tenant -> tokens/s; tenants named there
        are auto-registered. ``worker_factory(name) -> EngineWorker``
        enables :meth:`respawn`. Every worker passed in is attested (and
        receives all tenant key domains) before any traffic routes."""
        try:
            self._placement = PLACEMENTS[placement]
        except KeyError:
            raise ValueError(f"unknown placement {placement!r}; "
                             f"pick from {sorted(PLACEMENTS)}") from None
        self.gateway = gateway
        self._factory = worker_factory
        self.default_tenant = default_tenant
        gateway.register_tenant(default_tenant)
        for tenant in (tenant_budgets or {}):
            gateway.register_tenant(tenant)
        self._tenant_buckets = {t: _RateBucket(rate)
                                for t, rate in (tenant_budgets or {}).items()}
        self.workers: Dict[str, EngineWorker] = {}
        for w in workers:
            self.add_worker(w)
        self._pending: List[GenerationRequest] = []
        self.handles: Dict[int, Request] = {}    # id(gen) -> routed Request
        self.stats = FleetStats()

    # -- fleet membership -----------------------------------------------------
    def add_worker(self, worker: EngineWorker) -> None:
        if worker.name in self.workers and \
                self.workers[worker.name].state != DEAD:
            raise ValueError(f"worker name {worker.name!r} is already live "
                             f"(names key the migration nonce namespace)")
        self.gateway.admit(worker)
        self.workers[worker.name] = worker

    def ready_workers(self) -> List[EngineWorker]:
        return [w for w in self.workers.values() if w.state == READY]

    # -- submission / routing -------------------------------------------------
    def submit(self, gen: GenerationRequest) -> Optional[Request]:
        """Route one request into the fleet. Returns the live ``Request``
        handle, or None when the tenant's budget holds it at the gateway —
        it routes automatically once the bucket refills (``handles`` maps
        the submitted object to its handle afterwards)."""
        if gen.tenant is None:
            gen.tenant = self.default_tenant
        if gen.tenant not in self.gateway.tenants:
            raise KeyError(f"unknown tenant {gen.tenant!r} — register it on "
                           f"the gateway first")
        self.stats.submitted += 1
        bucket = self._tenant_buckets.get(gen.tenant)
        if bucket is not None and not bucket.can(gen.max_new_tokens):
            self._pending.append(gen)
            self.stats.held_budget += 1
            return None
        return self._route(gen)

    def _route(self, gen: GenerationRequest) -> Request:
        ready = self.ready_workers()
        if not ready:
            raise RuntimeError("no READY worker to route to")
        bucket = self._tenant_buckets.get(gen.tenant)
        if bucket is not None:
            bucket.charge(gen.max_new_tokens)
        worker = self._placement(ready, gen)
        env = self.gateway.envelope_seal(worker.name, gen.tenant, gen.prompt)
        gen.prompt = worker.open_envelope(env)
        req = worker.engine.submit(gen)
        self.handles[id(gen)] = req
        return req

    # -- serving loop ---------------------------------------------------------
    def step(self) -> int:
        """One fleet tick: re-try budget-held submissions, then advance every
        live worker's engine one step. Returns tokens produced fleet-wide."""
        if self._pending:
            still = []
            for gen in self._pending:
                bucket = self._tenant_buckets.get(gen.tenant)
                if bucket is None or bucket.can(gen.max_new_tokens):
                    self._route(gen)
                else:
                    still.append(gen)
            self._pending = still
        produced = 0
        for w in self.workers.values():
            if w.state in (READY, DRAINING) and not w.engine.idle:
                produced += w.engine.step()
        return produced

    @property
    def idle(self) -> bool:
        return not self._pending and all(
            w.engine.idle for w in self.workers.values()
            if w.state in (READY, DRAINING))

    def run(self, max_steps: int = 10_000) -> ServeStats:
        steps = 0
        while not self.idle and steps < max_steps:
            produced = self.step()
            steps += 1
            if produced == 0 and (self._pending or not self.idle):
                # budget-held or rate-gated everywhere: let buckets refill
                time.sleep(1e-3)
        return self.fleet_stats()

    # -- failure / drain / respawn --------------------------------------------
    def kill(self, name: str) -> None:
        """Forced worker failure: the enclave is lost mid-flight, but its
        sealed snapshot — ciphertext under the per-tenant domains, the
        at-rest property TEEs buy — survives and redistributes. In-flight
        requests complete on surviving workers byte-identically."""
        worker = self.workers[name]
        migrants, queued = worker.export_state()
        worker.state = DEAD
        self.stats.kills += 1
        self._redistribute(migrants, queued, exclude=name)

    def drain(self, name: str) -> None:
        """Graceful evacuation (host maintenance): stop admitting, seal the
        worker's state out under the tenant domains, move it, retire."""
        worker = self.workers[name]
        worker.state = DRAINING
        worker.engine.drain()
        migrants, queued = worker.export_state()
        self.stats.drains += 1
        self._redistribute(migrants, queued, exclude=name)
        worker.state = DEAD

    def _redistribute(self, migrants, queued, exclude: str) -> None:
        survivors = [w for w in self.ready_workers() if w.name != exclude]
        if not survivors and (migrants or queued):
            raise RuntimeError("no surviving READY worker to adopt the "
                               "exported state")
        for p in migrants:
            target = self._placement(survivors, p.req.gen)
            target.engine.import_sealed_state([p])
            self.stats.migrations += 1
            self.stats.migrated_bytes += sealed_nbytes(p.sealed)
        for req in queued:
            target = self._placement(survivors, req.gen)
            target.engine.import_sealed_state([], [req])
            self.stats.requeued += 1

    def respawn(self, name: str) -> EngineWorker:
        """Replace a DEAD worker: the factory builds a fresh one (fresh
        TrustDomain — a respawn is a new enclave), the gateway re-attests
        it and re-releases every tenant domain."""
        if not callable(self._factory):
            raise RuntimeError("no worker_factory configured")
        worker = self._factory(name)
        self.add_worker(worker)
        self.stats.respawns += 1
        return worker

    # -- observability --------------------------------------------------------
    def fleet_stats(self) -> ServeStats:
        reqs = []
        for w in self.workers.values():
            reqs += w.engine.scheduler.finished + w.engine.scheduler.dropped
        return stats_from_requests(reqs)

    def channel_totals(self) -> Dict[str, int]:
        """Summed boundary counters across every worker's TrustDomain."""
        totals = {"messages_in": 0, "messages_out": 0, "tokens_out": 0,
                  "seal_events": 0, "seal_bytes": 0,
                  "restore_events": 0, "restore_bytes": 0,
                  "store_hits": 0, "store_restored_bytes": 0,
                  "store_evictions": 0}
        for w in self.workers.values():
            ch = w.td.channel.stats
            for k in totals:
                totals[k] += getattr(ch, k)
        return totals
