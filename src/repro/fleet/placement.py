"""Pluggable placement policies: which READY worker gets a request.

A policy is ``(workers, gen) -> EngineWorker`` over a non-empty list of
ready workers. Ties break on worker name so placement is deterministic —
the fleet's differential tests rely on a reproducible routing given the
same submission order.
"""

from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.fleet.worker import EngineWorker
from repro.runtime.api import GenerationRequest


def least_loaded(workers: List[EngineWorker],
                 gen: GenerationRequest) -> EngineWorker:
    """Lowest effective KV demand wins (``kv_need`` already nets out
    resident shared pages, so this is pages the worker would actually be
    holding, not worst-case paper capacity)."""
    return min(workers, key=lambda w: (w.load(), w.name))


def tenant_affinity(workers: List[EngineWorker],
                    gen: GenerationRequest) -> EngineWorker:
    """Prefer a worker already serving this tenant — its content index
    likely holds the tenant's shared prompt prefixes resident, so the
    request maps pages instead of writing them. Falls back to least-loaded
    across the whole pool when no worker serves the tenant yet (or the
    request is tenant-less)."""
    if gen.tenant is not None:
        serving = [w for w in workers if w.serves_tenant(gen.tenant)]
        if serving:
            return least_loaded(serving, gen)
    return least_loaded(workers, gen)


def store_affinity(workers: List[EngineWorker],
                   gen: GenerationRequest) -> EngineWorker:
    """Prefer the worker whose content surfaces — live page index plus
    persistent sealed-page store — already hold the most pages of this
    prompt: routing a recurring prompt back to the worker that published
    it converts a cold prefill into MAC-verified store restores. The
    router sees only content-key residency counts (the same cumulative
    hashes the index uses), never page data. Falls back to least-loaded on
    an all-cold prompt or between equally-warm workers."""
    def coverage(w: EngineWorker) -> int:
        kv = getattr(w.engine, "kv", None)
        if kv is None or not getattr(kv, "supports_sharing", False):
            return 0
        prompt = np.asarray(gen.prompt, np.int32)
        keys = kv.page_keys(prompt, len(prompt))
        if not keys:
            return 0
        return kv.resident_pages(keys) + kv.store_resident_pages(keys)
    cover = {w.name: coverage(w) for w in workers}
    best = max(cover.values())
    if best > 0:
        return least_loaded([w for w in workers if cover[w.name] == best],
                            gen)
    return least_loaded(workers, gen)


PLACEMENTS: Dict[str, Callable[[List[EngineWorker], GenerationRequest],
                               EngineWorker]] = {
    "least_loaded": least_loaded,
    "tenant_affinity": tenant_affinity,
    "store_affinity": store_affinity,
}
