"""Pluggable placement policies: which READY worker gets a request.

A policy is ``(workers, gen) -> EngineWorker`` over a non-empty list of
ready workers. Ties break on worker name so placement is deterministic —
the fleet's differential tests rely on a reproducible routing given the
same submission order.
"""

from __future__ import annotations

from typing import Callable, Dict, List

from repro.fleet.worker import EngineWorker
from repro.runtime.api import GenerationRequest


def least_loaded(workers: List[EngineWorker],
                 gen: GenerationRequest) -> EngineWorker:
    """Lowest effective KV demand wins (``kv_need`` already nets out
    resident shared pages, so this is pages the worker would actually be
    holding, not worst-case paper capacity)."""
    return min(workers, key=lambda w: (w.load(), w.name))


def tenant_affinity(workers: List[EngineWorker],
                    gen: GenerationRequest) -> EngineWorker:
    """Prefer a worker already serving this tenant — its content index
    likely holds the tenant's shared prompt prefixes resident, so the
    request maps pages instead of writing them. Falls back to least-loaded
    across the whole pool when no worker serves the tenant yet (or the
    request is tenant-less)."""
    if gen.tenant is not None:
        serving = [w for w in workers if w.serves_tenant(gen.tenant)]
        if serving:
            return least_loaded(serving, gen)
    return least_loaded(workers, gen)


PLACEMENTS: Dict[str, Callable[[List[EngineWorker], GenerationRequest],
                               EngineWorker]] = {
    "least_loaded": least_loaded,
    "tenant_affinity": tenant_affinity,
}
