"""BM25 retrieval (Robertson/Okapi) — the classic ranking model the paper
runs inside TDX via Elasticsearch (§VI). Self-contained implementation: the
index lives inside the trust domain, so document contents never leave it.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import Counter, defaultdict
from typing import Dict, List, Sequence, Tuple

_TOKEN_RE = re.compile(r"[a-z0-9]+")


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(text.lower())


@dataclasses.dataclass
class BM25Index:
    k1: float = 1.2
    b: float = 0.75

    def __post_init__(self):
        self.doc_tokens: List[List[str]] = []
        self.doc_ids: List[str] = []
        self.df: Dict[str, int] = defaultdict(int)
        self.tf: List[Counter] = []
        self.doc_len: List[int] = []

    # -- build ---------------------------------------------------------------
    def add(self, doc_id: str, text: str) -> None:
        toks = tokenize(text)
        self.doc_ids.append(doc_id)
        self.doc_tokens.append(toks)
        counts = Counter(toks)
        self.tf.append(counts)
        self.doc_len.append(len(toks))
        for term in counts:
            self.df[term] += 1

    def build(self, docs: Dict[str, str]) -> "BM25Index":
        for doc_id, text in docs.items():
            self.add(doc_id, text)
        return self

    @property
    def n_docs(self) -> int:
        return len(self.doc_ids)

    @property
    def avg_len(self) -> float:
        return sum(self.doc_len) / max(len(self.doc_len), 1)

    # -- query ---------------------------------------------------------------
    def idf(self, term: str) -> float:
        df = self.df.get(term, 0)
        return math.log((self.n_docs - df + 0.5) / (df + 0.5) + 1.0)

    def score(self, query: str, doc_idx: int) -> float:
        toks = tokenize(query)
        score = 0.0
        dl = self.doc_len[doc_idx]
        for term in toks:
            f = self.tf[doc_idx].get(term, 0)
            if f == 0:
                continue
            denom = f + self.k1 * (1 - self.b + self.b * dl / self.avg_len)
            score += self.idf(term) * f * (self.k1 + 1) / denom
        return score

    def search(self, query: str, top_k: int = 10) -> List[Tuple[str, float]]:
        scores = [(self.doc_ids[i], self.score(query, i))
                  for i in range(self.n_docs)]
        scores.sort(key=lambda x: (-x[1], x[0]))
        return scores[:top_k]
