"""End-to-end RAG pipeline under a TrustDomain (paper §VI, Fig 14).

Three retrieval modes, as in the paper's BEIR evaluation:
  * bm25            — classic keyword ranking
  * bm25+rerank     — BM25 candidates reranked by dense cosine (cross-encoder
                      stand-in)
  * dense           — SBERT-style dense retrieval

The whole pipeline — index, retriever state, generation — lives inside the
trust domain: queries enter through the encrypted bounce buffer, documents
are sealed at rest, and the generator is the confidential Engine.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.confidential import TrustDomain
from repro.data.tokenizer import ByteTokenizer
from repro.rag.bm25 import BM25Index
from repro.rag.dense import DenseRetriever
from repro.runtime.api import GenerationRequest
from repro.runtime.engine import Engine


@dataclasses.dataclass
class RAGResult:
    query: str
    retrieved: List[Tuple[str, float]]
    answer_tokens: List[int]
    retrieval_s: float
    generation_s: float


class RAGPipeline:
    def __init__(self, docs: Dict[str, str], *, mode: str = "bm25",
                 engine: Optional[Engine] = None,
                 trust_domain: Optional[TrustDomain] = None,
                 rerank_candidates: int = 20):
        assert mode in ("bm25", "bm25+rerank", "dense")
        self.mode = mode
        self.td = trust_domain or (engine.td if engine else TrustDomain("none"))
        self.engine = engine
        self.tok = ByteTokenizer()
        self.rerank_candidates = rerank_candidates
        self.docs = docs
        # index construction happens inside the trust domain (sealed corpus)
        if self.td.confidential:
            sealed = {k: self.td.channel.host_send(
                np.frombuffer(v.encode(), np.uint8)) for k, v in docs.items()}
            docs = {k: bytes(self.td.channel.device_recv(s)).decode()
                    for k, s in sealed.items()}
        self.bm25 = BM25Index().build(docs) if mode != "dense" else None
        self.dense = (DenseRetriever().build(docs)
                      if mode in ("dense", "bm25+rerank") else None)

    def retrieve(self, query: str, top_k: int = 5) -> List[Tuple[str, float]]:
        if self.mode == "bm25":
            return self.bm25.search(query, top_k)
        if self.mode == "dense":
            return self.dense.search(query, top_k)
        # bm25 candidates -> dense rerank
        cands = self.bm25.search(query, self.rerank_candidates)
        scored = self.dense.search(query, len(self.dense.doc_ids))
        rank = {d: s for d, s in scored}
        reranked = sorted(cands, key=lambda x: -rank.get(x[0], -1e9))
        return [(d, rank.get(d, 0.0)) for d, _ in reranked[:top_k]]

    def query(self, query: str, top_k: int = 3,
              max_new_tokens: int = 16) -> RAGResult:
        q = self.td.ingress(np.frombuffer(query.encode(), np.uint8))
        query_clear = bytes(q).decode()
        t0 = time.monotonic()
        hits = self.retrieve(query_clear, top_k)
        t1 = time.monotonic()
        answer: List[int] = []
        if self.engine is not None:
            context = " ".join(self.docs[d][:200] for d, _ in hits)
            prompt = self.tok.encode(f"context: {context} question: {query_clear}")
            # explicit context budget: the engine refuses prompts that cannot
            # fit its KV cache, so trim the context head (the question sits at
            # the tail) rather than overflow. On a prefix-sharing engine a
            # repeated (resident) context additionally stops charging the
            # page pool at admission — engine.effective_kv_need reports the
            # discount — but the per-request budget itself is physical and
            # unchanged: every page of one sequence is mapped simultaneously.
            limit = self.engine.prompt_budget(max_new_tokens)
            if limit <= 0:
                raise ValueError(
                    f"engine (max_len={self.engine.max_len}, buckets="
                    f"{self.engine.prefill_buckets}) cannot serve "
                    f"{max_new_tokens} new tokens for any prompt")
            if len(prompt) > limit:
                prompt = prompt[-limit:]
            answer = self.engine.generate(GenerationRequest(
                prompt=prompt, max_new_tokens=max_new_tokens)).tokens
        t2 = time.monotonic()
        return RAGResult(query_clear, hits, answer, t1 - t0, t2 - t1)
