"""Dense retrieval: SBERT-style encoder + cosine similarity (paper §VI).

The encoder is a small transformer (our own DecoderLM trunk with causal=off
semantics approximated by mean pooling over token embeddings after the
stack) — enough to exercise the *systems* path the paper measures: embed the
corpus inside the TEE, keep the index sealed, score queries by cosine
similarity on-device.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models import build_model


def encoder_config(d_model: int = 64, num_layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name="sbert-tiny", family="dense", num_layers=num_layers,
        d_model=d_model, num_heads=4, num_kv_heads=4, head_dim=d_model // 4,
        d_ff=4 * d_model, vocab_size=ByteTokenizer.vocab_size,
        parallel=ParallelConfig(remat="none"),
    )


class DenseRetriever:
    def __init__(self, cfg: ModelConfig | None = None, max_len: int = 64,
                 seed: int = 0):
        self.cfg = cfg or encoder_config()
        self.model = build_model(self.cfg)
        self.params = self.model.init_params(jax.random.key(seed))
        self.tok = ByteTokenizer()
        self.max_len = max_len
        self.doc_ids: List[str] = []
        self.embeddings: jnp.ndarray | None = None

        @jax.jit
        def _embed(params, tokens):
            # mean-pooled hidden state as the sentence embedding
            impl = self.model._impl
            x = impl._embed(params, tokens)
            for name, n, slots in impl.blocks:
                x, _, _ = impl._run_block(name, slots, params[name], x,
                                          jnp.broadcast_to(
                                              jnp.arange(tokens.shape[1])[None],
                                              tokens.shape), "train", None)
            emb = jnp.mean(x.astype(jnp.float32), axis=1)
            return emb / (jnp.linalg.norm(emb, axis=-1, keepdims=True) + 1e-9)

        self._embed = _embed

    def _encode(self, texts: List[str]) -> jnp.ndarray:
        batch = np.zeros((len(texts), self.max_len), np.int32)
        for i, t in enumerate(texts):
            ids = self.tok.encode(t)[:self.max_len]
            batch[i, :len(ids)] = ids
        return self._embed(self.params, jnp.asarray(batch))

    # -- index ---------------------------------------------------------------
    def build(self, docs: Dict[str, str]) -> "DenseRetriever":
        self.doc_ids = list(docs.keys())
        self.embeddings = self._encode([docs[d] for d in self.doc_ids])
        return self

    def search(self, query: str, top_k: int = 10) -> List[Tuple[str, float]]:
        q = self._encode([query])[0]
        sims = jnp.einsum("d,nd->n", q, self.embeddings)
        order = np.argsort(-np.asarray(sims))
        return [(self.doc_ids[i], float(sims[i])) for i in order[:top_k]]
