"""Distributed layer: sharding rules, overlap collectives, pipeline, gradient
compression, fault tolerance. See DESIGN.md §4."""

from repro.distributed import sharding
from repro.distributed.compression import (
    make_grad_compressor, init_compression_state, compressed_bytes,
)
from repro.distributed.fault_tolerance import (
    FailureInjector, SimulatedFailure, run_with_restarts, reshard_state,
)
