"""Sharding rules: map every param/activation/cache leaf to a PartitionSpec.

Strategy (DESIGN.md §4):
  * TP over ``model``: attention heads, MLP hidden, MoE experts, vocab;
  * FSDP over ``data`` for the replicated remainder when cfg.parallel.fsdp
    (the embed/d_model dims), all-gathered at use inside the layer scan;
  * DP over ``data`` (x ``pod`` when multi-pod) for the batch;
  * SP for long decode: KV/latent cache *sequence* dim over ``model`` when
    the KV-head count does not divide the model axis.

Rules are structural — matched by leaf name + enclosing module path — so the
same table covers all 10 architectures. Any dim that does not divide its
mesh axes falls back to replication (keeps tiny smoke configs lowerable).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any

# spec symbols
M = "model"     # tensor-parallel axis
F = "__fsdp__"  # data axis iff cfg.parallel.fsdp else None
N = None

# top-level keys whose subtrees are layer-stacked (leading scan dim)
STACKED = {"layers", "moe_layers", "dense_layers", "groups",
           "enc_layers", "dec_layers"}

# (context key, leaf name) -> base spec (no stacking dim). Context "" matches
# any. First match wins; contexts are checked innermost-first.
RULES: Dict[Tuple[str, str], Tuple] = {
    # embedding / unembedding
    ("", "table"): (M, F),
    # attention (attn / self_attn / cross_attn share leaf names)
    ("", "wq"): (F, M, N),
    ("", "wk"): (F, M, N),
    ("", "wv"): (F, M, N),
    ("", "wo"): (M, N, F),
    # swiglu / shared experts
    ("", "w_gate"): (F, M),
    ("", "w_up"): (F, M),
    ("", "w_down"): (M, F),
    # gelu mlp
    ("", "w_in"): (F, M),
    ("", "b_in"): (M,),
    ("", "w_out"): (M, F),
    ("", "b_out"): (N,),
    # moe
    ("", "router"): (N, N),
    ("experts", "w_gate"): (M, F, N),
    ("experts", "w_up"): (M, F, N),
    ("experts", "w_down"): (M, N, F),
    # mla
    ("mla", "w_dq"): (F, N),
    ("mla", "w_uq"): (N, M, N),
    ("mla", "w_dkv"): (F, N),
    ("mla", "w_uk"): (N, M, N),
    ("mla", "w_uv"): (N, M, N),
    ("mla", "wo"): (M, N, F),
    # mamba
    ("mamba", "w_in"): (F, M),
    ("mamba", "conv_w"): (N, M),
    ("mamba", "conv_b"): (M,),
    ("mamba", "w_bcdt"): (M, N),
    ("mamba", "w_dt"): (N, M),
    ("mamba", "dt_bias"): (M,),
    ("mamba", "a_log"): (M, N),
    ("mamba", "d_skip"): (M,),
    ("mamba", "w_out"): (M, F),
    # rwkv time mix
    ("tmix", "w_r"): (N, M),
    ("tmix", "w_k"): (N, M),
    ("tmix", "w_v"): (N, M),
    ("tmix", "w_g"): (N, M),
    ("tmix", "w_o"): (M, N),
    ("decay_lora", "a"): (N, N),
    ("decay_lora", "b"): (N, M),
    ("tmix", "decay_base"): (M,),
    # rwkv channel mix
    ("cmix", "w_k"): (N, M),
    ("cmix", "w_v"): (M, N),
    ("cmix", "w_r"): (N, M),
}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape: Tuple[int, ...], spec: Tuple) -> P:
    """Replace any axis that does not evenly divide its dim with None."""
    fitted = []
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            fitted.append(axis)
        else:
            fitted.append(None)
    return P(*fitted)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
    return tuple(names)


def _base_spec(names: Tuple[str, ...]) -> Optional[Tuple]:
    leaf = names[-1]
    context = names[:-1]
    for ctx in reversed(context):
        if (ctx, leaf) in RULES:
            return RULES[(ctx, leaf)]
    return RULES.get(("", leaf))


def param_specs(cfg, abstract_params: Params, mesh: Mesh) -> Params:
    """PartitionSpec pytree matching ``abstract_params``.

    With ``dp_over_model`` (attention-free archs, §Perf): the model axis
    joins data parallelism, so TP dims are dropped and FSDP over ``data`` is
    forced — params shard over data, activations are fully local."""
    dp_over_model = cfg.parallel.dp_over_model
    fsdp_axis = "data" if (cfg.parallel.fsdp or dp_over_model) else None

    def spec_for(path, leaf):
        names = _path_names(path)
        stacked = bool(names) and names[0] in STACKED
        base = _base_spec(names)
        if base is None:
            base = (N,) * (leaf.ndim - (1 if stacked else 0))
        if dp_over_model:
            base = tuple(None if a == M else a for a in base)
        base = tuple(fsdp_axis if a == F else a for a in base)
        full = ((None,) + base) if stacked else base
        # pad/truncate defensively to leaf rank
        full = (tuple(full) + (None,) * leaf.ndim)[:leaf.ndim]
        return _fit(mesh, leaf.shape, full)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def state_specs(cfg, abstract_state, mesh: Mesh):
    """TrainState(params, OptState(m, v, step)) -> spec tree (moments follow
    their parameters; step is replicated).

    With ``zero1``: params keep their (replicated/TP) layout but the
    moments shard their leading dim over ``data`` — the ZeRO-1 dataflow
    (reduce-scatter grads, update shard, all-gather params) without FSDP's
    per-use gathers, which land inside sequential time scans for recurrent
    archs (EXPERIMENTS.md §Perf cell C)."""
    p_specs = param_specs(cfg, abstract_state.params, mesh)
    if cfg.parallel.zero1:
        def m_spec(pspec, leaf):
            if leaf.ndim and leaf.shape[0] % mesh.shape["data"] == 0:
                return P(*(("data",) + (None,) * (leaf.ndim - 1)))
            return pspec
        m_specs = jax.tree.map(m_spec, p_specs, abstract_state.opt.m)
    else:
        m_specs = p_specs
    return type(abstract_state)(
        params=p_specs,
        opt=type(abstract_state.opt)(m=m_specs, v=m_specs, step=P()),
    )


def _batch_axis_for(cfg, mesh: Mesh, batch_dim: int):
    """Pick the widest dp axis combo that divides the batch. With
    dp_over_model the model axis joins DP (flat data parallelism)."""
    dp = dp_axes(mesh)
    candidates = ([dp + ("model",), ("data", "model"), dp, ("data",)]
                  if cfg.parallel.dp_over_model else [dp, ("data",)])
    for cand in candidates:
        if all(a in mesh.axis_names for a in cand) \
                and batch_dim % _axis_size(mesh, cand) == 0:
            return cand
    return None


def batch_specs(cfg, abstract_batch: Dict[str, Any], mesh: Mesh):
    def spec_for(path, leaf):
        axis = _batch_axis_for(cfg, mesh, leaf.shape[0])
        base = (axis,) + (None,) * (leaf.ndim - 1)
        return _fit(mesh, leaf.shape, base)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_batch)


# cache leaf name -> which dim (after [L, B]) is sequence
_SEQ_LEAVES = {"k": 0, "v": 1, "ckv": 0, "krope": 1, "self_k": 0, "self_v": 1,
               "cross_k": 0, "cross_v": 1}


def cache_specs(cfg, abstract_cache: Params, mesh: Mesh) -> Params:
    """Decode/prefill cache sharding.

    Batch over dp; KV heads over ``model`` when they divide it, otherwise SP:
    the sequence dim shards over ``model`` (distributed-softmax attention).
    States (mamba/rwkv) shard their channel dim over ``model``.
    """
    model_size = mesh.shape.get("model", 1)
    if cfg.parallel.dp_over_model:
        model_size = 1  # model axis joins DP; no channel sharding

    def spec_for(path, leaf):
        names = _path_names(names_path := path)
        leaf_name = names[-1]
        if leaf_name == "pos":
            dp = _batch_axis_for(cfg, mesh, leaf.shape[0])
            return _fit(mesh, leaf.shape, (dp,))
        dp = _batch_axis_for(cfg, mesh,
                             leaf.shape[1] if leaf.ndim > 1 else leaf.shape[0])
        M_ = None if cfg.parallel.dp_over_model else M
        if leaf_name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            # [L, B, S, hk, hd]
            hk = leaf.shape[3]
            if model_size > 1 and hk % model_size == 0:
                return _fit(mesh, leaf.shape, (None, dp, None, M_, None))
            return _fit(mesh, leaf.shape, (None, dp, M_, None, None))
        if leaf_name in ("ckv", "krope"):
            # [L, B, S, r] — latent is per-token shared: SP over model
            return _fit(mesh, leaf.shape, (None, dp, M_, None))
        if leaf_name == "conv":      # [L, B, d_conv-1, d_inner]
            return _fit(mesh, leaf.shape, (None, dp, None, M_))
        if leaf_name == "ssm":       # [L, B, d_inner, d_state]
            return _fit(mesh, leaf.shape, (None, dp, M_, None))
        if leaf_name == "wkv":       # [L, B, h, k, v]
            return _fit(mesh, leaf.shape, (None, dp, M_, None, None))
        if leaf_name in ("tmix_x", "cmix_x"):  # [L, B, d]
            return _fit(mesh, leaf.shape, (None, dp, None))
        return _fit(mesh, leaf.shape, (None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_cache)


def to_named(mesh: Mesh, spec_tree: Params) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
