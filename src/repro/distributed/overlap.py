"""Compute/communication overlap: ring collective matmuls (shard_map).

XLA hides some collective latency, but the big TP wins come from *structural*
overlap: decomposing all-gather->matmul and matmul->reduce-scatter into a
ring of (chunk matmul || ppermute) steps so the ICI transfer of chunk i+1
runs under the MXU work of chunk i. These are the beyond-paper optimizations
applied in the §Perf hillclimb for collective-bound cells.

Both functions are written for use inside ``shard_map`` (manual collectives)
and are verified against their unoverlapped one-shot equivalents.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.compat import axis_size


def _ring_perm(axis_name: str, shift: int = 1):
    n = axis_size(axis_name)
    return [(i, (i + shift) % n) for i in range(n)]


def all_gather_matmul(x_local: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """y = all_gather(x, axis) @ w, overlapped.

    x_local: [m_l, k] (this rank's rows); w: [k, n] (replicated or local TP
    shard). Returns [m_l * p, n]. Each ring step matmuls the chunk currently
    held while the next chunk is in flight.
    """
    p = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m_l = x_local.shape[0]
    out = jnp.zeros((m_l * p, w.shape[1]), jnp.promote_types(x_local.dtype, w.dtype))
    chunk = x_local
    for step in range(p):
        src = (idx - step) % p            # whose rows we currently hold
        y = chunk @ w                      # compute...
        if step + 1 < p:
            chunk = jax.lax.ppermute(chunk, axis_name, _ring_perm(axis_name))
        out = jax.lax.dynamic_update_slice(out, y.astype(out.dtype),
                                           (src * m_l, 0))  # ...while data moves
    return out


def matmul_reduce_scatter(x: jax.Array, w_local: jax.Array,
                          axis_name: str) -> jax.Array:
    """y_local = reduce_scatter(x @ w, axis) over the contraction shards.

    x: [m, k_l]; w_local: [k_l, n] (both K-sharded). Full result would be
    sum_p x_p @ w_p, [m, n]; each rank keeps rows [idx*m_l, (idx+1)*m_l).
    Ring: a partial-sum buffer travels the ring, each rank adding its local
    contribution for the buffer's eventual owner while computing the next.
    """
    p = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = x.shape[0]
    assert m % p == 0, (m, p)
    m_l = m // p

    def local_chunk(owner):
        start = owner * m_l
        return jax.lax.dynamic_slice(x, (start, 0), (m_l, x.shape[1])) @ w_local

    # buffer starts as our contribution for rank (idx+p-1); after p-1 hops,
    # each rank adding its own contribution, it arrives at its owner complete.
    buf = local_chunk((idx + p - 1) % p)
    for step in range(p - 1):
        buf = jax.lax.ppermute(buf, axis_name, _ring_perm(axis_name))
        buf = buf + local_chunk((idx + p - 2 - step) % p)
    return buf
