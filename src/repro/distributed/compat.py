"""Version-bridging shims for the jax SPMD APIs this repo uses.

The codebase is written against the current jax surface (``jax.shard_map``
with ``check_vma``, ``jax.make_mesh(..., axis_types=...)``), but deployment
containers pin older jax releases where ``shard_map`` still lives in
``jax.experimental`` (kwarg ``check_rep``) and ``make_mesh`` has no
``axis_types``. Importing from here gives every caller — library code and
test subprocesses alike — one spelling that works on both.
"""

from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax

try:  # jax >= 0.6 style
    from jax import shard_map as _shard_map
except ImportError:  # pre-0.6: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
# the replication-check kwarg was renamed check_rep -> check_vma
# independently of the experimental->public move; inspect, don't assume.
_SHMAP_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")

_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check kwarg renamed as needed."""
    kw = {_SHMAP_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` dropping ``axis_types`` where unsupported (pre-0.5
    jax has no explicit/auto axis distinction — everything is Auto)."""
    if _MAKE_MESH_HAS_AXIS_TYPES:
        if axis_types is None and hasattr(jax.sharding, "AxisType"):
            axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def axis_size(axis_name) -> jax.Array:
    """``jax.lax.axis_size`` fallback: pre-0.6 jax spells it psum(1, axis)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
