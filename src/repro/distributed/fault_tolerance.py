"""Fault tolerance: failure injection, restart-with-resume, elastic rescale.

The runnability contract for 1000+ nodes (system brief): any step may die;
the job must resume from the latest good checkpoint, possibly on a
*different* device count (elastic), with stragglers detected and handled.

  * :class:`FailureInjector` — deterministic failure schedule for tests and
    the fault-tolerance example (stands in for preemptions/hardware faults).
  * :func:`run_with_restarts` — crash-looping driver: run -> on failure,
    restore latest checkpoint + data-cursor -> continue. Test-proven to
    produce the bitwise-identical loss curve to an uninterrupted run.
  * :func:`reshard_state` — elastic rescale: move a host-logical state tree
    onto a new mesh's shardings (save on mesh A, resume on mesh B).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional, Set

import jax

from repro.train.checkpoint import CheckpointManager

Params = Any


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedFailure at the given global step numbers (once each)."""
    fail_at: Set[int]
    fired: Set[int] = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


def run_with_restarts(*, state: Params, train_step: Callable,
                      data_factory: Callable[[int], Iterable],
                      num_steps: int, manager: CheckpointManager,
                      checkpoint_every: int,
                      injector: Optional[FailureInjector] = None,
                      max_restarts: int = 10):
    """Crash-looping training driver.

    ``data_factory(cursor)`` must return a deterministic iterator positioned
    at ``cursor`` batches consumed — checkpointing stores (state, cursor) so
    the resumed run sees exactly the batches the lost run would have.
    Returns (final_state, losses, restarts).
    """
    abstract = jax.eval_shape(lambda: state)
    step_fn = jax.jit(train_step)
    losses = {}
    restarts = 0
    start_step = 0

    while True:
        try:
            data = iter(data_factory(start_step))
            cur = state
            for step in range(start_step, num_steps):
                if injector is not None:
                    injector.check(step)
                batch = next(data)
                cur, metrics = step_fn(cur, batch)
                losses[step] = float(metrics["loss"])
                if (step + 1) % checkpoint_every == 0:
                    manager.save(step + 1, cur)
            return cur, [losses[i] for i in range(num_steps)], restarts
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            resumed_step, resumed = manager.resume(abstract)
            if resumed is None:
                start_step, state = 0, state
            else:
                start_step, state = resumed_step, resumed


def reshard_state(state: Params, shardings: Params) -> Params:
    """Elastic rescale: place a state tree onto new shardings (new mesh)."""
    return jax.tree.map(jax.device_put, state, shardings)
