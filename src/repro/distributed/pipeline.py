"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

For the multi-pod mesh the ``pod`` axis can run as pipeline stages instead of
extra data parallelism: stage s holds layers [s*L/S, (s+1)*L/S) and
microbatches flow through a (compute || ppermute) schedule with the classic
(S-1) bubble. Backward falls out of jax autodiff (the transpose of ppermute
is the reverse permute), so the same function trains.

This is an opt-in config (DESIGN.md §4); the dry-run's default multi-pod
mapping keeps ``pod`` as hierarchical DP.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.compat import axis_size, shard_map

Params = Any


def _ring(axis_name: str):
    n = axis_size(axis_name)
    return [(i, (i + 1) % n) for i in range(n)]


def gpipe_local(stage_fn: Callable[[Params, jax.Array], jax.Array],
                stage_params: Params, microbatches: jax.Array,
                axis_name: str) -> jax.Array:
    """Runs inside shard_map. ``microbatches``: [M, mb, ...] (same on every
    rank; only rank 0 consumes them). Returns [M, mb, ...] outputs valid on
    the LAST stage (zeros elsewhere).
    """
    s = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = microbatches.shape[0]
    carry = jnp.zeros_like(microbatches[0])
    outputs = jnp.zeros((m,) + microbatches.shape[1:], microbatches.dtype)

    for t in range(m + s - 1):
        inject = microbatches[min(t, m - 1)]
        x_in = jnp.where(idx == 0, inject, carry)
        y = stage_fn(stage_params, x_in)
        # emit: last stage finishes microbatch t-(s-1) at time t
        mb_idx = t - (s - 1)
        if mb_idx >= 0:
            emit = jnp.where(idx == s - 1, y, 0).astype(outputs.dtype)
            outputs = outputs.at[mb_idx].set(emit)
        # shift activations to the next stage
        carry = jax.lax.ppermute(y, axis_name, _ring(axis_name))
    return outputs


def make_gpipe(mesh: Mesh, axis_name: str,
               stage_fn: Callable[[Params, jax.Array], jax.Array],
               param_spec: P, in_spec: P, out_spec: P):
    """Wrap gpipe_local in shard_map for the given mesh axis.

    ``param_spec`` shards the stacked stage params [S, ...] over the axis;
    inputs/outputs are replicated ([M, mb, ...] everywhere, with the result
    broadcast from the last stage via psum of the zero-padded emits).
    """

    def pipelined(stacked_params: Params, microbatches: jax.Array) -> jax.Array:
        def local(params_local, mb):
            params_one = jax.tree.map(lambda x: x[0], params_local)
            out = gpipe_local(stage_fn, params_one, mb, axis_name)
            # broadcast final outputs to all ranks (only last stage nonzero)
            return jax.lax.psum(out, axis_name)

        return shard_map(local, mesh=mesh,
                         in_specs=(param_spec, in_spec),
                         out_specs=out_spec,
                         check_vma=False)(stacked_params, microbatches)

    return pipelined
