"""Gradient compression: int8 quantization with error feedback.

Cuts cross-pod (DCN) gradient bytes 4x for the multi-pod data axis — the
distributed-optimization trick the 1000-node posture needs where the paper's
platforms pay a 12x host-routed-link penalty (§V-D4): when the link is the
bottleneck, shrink the bytes.

Error feedback keeps the scheme convergent: the quantization residual is
carried into the next step (Seide et al. / EF-SGD), so compression noise is
zero-mean over time. Property-tested in tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class CompressionState(NamedTuple):
    error: Params  # residual feedback, f32, same structure as grads


def init_compression_state(params: Params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One leaf: add residual, quantize to int8 (what would cross the wire),
    dequantize, and compute the new residual."""
    gf = g.astype(jnp.float32) + err
    q, scale = _q8(gf)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), gf - deq


def make_grad_compressor(state: Optional[CompressionState] = None):
    """Returns (transform(grads) -> grads', get_state()) pair for wiring into
    make_train_step's grad_transform. Stateless-in-jit: the error term is
    threaded through a host-side cell updated each call."""
    cell = {"state": state}

    @jax.jit
    def _apply(grads: Params, error: Params):
        pairs = jax.tree.map(compress_decompress, grads, error)
        deq = jax.tree.map(lambda pr: pr[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return deq, new_err

    def transform(grads: Params) -> Params:
        if cell["state"] is None:
            cell["state"] = init_compression_state(grads)
        deq, new_err = _apply(grads, cell["state"].error)
        cell["state"] = CompressionState(new_err)
        return deq

    return transform, lambda: cell["state"]


def compressed_bytes(grads: Params) -> Tuple[int, int]:
    """(raw_bytes, wire_bytes) for reporting the DCN savings."""
    raw = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(grads))
    wire = sum(l.size * 1 + 4 for l in jax.tree.leaves(grads))  # int8 + scale
    return raw, wire
