"""Persistent content-addressed sealed-page store — the prefix-cache tier.

Prefix sharing (runtime/paged.py) only helps while something keeps a page
alive: a live table mapping holds plaintext in the pool, a sealed reference
parks ciphertext host-side. When the last reference drops, the parked blob
dies with it — and the next request carrying the same system prompt pays a
full prefill for content the domain already produced, sealed, and named.

The :class:`SealedPageStore` is the tier behind the content index that
retains that ciphertext past the last reference. It stores exactly the
blobs parking already mints — sealed under the canonical content-derived
name (:func:`repro.core.sealing.shared_page_name`), so identical content
always seals to the same (nonce, plaintext) pair and re-publishing a page
the store already holds is a membership no-op: no new ciphertext, no new
nonce, nothing to cross the boundary. The store holds ciphertext only; a
hit is MAC-verified on the way back into the pool like any other restore,
so a tampered entry fails closed before a single page moves.

Entries are namespaced per sealing-key domain (``SealingKey.key_id()``).
A fleet tenant's entries live under the tenant's key id: another tenant's
lookup is a clean miss by construction — the colliding content key is
never even consulted, so cross-tenant traffic cannot reach the MAC-failure
path, and if a blob were somehow offered across domains the independent
per-domain MAC key would reject it (core/sealing.py).

Retention is pluggable and budgeted in pages:

* ``lru`` — evict the least-recently-touched entry (publish and hit both
  refresh recency);
* ``cost`` — evict the entry whose retention buys the least, scored by the
  ``overheads.predict``-priced restore-vs-recompute breakeven: the sealed
  bytes a hit moves across the boundary vs the prefill compute it avoids,
  weighted by observed hits. A page that is cheap to recompute and never
  hit is the first to go however recently it landed.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.sealing import SealedTensor, SealingKey


@dataclasses.dataclass
class StoreEntry:
    """One retained page: content-named ciphertext plus retention state."""
    content_key: bytes                  # 16-byte prefix digest (the name)
    domain: str                         # SealingKey.key_id() namespace
    blobs: Dict[str, SealedTensor]      # kv leaf path -> sealed page
    n_bytes: int                        # plaintext bytes a hit restores
    tokens: int                         # prompt positions a hit avoids
    hits: int = 0
    stamp: int = 0                      # logical recency clock
    net_saving_s: float = 0.0           # priced recompute-minus-restore


def _lru(entries: Sequence[StoreEntry]) -> StoreEntry:
    return min(entries, key=lambda e: e.stamp)


def _cost(entries: Sequence[StoreEntry]) -> StoreEntry:
    # retention value = what keeping the page saves per future hit, scaled
    # by how often it actually hits (+1 so a never-hit entry still ranks by
    # its priced saving); recency breaks ties.
    return min(entries, key=lambda e: ((e.hits + 1) * e.net_saving_s, e.stamp))


POLICIES: Dict[str, Callable[[Sequence[StoreEntry]], StoreEntry]] = {
    "lru": _lru,
    "cost": _cost,
}


class SealedPageStore:
    """Content-addressed store of sealed KV pages, namespaced per key domain.

    ``budget_pages`` bounds total residency across all domains (None =
    unbounded); ``policy`` is ``"lru"``, ``"cost"``, or a callable picking
    the victim from a non-empty entry sequence. ``profile``/
    ``prefill_token_s`` feed the cost policy's restore-vs-recompute pricing
    (see :func:`repro.core.overheads.store_restore_savings`).
    """

    def __init__(self, budget_pages: Optional[int] = None,
                 policy: "str | Callable" = "lru", profile: str = "tdx",
                 prefill_token_s: Optional[float] = None):
        if callable(policy):
            self._policy = policy
            self.policy = getattr(policy, "__name__", "custom")
        else:
            if policy not in POLICIES:
                raise ValueError(f"unknown store policy '{policy}' "
                                 f"(have {sorted(POLICIES)})")
            self._policy = POLICIES[policy]
            self.policy = policy
        if budget_pages is not None and budget_pages < 0:
            raise ValueError("store_budget_pages must be >= 0")
        self.budget_pages = budget_pages
        self.profile = profile
        self.prefill_token_s = prefill_token_s
        self._domains: Dict[str, Dict[bytes, StoreEntry]] = {}
        self._clock = 0
        # counters (the bench's hit-rate and retention rows read these)
        self.hits = 0
        self.misses = 0
        self.publishes = 0
        self.republish_noops = 0
        self.evictions = 0
        self.restored_bytes = 0
        self.published_bytes = 0
        self.evicted_bytes = 0

    # -- addressing ---------------------------------------------------------

    @property
    def resident_pages(self) -> int:
        return sum(len(d) for d in self._domains.values())

    def _domain(self, key: SealingKey) -> Dict[bytes, StoreEntry]:
        return self._domains.setdefault(key.key_id(), {})

    def contains(self, key: SealingKey, content_key: bytes) -> bool:
        """Membership under this key domain, without touching recency or
        counters — what admission discounts and republish checks use."""
        return content_key in self._domains.get(key.key_id(), {})

    def resident_count(self, key: SealingKey,
                       content_keys: Sequence[bytes]) -> int:
        dom = self._domains.get(key.key_id(), {})
        return sum(1 for k in content_keys if k in dom)

    # -- the two verbs ------------------------------------------------------

    def lookup(self, key: SealingKey,
               content_key: bytes) -> Optional[Dict[str, SealedTensor]]:
        """The consuming read: returns the sealed blobs (caller MAC-verifies
        by unsealing) or None. Domains are keyed by ``key.key_id()``, so a
        lookup under any other key — a different fleet tenant — is a clean
        miss however many domains hold this content key. Hits refresh
        recency; the entry is retained, not consumed."""
        entry = self._domains.get(key.key_id(), {}).get(content_key)
        if entry is None:
            self.misses += 1
            return None
        self._clock += 1
        entry.stamp = self._clock
        entry.hits += 1
        self.hits += 1
        self.restored_bytes += entry.n_bytes
        return entry.blobs

    def publish(self, key: SealingKey, content_key: bytes,
                blobs: Dict[str, SealedTensor], *,
                tokens: int = 0) -> List[StoreEntry]:
        """Retain sealed blobs under (key domain, content key).

        Re-publishing a resident key is a no-op by membership check alone —
        the content-derived name guarantees the caller's blobs are
        byte-identical to what the store holds, so nothing is re-sealed and
        no nonce is minted twice. Returns the entries evicted to stay
        within ``budget_pages`` (possibly including the fresh one when the
        budget is 0) so the caller can account them as events."""
        dom = self._domain(key)
        if content_key in dom:
            self.republish_noops += 1
            return []
        n_bytes = sum(st.n_bytes for st in blobs.values())
        self._clock += 1
        entry = StoreEntry(content_key=content_key, domain=key.key_id(),
                           blobs=blobs, n_bytes=n_bytes, tokens=tokens,
                           stamp=self._clock,
                           net_saving_s=self._net_saving(n_bytes, tokens))
        dom[content_key] = entry
        self.publishes += 1
        self.published_bytes += n_bytes
        evicted: List[StoreEntry] = []
        while (self.budget_pages is not None
               and self.resident_pages > self.budget_pages):
            victims = [e for d in self._domains.values() for e in d.values()]
            v = self._policy(victims)
            del self._domains[v.domain][v.content_key]
            self.evictions += 1
            self.evicted_bytes += v.n_bytes
            evicted.append(v)
        return evicted

    # -- pricing ------------------------------------------------------------

    def _net_saving(self, n_bytes: int, tokens: int) -> float:
        """Seconds a future hit on this entry saves (recompute minus
        restore), per the overhead model. <= 0 means recompute wins and the
        cost policy sheds the entry first."""
        from repro.core.overheads import store_restore_savings
        restore, recompute, _ = store_restore_savings(
            1, n_bytes, tokens, self.profile,
            prefill_token_s=self.prefill_token_s)
        if restore is None or recompute is None:
            return 0.0
        return recompute.t_tee_s - restore.t_tee_s

    def describe(self) -> str:
        return (f"{self.resident_pages} resident pages in "
                f"{len(self._domains)} domains [policy={self.policy}, "
                f"budget={self.budget_pages}]: {self.hits} hits / "
                f"{self.misses} misses, {self.publishes} publishes "
                f"({self.republish_noops} republish no-ops), "
                f"{self.evictions} evictions")
