"""Request/response object model for the serving API (engine v3).

The paper serves real traffic through vLLM/IPEX-style engines where every
request carries its own generation settings and deadline, and Insight 10
shows per-crossing fixed costs dominate cGPU overhead — a knob that only
exists per request (how many tokens ride in each encrypted egress frame).
This module is the stable surface the engine, launcher, benchmarks and
examples all speak:

  * :class:`SamplingParams` — how tokens are chosen (greedy by default;
    temperature/top-k with a reproducible per-request seed),
  * :class:`FramePolicy`   — how sampled tokens cross the trust boundary
    (``coalesce=1``: one encrypted frame per token, the paper's SecureChat
    pattern; ``coalesce=N``: N tokens amortize one frame's fixed cost),
  * :class:`GenerationRequest` — prompt + params + priority + SLO fields
    (relative deadline, drop-on-deadline policy),
  * :class:`RequestOutput` — tokens, finish reason, per-request timing and
    boundary-crossing counts (the unit Insight 10's fixed cost is paid per).

Everything here is plain host-side data; the engine turns
:class:`SamplingParams` into ``[slots]``-shaped device arrays (see
``kvcache.SlotState``) so the jitted decode step samples per request.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

# fired as each token becomes visible OUTSIDE the trust domain (i.e. at
# frame-flush time, not at sample time, when frames are coalesced)
TokenCallback = Callable[["object", int], None]

FINISH_LENGTH = "length"     # hit max_new_tokens
FINISH_STOP = "stop"         # emitted eos_id
FINISH_DROPPED = "dropped"   # deadline passed while queued (on_deadline="drop")
FINISH_ABORTED = "aborted"   # deadline passed mid-flight (on_deadline="abort")
FINISH_REJECTED = "rejected"  # refused at ingest: deadline provably unmeetable


@dataclasses.dataclass
class SamplingParams:
    """Per-request token-selection settings.

    ``temperature <= 0`` is greedy (the default — byte-identical to engine
    v2). With ``temperature > 0`` the engine samples from the scaled
    distribution, optionally restricted to the ``top_k`` highest logits
    (``top_k=0`` = unrestricted; ``top_k`` must be < vocab_size — use 0
    instead of the degenerate full-vocab restriction) and/or to the nucleus
    of tokens whose cumulative probability reaches ``top_p``
    (``top_p=1.0`` = off). ``top_k`` and ``top_p`` compose: the support is
    the intersection of both restrictions.

    ``repetition_penalty`` (> 1 discourages; CTRL-style: positive logits of
    already-generated tokens divide by it, negative ones multiply) and
    ``presence_penalty`` (a flat subtraction from every already-generated
    token's logit; may be negative to *encourage* reuse) act on this
    request's generated tokens only — the prompt is not penalized, and
    neither applies to greedy requests. The neutral values (1.0 / 0.0) are
    free: like ``top_p``, the penalty math only compiles into the decode
    step when some live request actually uses it.

    ``logit_bias`` maps token ids to additive logit offsets, applied to the
    raw logits before the penalties every step (use a large negative value
    like ``-100`` to ban a token, a positive one to promote it). The map is
    static for the request's lifetime and rebuilt whenever its slot's
    sampling row is set, so a sealed preemption/restore reproduces it
    exactly. Like the penalties it only applies to sampled requests — a
    greedy request with a bias is rejected at validation rather than
    silently ignoring the map (the greedy fast path never consults sampling
    state).

    ``seed`` makes the request reproducible: the engine derives one PRNG key
    from it and ``fold_in``s the output-token index at every step, so the
    same seeded request yields byte-identical tokens even across a sealed-KV
    preemption/restore cycle (the fold-in depends only on how many tokens
    exist, not on when they were produced — and the penalty history is
    rebuilt from the request's own output list on restore). Unseeded sampled
    requests get a fresh seed at submit time (recorded in
    :class:`RequestOutput`).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    repetition_penalty: float = 1.0
    presence_penalty: float = 0.0
    logit_bias: Optional[Dict[int, float]] = None
    seed: Optional[int] = None

    def validate(self, vocab_size: int) -> None:
        if not np.isfinite(self.temperature):
            raise ValueError(f"temperature must be finite, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.top_k >= vocab_size:
            raise ValueError(
                f"top_k={self.top_k} must be < vocab_size={vocab_size}; "
                f"use top_k=0 for an unrestricted distribution")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(
                f"top_p must be in (0, 1], got {self.top_p}; "
                f"use top_p=1.0 for an unrestricted distribution")
        if not (np.isfinite(self.repetition_penalty)
                and self.repetition_penalty > 0):
            raise ValueError(
                f"repetition_penalty must be finite and > 0, got "
                f"{self.repetition_penalty}; 1.0 turns it off")
        if not np.isfinite(self.presence_penalty):
            raise ValueError(f"presence_penalty must be finite, got "
                             f"{self.presence_penalty}; 0.0 turns it off")
        if self.logit_bias:
            if self.is_greedy:
                raise ValueError(
                    "logit_bias requires temperature > 0: the greedy path "
                    "takes argmax over the raw logits and would silently "
                    "ignore the bias map")
            for tok, val in self.logit_bias.items():
                if not (0 <= int(tok) < vocab_size):
                    raise ValueError(
                        f"logit_bias token id {tok} out of range "
                        f"[0, {vocab_size})")
                if not np.isfinite(val):
                    raise ValueError(
                        f"logit_bias[{tok}] must be finite, got {val}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclasses.dataclass
class FramePolicy:
    """How a request's sampled tokens leave the trust domain.

    ``coalesce=1`` streams one encrypted frame per token — maximum boundary
    crossings, the honest worst case the cgpu profile's ``fixed_boundary_s``
    prices. ``coalesce=N`` buffers N tokens per frame (flush-on-finish), so
    one fixed per-crossing cost is amortized over N tokens — the Insight-10
    amortization curve ``serve_bench.py`` sweeps. Decoded output is
    unaffected; only latency-to-client and crossing counts change.
    """
    coalesce: int = 1

    def validate(self) -> None:
        if self.coalesce < 1:
            raise ValueError(f"coalesce must be >= 1, got {self.coalesce}")


@dataclasses.dataclass
class GenerationRequest:
    """One unit of serving work: prompt + per-request policies.

    SLO fields: ``deadline_s`` is relative to submit time. With
    ``on_deadline="drop"`` the scheduler removes the request if the deadline
    passes while it is still queued (counted in ``ServeStats.dropped_requests``;
    its :class:`RequestOutput` carries ``finish_reason="dropped"``). With
    ``on_deadline="abort"`` the deadline is enforced *mid-flight* too: a
    running request past its deadline is terminated at the next engine step
    (partial tokens delivered, ``finish_reason="aborted"``), and a sealed-out
    (preempted) request past its deadline is discarded instead of restored —
    bounding the tail latency its slot-mates would otherwise pay. Both count
    in ``ServeStats.deadline_misses``. With the default ``"serve"`` it is
    served anyway and a late finish is counted in
    ``ServeStats.deadline_misses``. Requests are single-use: submit a fresh
    object per call.

    ``share_prefix`` (default True) lets a prefix-sharing engine
    (``Engine(kv_backend="paged", prefix_sharing=True)``) map this
    request's prompt pages onto resident shared physical pages and register
    its own pages in the content index. Opting out (``share_prefix=False``)
    keeps every page private — for tenants whose prompts must not be
    content-addressed alongside other traffic, at worst-case memory cost.
    On a non-sharing engine the flag is inert. Decoded output is identical
    either way.
    """
    prompt: np.ndarray
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    priority: int = 0                  # higher = more important
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    frame: FramePolicy = dataclasses.field(default_factory=FramePolicy)
    deadline_s: Optional[float] = None
    on_deadline: str = "serve"         # "serve" | "drop" | "abort"
    share_prefix: bool = True
    on_token: Optional[TokenCallback] = None
    # Which tenant's key domain this request's sealed KV and egress frames
    # live in (fleet serving). None = the worker's own domain — the
    # single-engine default, byte-identical to pre-fleet behavior. The
    # gateway sets it; a tenant can't choose another tenant's domain because
    # the domain key itself never leaves the attested workers.
    tenant: Optional[str] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)

    def validate(self, vocab_size: int) -> None:
        if self.max_new_tokens < 1:
            # the prefill-produced first token always exists; a request that
            # asked for zero would still emit (and egress) it.
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if self.on_deadline not in ("serve", "drop", "abort"):
            raise ValueError(f"on_deadline must be 'serve', 'drop' or "
                             f"'abort', got {self.on_deadline!r}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        self.params.validate(vocab_size)
        self.frame.validate()


@dataclasses.dataclass
class RequestOutput:
    """The finished (or dropped) result of one :class:`GenerationRequest`.

    Timing is host-measured: ``ttft_s`` submit→first sampled token,
    ``e2e_s`` submit→done. Boundary counts are per request — the crossings
    this request paid for: one ingress message for the prompt and
    ``egress_frames`` encrypted frames carrying ``egress_tokens`` tokens
    (``egress_frames == ceil(tokens / coalesce)``; both 0 outside a
    confidential mode, where nothing crosses an encrypted boundary).
    """
    rid: int
    tokens: List[int]
    finish_reason: str
    ttft_s: float = 0.0
    e2e_s: float = 0.0
    n_preemptions: int = 0
    sealed_bytes: int = 0
    deadline_missed: bool = False
    ingress_messages: int = 0
    egress_frames: int = 0
    egress_tokens: int = 0
    seed: Optional[int] = None

    @classmethod
    def from_request(cls, req) -> "RequestOutput":
        """Build from a finished scheduler ``Request`` (duck-typed to avoid
        an api->scheduler import cycle)."""
        if not req.finished:
            raise RuntimeError(f"request {req.rid} has not finished")
        return cls(
            rid=req.rid,
            tokens=list(req.output),
            finish_reason=req.finish_reason,
            ttft_s=(req.t_first_token - req.t_submit) if req.output else 0.0,
            e2e_s=req.t_done - req.t_submit,
            n_preemptions=req.n_preemptions,
            sealed_bytes=req.sealed_bytes,
            deadline_missed=req.deadline_missed,    # one source: the Request
            ingress_messages=req.ingress_messages,
            egress_frames=req.egress_frames,
            egress_tokens=req.egress_tokens,
            seed=req.seed,
        )
