"""Pluggable KV-cache backends for continuous batching.

The engine sees one :class:`KVBackend` interface; the KV *layout* behind it
is a deployment choice (``Engine(kv_backend="slot"|"paged")``). Picking one:

**Slot-dense** (:class:`SlotDenseBackend`, the default) keeps a fixed
``[L, max_slots, max_len, ...]`` buffer, one slot per in-flight sequence —
the JetStream-style TPU-native layout: contiguous reads for the MXU/VPU,
static shapes for XLA, zero indirection on the decode hot path. It wins when
sequences actually use most of ``max_len`` (short-context chat at high
occupancy), when ``max_len`` is small enough that whole-slot sealing is
cheap, and when decode-step latency matters more than memory efficiency.

**Paged** (:class:`~repro.runtime.paged.PagedKVBackend`) keeps a static
``[num_pages, page_size, ...]`` pool plus an ``[slots, max_pages]`` int32
page table; decode gathers each slot's pages into the dense view the model
expects (``jnp.take`` over the table — still static shapes, TPU-safe) and
scatters back only the one appended position. Everything becomes
proportional to *tokens used, not capacity reserved*:

  * admission charges ``ceil(need / page_size)`` pages instead of an
    implicit whole ``max_len`` slot — long-context mixes where most
    requests are short admit far more concurrency from the same HBM;
  * sealed preemption seals per-page ciphertext (per-page nonces), so
    evicting a sequence that holds 3 pages moves 3 pages across the trust
    boundary, not ``max_len`` worth (the paper's Insight-10 boundary-cost
    model: crossings are fixed-cost dominated, so move less);
  * partial eviction can free just the tail pages of a victim and restore
    only that delta later.

It costs one gather per decode step and page-table bookkeeping. Prefer it
for long-context workloads (``max_len`` ≥ 1k), memory-constrained pools,
or whenever preemption/sealing traffic shows up in ``ChannelStats``.

``page_size`` guidance: small pages (8–16) track token usage tightly
(least waste, most seal granularity) but grow the page table and per-page
seal count; large pages (64–128) amortize per-page fixed costs toward
slot-dense behavior. 16–32 is a good default at ``max_len`` ≤ 4k; scale
page_size with context length so ``max_pages`` stays in the hundreds.

Cache pytrees follow the model layout contract: top-level key "pos" is
batch-major [b]; every other leaf is layer-stacked with batch at axis 1
([L, b, ...]). ``insert_slot``/``insert_rows``/``extract_slot`` are the
dense splice primitives both backends build on.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sealing import (SealedTensor, SealingKey, seal_tree,
                                unseal_tree)
from repro.runtime import sampling

Cache = Any
Params = Any


@dataclasses.dataclass
class SlotState:
    """Slot bookkeeping + the ``[slots]``-shaped per-sequence sampling rows
    the jitted decode step consumes (each sequence samples with its own
    temperature/top-k/top-p/PRNG key). Owned by the KV backend — a backend
    maps sequences to whatever physical layout it likes, but every live
    sequence holds exactly one row here. The arrays are host-side numpy
    mirrors; the engine snapshots them into a ``sampling.SamplingState`` per
    step. A released row resets to greedy (temp 0, top_p 1) so stale
    settings can never leak into the next occupant."""
    free: List[int]
    active: dict  # slot -> request id
    temp: np.ndarray    # [slots] f32; <= 0 → greedy
    top_k: np.ndarray   # [slots] i32; 0 → unrestricted
    top_p: np.ndarray   # [slots] f32; >= 1 → unrestricted
    key: np.ndarray     # [slots, 2] u32 per-request base PRNG keys

    @classmethod
    def create(cls, max_slots: int) -> "SlotState":
        return cls(free=list(range(max_slots)), active={},
                   temp=np.zeros(max_slots, np.float32),
                   top_k=np.zeros(max_slots, np.int32),
                   top_p=np.ones(max_slots, np.float32),
                   key=np.zeros((max_slots, 2), np.uint32))

    def acquire(self, request_id: int) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop(0)
        self.active[slot] = request_id
        return slot

    def release(self, slot: int) -> None:
        rid = self.active.pop(slot, None)
        if rid is not None:
            self.free.append(slot)
            self.clear_sampling(slot)

    def set_sampling(self, slot: int, temp: float, top_k: int, top_p: float,
                     key: np.ndarray) -> None:
        self.temp[slot] = temp
        self.top_k[slot] = top_k
        self.top_p[slot] = top_p
        self.key[slot] = key

    def clear_sampling(self, slot: int) -> None:
        self.temp[slot] = 0.0
        self.top_k[slot] = 0
        self.top_p[slot] = 1.0
        self.key[slot] = 0

    @property
    def any_sampled(self) -> bool:
        return bool((self.temp > 0).any())

    @property
    def any_top_p(self) -> bool:
        return bool(((self.temp > 0) & (self.top_p < 1.0)).any())

    @property
    def max_top_k(self) -> int:
        return int(self.top_k.max()) if len(self.top_k) else 0

    @property
    def num_active(self) -> int:
        return len(self.active)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (shape padding keeps compiled variants
    bounded by log2, not one per batch/scatter size)."""
    p = 1
    while p < n:
        p *= 2
    return p


def _is_pos(path) -> bool:
    return any(getattr(k, "key", None) == "pos" for k in path[:1])


@jax.jit
def insert_slot(batched: Cache, single: Cache, slot: jax.Array) -> Cache:
    """Write a b=1 cache into batch slot ``slot`` of the batched cache."""
    def upd(path, big, small):
        if _is_pos(path):
            return big.at[slot].set(small[0])
        # [L, 1, ...] into [L, B, ...] at axis 1
        start = (jnp.int32(0), slot.astype(jnp.int32)) + (jnp.int32(0),) * (big.ndim - 2)
        return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)
    return jax.tree_util.tree_map_with_path(upd, batched, single)


@partial(jax.jit, donate_argnums=(0,))
def insert_rows(batched: Cache, src: Cache, slots: jax.Array) -> Cache:
    """Scatter the first k rows of a b>=k cache into batch slots ``slots``
    (int32 [k], distinct) in ONE donated call — a batched prefill group
    splices in with a single cache materialization instead of k full-cache
    copies through repeated ``insert_slot``."""
    k = slots.shape[0]
    def upd(path, big, small):
        if _is_pos(path):
            return big.at[slots].set(small[:k])
        # [L, k, ...] rows into [L, B, ...] at axis 1
        return big.at[:, slots].set(small[:, :k].astype(big.dtype))
    return jax.tree_util.tree_map_with_path(upd, batched, src)


@jax.jit
def extract_slot(batched: Cache, slot: jax.Array) -> Cache:
    """Inverse of insert_slot: pull slot ``slot`` out as a b=1 cache."""
    def get(path, big):
        if _is_pos(path):
            return jax.lax.dynamic_slice(big, (slot.astype(jnp.int32),), (1,))
        start = (jnp.int32(0), slot.astype(jnp.int32)) + (jnp.int32(0),) * (big.ndim - 2)
        sizes = (big.shape[0], 1) + big.shape[2:]
        return jax.lax.dynamic_slice(big, start, sizes)
    return jax.tree_util.tree_map_with_path(get, batched)


def cache_bytes(cache: Cache) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


# ---------------------------------------------------------------------------
# backend protocol
# ---------------------------------------------------------------------------

class KVBackend:
    """One live KV store behind the engine. A backend owns

      * the device cache (whatever physical layout),
      * the slot <-> sequence mapping and per-sequence sampling rows
        (:class:`SlotState`),
      * the jitted decode step over its layout, and
      * the seal/restore format a preemption moves across the boundary.

    The engine speaks tokens: every capacity question is asked in "KV
    positions this request may write" (``n_tokens``), and the backend maps
    that onto slots, pages, or whatever it accounts in.
    """

    name: str = "?"

    def __init__(self, model, max_slots: int, max_len: int):
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.slots = SlotState.create(max_slots)

    # -- capacity -----------------------------------------------------------
    @property
    def request_capacity(self) -> int:
        """Most KV positions a single request may occupy."""
        return self.max_len

    def can_admit(self, n_tokens: int) -> bool:
        """Beyond a free slot, is there KV room for ``n_tokens`` positions?"""
        return True

    def can_restore(self, n_tokens: int) -> bool:
        """Room to re-admit a sealed-out sequence of ``n_tokens`` positions
        (a free slot is checked separately via ``slots.free``)."""
        return True

    def prompt_budget(self, max_new_tokens: int,
                      buckets: Sequence[int]) -> int:
        """Longest prompt a submit will accept for ``max_new_tokens``,
        accounting for prefill-bucket padding: a short prompt still occupies
        its whole (left-padded) bucket in the cache."""
        cand = self.request_capacity - max_new_tokens + 1  # last token: no KV
        if cand >= buckets[-1]:
            return cand
        fits = [b for b in buckets if b <= cand]
        return fits[-1] if fits else 0

    # -- sequence lifecycle ---------------------------------------------------
    def acquire(self, rid: int, n_tokens: int) -> Optional[int]:
        return self.slots.acquire(rid)

    def release(self, slot: int) -> None:
        self.slots.release(slot)

    # -- device compute -------------------------------------------------------
    def fresh_prefill_cache(self, rows: int) -> Cache:
        """A zeroed ``rows``-sequence dense cache for one prefill call (both
        backends prefill dense; the splice into backend storage differs)."""
        return self.model.init_cache(rows, self.max_len)

    def insert_prefill(self, prefilled: Cache, slots: List[int],
                       written_len: int) -> None:
        raise NotImplementedError

    def decode(self, params: Params, tokens: np.ndarray,
               state: Optional[sampling.SamplingState], kmax: int,
               write_slots: Sequence[int]) -> np.ndarray:
        """One batched decode+sample step over all ``max_slots`` rows.
        ``write_slots`` are the slots genuinely appending a KV position this
        step (active, not paused) — a backend may route other rows' writes
        to a scratch location. Returns the sampled token per row."""
        raise NotImplementedError

    def cache_nbytes(self) -> int:
        raise NotImplementedError

    # -- sealing --------------------------------------------------------------
    def seal(self, key: SealingKey, slot: int,
             prefix: str) -> Dict[str, SealedTensor]:
        """Encrypt slot ``slot``'s KV for eviction across the trust boundary.
        ``prefix`` must be unique per (stream, seal epoch) — it derives the
        nonces. Does NOT release the slot."""
        raise NotImplementedError

    def restore(self, key: SealingKey, sealed: Dict[str, SealedTensor],
                slot: int, prefix: str, n_tokens: int) -> None:
        """Inverse of :meth:`seal` into freshly-acquired slot ``slot``."""
        raise NotImplementedError


class SlotDenseBackend(KVBackend):
    """The dense ``[L, max_slots, max_len, ...]`` layout (see module
    docstring for when it wins). Sealing moves the victim's whole
    ``max_len`` extent regardless of how many positions hold live tokens."""

    name = "slot"

    def __init__(self, model, max_slots: int, max_len: int):
        super().__init__(model, max_slots, max_len)
        self.cache = model.init_cache(max_slots, max_len)

        def _decode(params, tokens, cache, state, kmax):
            logits, cache = model.decode_step(params, tokens, cache)
            if state is None:     # all-greedy step: no sampling state at all
                return sampling.greedy(logits), cache
            return sampling.sample(logits, state, kmax=kmax), cache

        self._decode_fn = jax.jit(_decode, donate_argnums=(2,),
                                  static_argnums=(4,))

    def insert_prefill(self, prefilled: Cache, slots: List[int],
                       written_len: int) -> None:
        # one donated scatter for the whole group (not k full-cache copies)
        self.cache = insert_rows(self.cache, prefilled,
                                 jnp.asarray(slots, jnp.int32))

    def decode(self, params, tokens, state, kmax, write_slots) -> np.ndarray:
        next_tokens, self.cache = self._decode_fn(
            params, jnp.asarray(tokens[:, None]), self.cache, state, kmax)
        return np.asarray(next_tokens)

    def cache_nbytes(self) -> int:
        return cache_bytes(self.cache)

    def seal(self, key, slot, prefix) -> Dict[str, SealedTensor]:
        single = extract_slot(self.cache, jnp.int32(slot))
        return seal_tree(key, single, prefix=prefix)

    def restore(self, key, sealed, slot, prefix, n_tokens) -> None:
        single_like = self.model.abstract_cache(1, self.max_len)
        single = unseal_tree(key, sealed, single_like, prefix=prefix)
        self.cache = insert_slot(self.cache, single, jnp.int32(slot))


def make_backend(kind: str, model, *, max_slots: int, max_len: int,
                 page_size: int = 16,
                 num_pages: Optional[int] = None) -> KVBackend:
    """Factory behind ``Engine(kv_backend=...)``."""
    if kind == "slot":
        return SlotDenseBackend(model, max_slots, max_len)
    if kind == "paged":
        from repro.runtime.paged import PagedKVBackend
        return PagedKVBackend(model, max_slots, max_len,
                              page_size=page_size, num_pages=num_pages)
    raise ValueError(f"unknown kv backend {kind!r} (want 'slot' or 'paged')")
