"""Pluggable KV-cache backends for continuous batching.

The engine sees one :class:`KVBackend` interface; the KV *layout* behind it
is a deployment choice (``Engine(kv_backend="slot"|"paged")``). Picking one:

**Slot-dense** (:class:`SlotDenseBackend`, the default) keeps a fixed
``[L, max_slots, max_len, ...]`` buffer, one slot per in-flight sequence —
the JetStream-style TPU-native layout: contiguous reads for the MXU/VPU,
static shapes for XLA, zero indirection on the decode hot path. It wins when
sequences actually use most of ``max_len`` (short-context chat at high
occupancy), when ``max_len`` is small enough that whole-slot sealing is
cheap, and when decode-step latency matters more than memory efficiency.

**Paged** (:class:`~repro.runtime.paged.PagedKVBackend`) keeps a static
``[num_pages, page_size, ...]`` pool plus an ``[slots, max_pages]`` int32
page table; decode gathers each slot's pages into the dense view the model
expects (``jnp.take`` over the table — still static shapes, TPU-safe) and
scatters back only the one appended position. Everything becomes
proportional to *tokens used, not capacity reserved*:

  * admission charges ``ceil(need / page_size)`` pages instead of an
    implicit whole ``max_len`` slot — long-context mixes where most
    requests are short admit far more concurrency from the same HBM;
  * sealed preemption seals per-page ciphertext (per-page nonces), so
    evicting a sequence that holds 3 pages moves 3 pages across the trust
    boundary, not ``max_len`` worth (the paper's Insight-10 boundary-cost
    model: crossings are fixed-cost dominated, so move less);
  * partial eviction can free just the tail pages of a victim and restore
    only that delta later.

It costs one gather per decode step and page-table bookkeeping. Prefer it
for long-context workloads (``max_len`` ≥ 1k), memory-constrained pools,
or whenever preemption/sealing traffic shows up in ``ChannelStats``.

``page_size`` guidance: small pages (8–16) track token usage tightly
(least waste, most seal granularity) but grow the page table and per-page
seal count; large pages (64–128) amortize per-page fixed costs toward
slot-dense behavior. 16–32 is a good default at ``max_len`` ≤ 4k; scale
page_size with context length so ``max_pages`` stays in the hundreds.

**Prefix sharing and on-demand allocation**
(``Engine(kv_backend="paged", prefix_sharing=True)``; paged only). A
content index maps the cumulative hash of the token ids up to each aligned
page boundary to one shared physical page with a per-page refcount;
requests whose (padded) prompts agree on a page-aligned prefix map the
same physical pages instead of storing copies. When sharing pays: any
workload where many requests open identically — RAG system prompts,
few-shot headers, agent scaffolds — provided the shared region is *page
aligned and position aligned* (prefill left-pads prompts into their
bucket, so equal-length prompts with a common head share; KV entries are
position-dependent, so a prefix at a different offset is different
content). Capacity multiplies: N requests over one B-page context cost
B + N·(suffix pages), not N·B — which in a TEE is the difference between
fitting in the attested enclave memory or paying sealed-eviction traffic.

COW cost model: shared *full* pages are never written again and cost
nothing; the final partial prompt page is written by the first decode
append, which triggers one page copy (copy-on-write) per sharer that
diverges while others still read the page — worst case ``ceil(one page)``
extra write per request, amortized against ``shared pages × page_size``
tokens of prefill KV never recomputed or stored. Sharing a page whose
writer was its sole reader degrades to an index unregistration (free).

Sealing semantics under sharing: a victim's *private* pages seal per-page
under its epoch prefix as usual; its *shared* pages seal **by reference**
— the sealed meta records each page's content key (and refcount), and
restore re-links the resident page (no ciphertext moved either way). The
page's data crosses the boundary only when its **last** live reference
drops while sealed references remain: it is then *parked* once under a
content-derived name (same content => same nonce AND same plaintext, so
re-parking identical content can never pair one nonce with two
plaintexts), and the first restore that needs it re-materializes it into
the pool. Net: sealed bytes per preemption shrink by the shared fraction,
and K victims sharing a prefix pay for its eviction at most once.

On-demand allocation (``alloc="ondemand"``, implied by sharing — COW
grants cannot be covered by any admission-time worst case): admission
checks only the prompt's immediate page need (minus resident shared
pages) against the free pool, and decode appends are granted at step
time. The pool may be oversubscribed against worst cases; when it runs
dry the engine frees capacity by evict-by-slack *capacity preemption*
(partial ``seal_tail_pages`` of the laxest victim's private tail, else a
whole-slot seal). ``alloc="reserve"`` (the default without sharing) keeps
the PR-3 worst-case reservations, under which appends can never fail.

**Persistent sealed-page store** (``Engine(kv_backend="paged",
prefix_sharing=True, page_store=True)``; see
:mod:`repro.runtime.pagestore`). Plain prefix sharing only helps while
some live mapping or sealed reference keeps a page alive: when the last
reference drops, the parked ciphertext dies with it and the next
recurring prompt re-prefills content the domain already produced and
named. The store is the tier *behind* the content index that retains
content-named ciphertext past the last reference: ``insert_prefill``
misses consult it and restore MAC-verified pages into the pool (mapped
and refcounted like any shared page), aligned full pages publish to it on
seal/park/release, and admission discounts store-resident prefixes the
same way it discounts live ones. When the store beats plain sharing:
recurring-but-not-overlapping traffic — cold-start RAG contexts, system
prompts across bursty sessions, tenant scaffolds with idle gaps — where
requests arrive after their predecessors fully drained, so the live index
is empty however hot the content. Plain sharing already covers the
simultaneous case for free; the store adds host memory (budgeted in
pages: ``store_budget_pages``, LRU or restore-vs-recompute ``cost``
retention) and one MAC-verified unseal per hit, worth paying exactly when
the ``overheads.predict``-priced sealed bytes across the boundary
undercut the prefill compute a hit avoids
(:func:`repro.core.overheads.store_restore_savings` — serve.py and
serve_bench.py print the breakeven line). Entries are namespaced per
sealing-key domain: a fleet tenant's entries fail MAC under any other
domain and are never even offered cross-tenant (the lookup is a clean
miss by key-id namespace).

**Gather vs kernel decode** (``Engine(kv_backend="paged",
kv_decode="gather"|"kernel")``; paged only). The default ``gather`` path
rematerializes each slot's full dense KV view per decode step (``jnp.take``
over the page table) and runs the model's stock ``decode_step`` — simple,
mesh-capable, and the differential reference. ``kernel`` replaces the
gather+SDPA with :mod:`repro.kernels.paged_attention`: a Pallas kernel that
walks the page table directly, streaming only each slot's *valid* pages
from the pool into VMEM with online softmax — per-step HBM traffic drops
from O(max_pages·page_size) rematerialized to O(context) streamed, so the
advantage grows with context length (the gather's rematerialization
dominates from roughly 512 tokens of context upward; at short contexts the
two are within noise). The kernel path additionally unlocks the
*fused-unseal restore*: sealed full pages restore as ciphertext bits and
decrypt in-VMEM against per-page nonces during attention
(``paged_attention_unseal``), so restored KV plaintext never round-trips
HBM. Kernel outputs are numerically close to gather (f32 online softmax),
not bitwise; decoded tokens agree at the bench operating points and the
differential harness pins a tight tolerance. Constraints: dense attention
family only (uniform attn+swiglu blocks), single-device plans (use
``gather`` on meshes).

**Sharded** (:class:`ShardedKVBackend`, implied by ``Engine(mesh=...)``)
is not a third layout — it wraps either of the above when the engine spans
a mesh (:class:`~repro.runtime.plan.ShardedPlan`). When to *shard* the
cache vs replicate it: the slot-dense cache shards cleanly (each data-shard
owns ``max_slots / dp`` whole sequences per
:func:`repro.distributed.sharding.cache_specs` — shard it whenever the
data-axis size divides ``max_slots`` (otherwise the batch dim falls back to
replication and every seal is tagged ``/s0``), which also keeps decode
outputs byte-identical to one device); the paged pool is *shared* by every
sequence, so it replicates for now (its dense recurrent-state leaves still
shard by batch) — prefer slot-dense for mesh serving until per-shard page
pools land (ROADMAP). Sealing under a mesh is per *addressable shard*:
every sealed name gains a ``/s{shard}`` suffix recording which data-shard
the ciphertext left, so concurrent hosts sealing under one prefix occupy
disjoint nonce namespaces and a preemption round-trips byte-identically
(restore reads the shard tag back out of the sealed names — the slot it
lands in may live on a different shard).

Cache pytrees follow the model layout contract: top-level key "pos" is
batch-major [b]; every other leaf is layer-stacked with batch at axis 1
([L, b, ...]). ``insert_slot``/``insert_rows``/``extract_slot`` are the
dense splice primitives both backends build on.
"""

from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sealing import (SealedTensor, SealingKey, seal_tree,
                                unseal_tree)
from repro.runtime import sampling
from repro.runtime.plan import ComputePlan, SingleDevicePlan

Cache = Any
Params = Any


@dataclasses.dataclass
class SlotState:
    """Slot bookkeeping + the ``[slots]``-shaped per-sequence sampling rows
    the jitted decode step consumes (each sequence samples with its own
    temperature/top-k/top-p/PRNG key). Owned by the KV backend — a backend
    maps sequences to whatever physical layout it likes, but every live
    sequence holds exactly one row here. The arrays are host-side numpy
    mirrors; the engine snapshots them into a ``sampling.SamplingState`` per
    step. A released row resets to greedy (temp 0, top_p 1) so stale
    settings can never leak into the next occupant."""
    free: List[int]
    active: dict  # slot -> request id
    temp: np.ndarray    # [slots] f32; <= 0 → greedy
    top_k: np.ndarray   # [slots] i32; 0 → unrestricted
    top_p: np.ndarray   # [slots] f32; >= 1 → unrestricted
    key: np.ndarray     # [slots, 2] u32 per-request base PRNG keys
    rep_pen: np.ndarray   # [slots] f32; 1.0 → no repetition penalty
    presence: np.ndarray  # [slots] f32; 0.0 → no presence penalty
    # [slots, vocab] i32 counts of *generated* tokens, tracked ONLY for
    # slots whose request actually penalizes (greedy/unpenalized rows stay
    # zero, so their churn never invalidates anything). Rebuilt from
    # Request.output after a sealed restore, so seeded requests re-sample
    # identically. Allocated lazily on the first penalized ``set_sampling``
    # (engines that never see a penalty never pay max_slots x vocab ints).
    # ``hist_version`` bumps on the BULK mutations (row rebuild/clear) so
    # the engine's device mirror knows when an incremental update stream
    # was broken and a re-upload is due — per-token ``note_token`` counts
    # are mirrored incrementally instead of re-shipping the whole matrix
    # every decode step.
    vocab: int = 0
    hist: Optional[np.ndarray] = None
    hist_version: int = 0
    # [slots, vocab] f32 additive logit-bias rows, lazily allocated like
    # ``hist`` on the first ``set_sampling(..., logit_bias=...)``. Bias is
    # static per request (no per-token stream), so the device mirror is
    # version-triggered only: ``bias_version`` bumps whenever any row
    # changes and the engine re-uploads the whole matrix then.
    bias: Optional[np.ndarray] = None
    bias_version: int = 0

    @classmethod
    def create(cls, max_slots: int, vocab: int = 0) -> "SlotState":
        return cls(free=list(range(max_slots)), active={},
                   temp=np.zeros(max_slots, np.float32),
                   top_k=np.zeros(max_slots, np.int32),
                   top_p=np.ones(max_slots, np.float32),
                   key=np.zeros((max_slots, 2), np.uint32),
                   rep_pen=np.ones(max_slots, np.float32),
                   presence=np.zeros(max_slots, np.float32),
                   vocab=vocab)

    def acquire(self, request_id: int) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop(0)
        self.active[slot] = request_id
        return slot

    def release(self, slot: int) -> None:
        rid = self.active.pop(slot, None)
        if rid is not None:
            self.free.append(slot)
            self.clear_sampling(slot)

    def set_sampling(self, slot: int, temp: float, top_k: int, top_p: float,
                     key: np.ndarray, rep_pen: float = 1.0,
                     presence: float = 0.0,
                     logit_bias: Optional[Dict[int, float]] = None) -> None:
        self.temp[slot] = temp
        self.top_k[slot] = top_k
        self.top_p[slot] = top_p
        self.key[slot] = key
        self.rep_pen[slot] = rep_pen
        self.presence[slot] = presence
        if self.penalized(slot) and self.hist is None and self.vocab > 0:
            self.hist = np.zeros((len(self.temp), self.vocab), np.int32)
        self._set_bias_row(slot, logit_bias)

    def _set_bias_row(self, slot: int,
                      logit_bias: Optional[Dict[int, float]]) -> None:
        """Densify a request's sparse bias map into its slot row. A request
        without a map keeps (or resets to) the zero row; the matrix itself
        only exists once some request has biased."""
        if logit_bias:
            if self.bias is None:
                self.bias = np.zeros((len(self.temp), self.vocab), np.float32)
            self.bias[slot] = 0.0
            for tok, val in logit_bias.items():
                self.bias[slot, int(tok)] = np.float32(val)
            self.bias_version += 1
        elif self.bias is not None and self.bias[slot].any():
            self.bias[slot] = 0.0
            self.bias_version += 1

    def penalized(self, slot: int) -> bool:
        """Does this slot's request use a non-neutral penalty? Only such
        slots have their token history tracked."""
        return bool(self.rep_pen[slot] != 1.0 or self.presence[slot] != 0.0)

    def clear_sampling(self, slot: int) -> None:
        self.temp[slot] = 0.0
        self.top_k[slot] = 0
        self.top_p[slot] = 1.0
        self.key[slot] = 0
        self.rep_pen[slot] = 1.0
        self.presence[slot] = 0.0
        if self.hist is not None and self.hist[slot].any():
            # bump only when the row actually changes: routine churn of
            # greedy/unpenalized slot-mates (whose rows are already zero)
            # must not force a full [slots, vocab] mirror re-upload.
            self.hist[slot] = 0
            self.hist_version += 1
        if self.bias is not None and self.bias[slot].any():
            self.bias[slot] = 0.0
            self.bias_version += 1

    def note_token(self, slot: int, token: int) -> bool:
        """Count one generated token into the penalty history — only for a
        penalized slot (others keep zero rows so their churn stays free).
        Incremental: does NOT bump hist_version — the caller mirrors the
        increment itself. Returns whether the token was counted."""
        if self.hist is None or not self.penalized(slot):
            return False
        self.hist[slot, int(token)] += 1
        return True

    def set_hist(self, slot: int, tokens: Sequence[int]) -> None:
        """Rebuild a slot's penalty history (sealed restore: the token list
        travels with the request, not with the cache). Unpenalized slots
        keep zero rows; no version bump when the row is unchanged (fresh
        admission into an already-clean row)."""
        if self.hist is None:
            return
        if not self.penalized(slot):
            if self.hist[slot].any():          # defensive: never stale
                self.hist[slot] = 0
                self.hist_version += 1
            return
        if not (len(tokens) or self.hist[slot].any()):
            return
        self.hist[slot] = 0
        for t in tokens:
            self.hist[slot, int(t)] += 1
        self.hist_version += 1

    @property
    def any_sampled(self) -> bool:
        return bool((self.temp > 0).any())

    @property
    def any_top_p(self) -> bool:
        return bool(((self.temp > 0) & (self.top_p < 1.0)).any())

    @property
    def any_rep_pen(self) -> bool:
        return bool(((self.temp > 0) & (self.rep_pen != 1.0)).any())

    @property
    def any_presence(self) -> bool:
        return bool(((self.temp > 0) & (self.presence != 0.0)).any())

    @property
    def any_bias(self) -> bool:
        return self.bias is not None and bool(self.bias.any())

    @property
    def max_top_k(self) -> int:
        return int(self.top_k.max()) if len(self.top_k) else 0

    @property
    def num_active(self) -> int:
        return len(self.active)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (shape padding keeps compiled variants
    bounded by log2, not one per batch/scatter size)."""
    p = 1
    while p < n:
        p *= 2
    return p


def host_upload(x, dtype=None) -> jax.Array:
    """Host->device upload that always *copies* host-owned buffers.

    ``jnp.asarray`` on a numpy array may zero-copy it into the computation
    when its malloc'd address happens to satisfy the runtime's alignment
    bound. Whether that happens varies per allocation, and XLA:CPU kernels
    pick alignment-dependent code paths with different FMA grouping — so
    the same engine scenario can produce last-ulp logit differences from
    run to run, flipping near-tie sampled tokens (observed as bimodal
    outputs in the 8-device parity tests). Copying into a runtime-allocated
    buffer pins every upload to one alignment class, restoring the engine's
    byte-identical-replay contract. The arrays on these paths are small
    (slot tables, token columns, page indices), so the copy is noise next
    to the step itself; weights and KV pools never go through here.
    """
    if isinstance(x, jax.Array):
        return x if dtype is None else jnp.asarray(x, dtype)
    return jnp.array(np.ascontiguousarray(x), dtype)


def _is_pos(path) -> bool:
    return any(getattr(k, "key", None) == "pos" for k in path[:1])


@jax.jit
def insert_slot(batched: Cache, single: Cache, slot: jax.Array) -> Cache:
    """Write a b=1 cache into batch slot ``slot`` of the batched cache."""
    def upd(path, big, small):
        if _is_pos(path):
            return big.at[slot].set(small[0])
        # [L, 1, ...] into [L, B, ...] at axis 1
        start = (jnp.int32(0), slot.astype(jnp.int32)) + (jnp.int32(0),) * (big.ndim - 2)
        return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)
    return jax.tree_util.tree_map_with_path(upd, batched, single)


@partial(jax.jit, donate_argnums=(0,))
def insert_rows(batched: Cache, src: Cache, slots: jax.Array) -> Cache:
    """Scatter the first k rows of a b>=k cache into batch slots ``slots``
    (int32 [k], distinct) in ONE donated call — a batched prefill group
    splices in with a single cache materialization instead of k full-cache
    copies through repeated ``insert_slot``."""
    k = slots.shape[0]
    def upd(path, big, small):
        if _is_pos(path):
            return big.at[slots].set(small[:k])
        # [L, k, ...] rows into [L, B, ...] at axis 1
        return big.at[:, slots].set(small[:, :k].astype(big.dtype))
    return jax.tree_util.tree_map_with_path(upd, batched, src)


@jax.jit
def extract_slot(batched: Cache, slot: jax.Array) -> Cache:
    """Inverse of insert_slot: pull slot ``slot`` out as a b=1 cache."""
    def get(path, big):
        if _is_pos(path):
            return jax.lax.dynamic_slice(big, (slot.astype(jnp.int32),), (1,))
        start = (jnp.int32(0), slot.astype(jnp.int32)) + (jnp.int32(0),) * (big.ndim - 2)
        sizes = (big.shape[0], 1) + big.shape[2:]
        return jax.lax.dynamic_slice(big, start, sizes)
    return jax.tree_util.tree_map_with_path(get, batched)


def cache_bytes(cache: Cache) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


# ---------------------------------------------------------------------------
# backend protocol
# ---------------------------------------------------------------------------

class KVBackend:
    """One live KV store behind the engine. A backend owns

      * the device cache (whatever physical layout),
      * the slot <-> sequence mapping and per-sequence sampling rows
        (:class:`SlotState`),
      * the jitted decode step over its layout, and
      * the seal/restore format a preemption moves across the boundary.

    The engine speaks tokens: every capacity question is asked in "KV
    positions this request may write" (``n_tokens``), and the backend maps
    that onto slots, pages, or whatever it accounts in.
    """

    name: str = "?"
    supports_partial = False   # page-granular (tail) eviction available?
    supports_sharing = False   # content-indexed prefix page sharing on?
    on_demand = False          # step-time page grants (vs admission reserve)

    def __init__(self, model, max_slots: int, max_len: int,
                 plan: Optional[ComputePlan] = None):
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.plan = plan or SingleDevicePlan(model)
        self.slots = SlotState.create(max_slots, model.cfg.vocab_size)

    # -- capacity -----------------------------------------------------------
    @property
    def request_capacity(self) -> int:
        """Most KV positions a single request may occupy."""
        return self.max_len

    def can_admit(self, n_tokens: int) -> bool:
        """Beyond a free slot, is there KV room for ``n_tokens`` positions?"""
        return True

    def can_restore(self, n_tokens: int,
                    n_pages: Optional[int] = None) -> bool:
        """Room to re-admit a sealed-out sequence of ``n_tokens`` positions
        (a free slot is checked separately via ``slots.free``). ``n_pages``
        is the page count the sequence actually held at seal time — the
        unit an on-demand paged pool gates on instead of the worst case."""
        return True

    def page_keys(self, tokens: np.ndarray,
                  written_len: int) -> Optional[List[bytes]]:
        """Content keys for a prompt's prefill pages, or None when the
        backend does no prefix sharing (the accounting hooks below accept
        None and fall back to unshared behavior)."""
        return None

    def resident_pages(self, page_keys: Optional[Sequence[Any]]) -> int:
        """How many of these content keys are resident in the sharing
        index right now (0 without sharing)."""
        return 0

    # persistent sealed-page store tier (paged + sharing backends only);
    # None means no store is attached and every store counter stays 0.
    page_store = None
    store_hits = 0
    store_restored_pages = 0
    store_restored_bytes = 0

    def store_resident_pages(self, page_keys: Optional[Sequence[Any]]
                             ) -> int:
        """How many of these content keys the persistent page store could
        serve beyond the live index (0 without a store)."""
        return 0

    @property
    def free_physical_pages(self) -> int:
        """Free pages an on-demand grant can draw on (page backends only;
        the engine consults this behind the ``on_demand`` flag)."""
        return 0

    def step_page_need(self, slot: int) -> int:
        """Pages the next decode step will take for this slot's append
        (fresh page / copy-on-write); the engine's step-time grant loop
        sums this over the batch in on-demand mode."""
        return 0

    def evictable_tail_pages(self, slot: int) -> int:
        """Tail pages a partial eviction may seal off this slot (page
        backends with ``supports_partial`` only)."""
        return 0

    def admission_check(self, need: int,
                        page_keys: Optional[Sequence[Any]] = None
                        ) -> Tuple[bool, int]:
        """(fits, effective_need): can ``need`` worst-case KV positions ever
        be served, and what does the request *effectively* demand once
        resident shared pages are discounted? Default: no sharing, the
        plain capacity bound."""
        return need <= self.request_capacity, need

    def prompt_budget(self, max_new_tokens: int,
                      buckets: Sequence[int]) -> int:
        """Longest prompt a submit will accept for ``max_new_tokens``,
        accounting for prefill-bucket padding: a short prompt still occupies
        its whole (left-padded) bucket in the cache. Prefix sharing does
        NOT raise this bound — every page of one sequence holds its own
        simultaneous table mapping whether shared or private — it lowers
        the *effective demand* admission charges (see
        :meth:`admission_check`)."""
        cand = self.request_capacity - max_new_tokens + 1  # last token: no KV
        if cand >= buckets[-1]:
            return cand
        fits = [b for b in buckets if b <= cand]
        return fits[-1] if fits else 0

    # -- sequence lifecycle ---------------------------------------------------
    def acquire(self, rid: int, n_tokens: int) -> Optional[int]:
        return self.slots.acquire(rid)

    def release(self, slot: int) -> None:
        self.slots.release(slot)

    # -- device compute -------------------------------------------------------
    def fresh_prefill_cache(self, rows: int) -> Cache:
        """A zeroed ``rows``-sequence dense cache for one prefill call (both
        backends prefill dense; the splice into backend storage differs)."""
        return self.model.init_cache(rows, self.max_len)

    def insert_prefill(self, prefilled: Cache, slots: List[int],
                       written_len: int,
                       page_keys: Optional[List[Any]] = None) -> None:
        """Splice a prefilled dense group into backend storage.
        ``page_keys`` (sharing backends) carries one entry per slot: the
        prompt's content keys, or None for a request that opted out."""
        raise NotImplementedError

    def drain_events(self) -> List[Tuple[str, int, int]]:
        """(kind, nbytes, n_tensors) boundary traffic generated outside an
        explicit seal/restore call — shared-page parking and
        re-materialization on the paged backend. The engine drains this
        into TrustDomain accounting; default backends generate none."""
        return []

    def discard_sealed(self, key: SealingKey, sealed: Dict[str, SealedTensor],
                       prefix: str, suffix: str = "") -> None:
        """A sealed dict is spent — restored in full, or dropped unrestored
        (deadline abort): release whatever references it holds (shared-page
        sealed refcounts on the sharing backend). Default: nothing."""

    def decode(self, params: Params, tokens: np.ndarray,
               state: Optional[sampling.SamplingState], kmax: int,
               write_slots: Sequence[int]) -> np.ndarray:
        """One batched decode+sample step over all ``max_slots`` rows.
        ``write_slots`` are the slots genuinely appending a KV position this
        step (active, not paused) — a backend may route other rows' writes
        to a scratch location. Returns the sampled token per row."""
        raise NotImplementedError

    def cache_nbytes(self) -> int:
        raise NotImplementedError

    # -- sealing --------------------------------------------------------------
    def seal(self, key: SealingKey, slot: int, prefix: str,
             suffix: str = "") -> Dict[str, SealedTensor]:
        """Encrypt slot ``slot``'s KV for eviction across the trust boundary.
        ``prefix`` must be unique per (stream, seal epoch) — it derives the
        nonces; ``suffix`` lands after the leaf path in every name (the
        sharded wrapper's per-shard ``/s{shard}`` tag). Does NOT release the
        slot."""
        raise NotImplementedError

    def restore(self, key: SealingKey, sealed: Dict[str, SealedTensor],
                slot: int, prefix: str, n_tokens: int,
                suffix: str = "") -> None:
        """Inverse of :meth:`seal` into freshly-acquired slot ``slot``."""
        raise NotImplementedError


class SlotDenseBackend(KVBackend):
    """The dense ``[L, max_slots, max_len, ...]`` layout (see module
    docstring for when it wins). Sealing moves the victim's whole
    ``max_len`` extent regardless of how many positions hold live tokens."""

    name = "slot"

    def __init__(self, model, max_slots: int, max_len: int,
                 plan: Optional[ComputePlan] = None):
        super().__init__(model, max_slots, max_len, plan)
        self.cache = self.plan.place_dense_cache(
            model.init_cache(max_slots, max_len))

        def _decode(params, tokens, cache, state, kmax):
            logits, cache = model.decode_step(params, tokens, cache)
            if state is None:     # all-greedy step: no sampling state at all
                return sampling.greedy(logits), cache
            return sampling.sample(logits, state, kmax=kmax), cache

        self._decode_fn = self.plan.compile_decode(
            _decode, donate_argnums=(2,), static_argnums=(4,))

    def insert_prefill(self, prefilled: Cache, slots: List[int],
                       written_len: int, page_keys=None) -> None:
        # one donated scatter for the whole group (not k full-cache copies)
        self.cache = insert_rows(self.cache, prefilled,
                                 host_upload(slots, jnp.int32))

    def decode(self, params, tokens, state, kmax, write_slots) -> np.ndarray:
        next_tokens, self.cache = self._decode_fn(
            params, host_upload(tokens[:, None]), self.cache, state, kmax)
        return np.asarray(next_tokens)

    def cache_nbytes(self) -> int:
        return cache_bytes(self.cache)

    def seal(self, key, slot, prefix, suffix="") -> Dict[str, SealedTensor]:
        single = extract_slot(self.cache, jnp.int32(slot))
        return seal_tree(key, single, prefix=prefix, suffix=suffix)

    def restore(self, key, sealed, slot, prefix, n_tokens,
                suffix="") -> None:
        single_like = self.model.abstract_cache(1, self.max_len)
        single = unseal_tree(key, sealed, single_like, prefix=prefix,
                             suffix=suffix)
        self.cache = insert_slot(self.cache, single, jnp.int32(slot))


# sealed-name anatomy for the shard tag and partial-eviction meta blobs
_SUFFIX_RE = re.compile(r"/s(\d+)$")
_PAGEMETA_RE = re.compile(r"^(?P<prefix>.*)/pagemeta(?P<suffix>/s\d+)?$")


def tail_blob_names(sealed: Dict[str, SealedTensor]
                    ) -> List[Tuple[str, str]]:
    """(prefix, suffix) of every partial-eviction tail blob riding in a
    sealed dict (a paused victim that was whole-sealed carries its earlier
    tail under its own epoch prefix — and, under a mesh, shard suffix)."""
    out = []
    for name in sealed:
        m = _PAGEMETA_RE.match(name)
        if m:
            out.append((m.group("prefix"), m.group("suffix") or ""))
    return out


class ShardedKVBackend:
    """Mesh wrapper around either layout: compute/placement concerns already
    live in the backend's :class:`~repro.runtime.plan.ShardedPlan`; what the
    wrapper owns is keeping *sealing* correct per addressable shard. Every
    seal gains a ``/s{shard}`` name suffix recording which data-shard the
    slot's row was read from (concurrent hosts sealing under one prefix stay
    in disjoint nonce namespaces), and restore recovers the tag from the
    sealed names themselves — so a preemption round-trips byte-identically
    even when the sequence re-lands on a different shard. Everything else
    delegates to the wrapped backend."""

    def __init__(self, inner: KVBackend):
        self.inner = inner
        if not inner.plan.is_sharded:
            raise ValueError("ShardedKVBackend wants a backend built on a "
                             "ShardedPlan")

    def __getattr__(self, attr):
        return getattr(self.inner, attr)

    def _suffix_for(self, slot: int) -> str:
        shard = self.inner.plan.shard_of_slot(slot, self.inner.max_slots)
        return f"/s{shard}"

    @staticmethod
    def _detect_suffix(sealed: Dict[str, SealedTensor], prefix: str) -> str:
        for name in sealed:
            if name.startswith(prefix):
                m = _SUFFIX_RE.search(name)
                if m:
                    return m.group(0)
        return ""

    def seal(self, key, slot, prefix, suffix=None, detach=False):
        kw = {"detach": detach} if detach else {}
        return self.inner.seal(key, slot, prefix,
                               suffix=suffix or self._suffix_for(slot), **kw)

    def restore(self, key, sealed, slot, prefix, n_tokens, suffix=None):
        if suffix is None:
            suffix = self._detect_suffix(sealed, prefix)
        return self.inner.restore(key, sealed, slot, prefix, n_tokens,
                                  suffix=suffix)

    def seal_tail_pages(self, key, slot, prefix, n_pages, suffix=None):
        return self.inner.seal_tail_pages(
            key, slot, prefix, n_pages,
            suffix=suffix or self._suffix_for(slot))

    def restore_tail_pages(self, key, sealed, slot, prefix, reserve=True,
                           suffix=None):
        if suffix is None:
            suffix = self._detect_suffix(sealed, prefix)
        return self.inner.restore_tail_pages(key, sealed, slot, prefix,
                                             reserve=reserve, suffix=suffix)

    def discard_sealed(self, key, sealed, prefix, suffix=None):
        if suffix is None:
            suffix = self._detect_suffix(sealed, prefix)
        return self.inner.discard_sealed(key, sealed, prefix, suffix=suffix)


def make_backend(kind: str, model, *, max_slots: int, max_len: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 plan: Optional[ComputePlan] = None,
                 prefix_sharing: bool = False,
                 alloc: Optional[str] = None,
                 decode: str = "gather",
                 page_store: Any = None,
                 store_budget_pages: Optional[int] = None) -> KVBackend:
    """Factory behind ``Engine(kv_backend=...)``. With a sharded ``plan``
    the chosen layout is built on the mesh and wrapped for per-shard
    sealing. ``prefix_sharing``/``alloc``/``decode``/``page_store`` are
    paged-only (see the module docstring's prefix-sharing, store-tier, and
    decode-mode sections)."""
    if kind == "slot":
        if (prefix_sharing or alloc is not None or decode != "gather"
                or page_store or store_budget_pages is not None):
            raise ValueError("prefix_sharing / kv_alloc / kv_decode / "
                             "page_store need kv_backend='paged' (the dense "
                             "slot layout has no pages to share, grant, "
                             "table-walk, or store)")
        kv: KVBackend = SlotDenseBackend(model, max_slots, max_len, plan)
    elif kind == "paged":
        from repro.runtime.paged import PagedKVBackend
        kv = PagedKVBackend(model, max_slots, max_len,
                            page_size=page_size, num_pages=num_pages,
                            plan=plan, prefix_sharing=prefix_sharing,
                            alloc=alloc, decode=decode,
                            page_store=page_store,
                            store_budget_pages=store_budget_pages)
    else:
        raise ValueError(
            f"unknown kv backend {kind!r} (want 'slot' or 'paged')")
    if kv.plan.is_sharded:
        return ShardedKVBackend(kv)   # type: ignore[return-value]
    return kv
