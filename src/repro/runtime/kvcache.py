"""Slot-based KV cache management for continuous batching.

Hardware-adaptation note (DESIGN.md): vLLM's paged KV cache is
GPU-idiomatic — fine-grained gather over a page table suits GPU SMs. On TPU,
serving stacks (JetStream-style) use *slot-based* dense caches: a fixed
[max_slots, max_len, ...] buffer, one slot per in-flight sequence, because
the MXU/VPU want contiguous reads and XLA wants static shapes. We therefore
manage slots, not pages; the same role (bounded KV memory, admission
control), the TPU-native layout.

``insert_slot`` splices a freshly-prefilled single-sequence cache into the
batched decode cache. Cache pytrees follow the model layout contract:
top-level key "pos" is batch-major [b]; every other leaf is layer-stacked
with batch at axis 1 ([L, b, ...]).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Cache = Any


@dataclasses.dataclass
class SlotState:
    """Slot bookkeeping + the ``[slots]``-shaped per-request sampling arrays
    the jitted decode step consumes (engine v3: each slot samples with its
    own temperature/top-k/PRNG key). The arrays are host-side numpy mirrors;
    the engine snapshots them into a ``sampling.SamplingState`` per step.
    A released slot resets to greedy (temp 0) so stale settings can never
    leak into the next occupant."""
    free: List[int]
    active: dict  # slot -> request id
    temp: np.ndarray    # [slots] f32; <= 0 → greedy
    top_k: np.ndarray   # [slots] i32; 0 → unrestricted
    key: np.ndarray     # [slots, 2] u32 per-request base PRNG keys

    @classmethod
    def create(cls, max_slots: int) -> "SlotState":
        return cls(free=list(range(max_slots)), active={},
                   temp=np.zeros(max_slots, np.float32),
                   top_k=np.zeros(max_slots, np.int32),
                   key=np.zeros((max_slots, 2), np.uint32))

    def acquire(self, request_id: int) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop(0)
        self.active[slot] = request_id
        return slot

    def release(self, slot: int) -> None:
        rid = self.active.pop(slot, None)
        if rid is not None:
            self.free.append(slot)
            self.clear_sampling(slot)

    def set_sampling(self, slot: int, temp: float, top_k: int,
                     key: np.ndarray) -> None:
        self.temp[slot] = temp
        self.top_k[slot] = top_k
        self.key[slot] = key

    def clear_sampling(self, slot: int) -> None:
        self.temp[slot] = 0.0
        self.top_k[slot] = 0
        self.key[slot] = 0

    @property
    def any_sampled(self) -> bool:
        return bool((self.temp > 0).any())

    @property
    def max_top_k(self) -> int:
        return int(self.top_k.max()) if len(self.top_k) else 0

    @property
    def num_active(self) -> int:
        return len(self.active)


def _is_pos(path) -> bool:
    return any(getattr(k, "key", None) == "pos" for k in path[:1])


@jax.jit
def insert_slot(batched: Cache, single: Cache, slot: jax.Array) -> Cache:
    """Write a b=1 cache into batch slot ``slot`` of the batched cache."""
    def upd(path, big, small):
        if _is_pos(path):
            return big.at[slot].set(small[0])
        # [L, 1, ...] into [L, B, ...] at axis 1
        start = (jnp.int32(0), slot.astype(jnp.int32)) + (jnp.int32(0),) * (big.ndim - 2)
        return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)
    return jax.tree_util.tree_map_with_path(upd, batched, single)


@partial(jax.jit, donate_argnums=(0,))
def insert_rows(batched: Cache, src: Cache, slots: jax.Array) -> Cache:
    """Scatter the first k rows of a b>=k cache into batch slots ``slots``
    (int32 [k], distinct) in ONE donated call — a batched prefill group
    splices in with a single cache materialization instead of k full-cache
    copies through repeated ``insert_slot``."""
    k = slots.shape[0]
    def upd(path, big, small):
        if _is_pos(path):
            return big.at[slots].set(small[:k])
        # [L, k, ...] rows into [L, B, ...] at axis 1
        return big.at[:, slots].set(small[:, :k].astype(big.dtype))
    return jax.tree_util.tree_map_with_path(upd, batched, src)


@jax.jit
def extract_slot(batched: Cache, slot: jax.Array) -> Cache:
    """Inverse of insert_slot: pull slot ``slot`` out as a b=1 cache."""
    def get(path, big):
        if _is_pos(path):
            return jax.lax.dynamic_slice(big, (slot.astype(jnp.int32),), (1,))
        start = (jnp.int32(0), slot.astype(jnp.int32)) + (jnp.int32(0),) * (big.ndim - 2)
        sizes = (big.shape[0], 1) + big.shape[2:]
        return jax.lax.dynamic_slice(big, start, sizes)
    return jax.tree_util.tree_map_with_path(get, batched)


def cache_bytes(cache: Cache) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
