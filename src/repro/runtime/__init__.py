"""Serving runtime: request-object API, engine, compute plans, KV backends,
scheduler, sampling. The KV layout is pluggable —
``Engine(kv_backend="slot"|"paged")`` picks between the dense slot cache and
the paged pool (see :mod:`repro.runtime.kvcache` for the selection guide) —
and so is the device footprint: ``Engine(mesh="dp=4")`` spans the engine
across a jax mesh behind a :class:`~repro.runtime.plan.ComputePlan`
(byte-identical outputs on dp meshes, measured collective traffic in
``ChannelStats``).

Typical use::

    from repro.runtime import Engine, GenerationRequest, SamplingParams

    req = engine.submit(GenerationRequest(
        prompt=tokens, max_new_tokens=64,
        params=SamplingParams(temperature=0.8, top_k=40, seed=7)))
    engine.run()
    out = req.result()          # RequestOutput
"""

from repro.runtime.api import (FINISH_ABORTED, FINISH_DROPPED, FINISH_LENGTH,
                               FINISH_REJECTED, FINISH_STOP, FramePolicy,
                               GenerationRequest, RequestOutput,
                               SamplingParams)
from repro.runtime.engine import Engine
from repro.runtime.kvcache import (KVBackend, ShardedKVBackend,
                                   SlotDenseBackend, SlotState, make_backend)
from repro.runtime.plan import (ComputePlan, PrefillOnlyPlan, ShardedPlan,
                                SingleDevicePlan, parse_mesh)
from repro.runtime.scheduler import (Request, Scheduler, ServeStats,
                                     stats_from_requests)

__all__ = [
    "FINISH_ABORTED", "FINISH_DROPPED", "FINISH_LENGTH", "FINISH_REJECTED",
    "FINISH_STOP",
    "FramePolicy", "GenerationRequest", "RequestOutput", "SamplingParams",
    "Engine", "KVBackend", "ShardedKVBackend", "SlotDenseBackend",
    "SlotState", "make_backend",
    "ComputePlan", "PrefillOnlyPlan", "ShardedPlan", "SingleDevicePlan",
    "parse_mesh",
    "Request", "Scheduler", "ServeStats", "stats_from_requests",
]
