"""Serving runtime: the request-object API, engine, scheduler, sampling.

Typical use::

    from repro.runtime import Engine, GenerationRequest, SamplingParams

    req = engine.submit(GenerationRequest(
        prompt=tokens, max_new_tokens=64,
        params=SamplingParams(temperature=0.8, top_k=40, seed=7)))
    engine.run()
    out = req.result()          # RequestOutput
"""

from repro.runtime.api import (FINISH_DROPPED, FINISH_LENGTH, FINISH_STOP,
                               FramePolicy, GenerationRequest, RequestOutput,
                               SamplingParams)
from repro.runtime.engine import Engine
from repro.runtime.scheduler import (Request, Scheduler, ServeStats,
                                     stats_from_requests)

__all__ = [
    "FINISH_DROPPED", "FINISH_LENGTH", "FINISH_STOP",
    "FramePolicy", "GenerationRequest", "RequestOutput", "SamplingParams",
    "Engine", "Request", "Scheduler", "ServeStats", "stats_from_requests",
]
