"""Paged KV backend: a page-pool + page-table layout behind ``KVBackend``.

Layout. Every cache leaf with a sequence-length axis (attention ``k``/``v``,
MLA ``ckv``/``krope``) is stored as a static pool
``[L, num_pages + 1, page_size, ...]`` — physical page 0 is a reserved
*null page* (scratch for rows that are not appending) and pages
``1..num_pages`` are allocatable. An ``[max_slots, max_pages]`` int32 page
table maps each sequence's logical pages to physical ones (entry 0 =
unmapped). Leaves without a length axis (SSM conv/state, RWKV wkv rows)
stay slot-dense ``[L, max_slots, ...]``; per-sequence positions live
host-side and are threaded into each step.

Decode. One ``jnp.take`` over the page table gathers each sequence's pages
into exactly the dense ``[L, max_slots, max_len, ...]`` view the model's
``decode_step`` already expects — static shapes end to end (TPU/XLA-safe),
no model changes. Positions at or beyond a sequence's live length are
masked inside attention (``kv_valid_len``), so whatever the gather pulls
out of unmapped/null pages never reaches a logit, and outputs are
bit-identical to the slot-dense backend. Only the single appended position
is scattered back per step (``pool.at[:, write_phys, write_off]``); rows
that are not appending route their write to the null page.

Allocation. Two modes (``alloc=``):

  * ``"reserve"`` (default): admission reserves ``ceil(need / page_size)``
    pages — the request's own worst case — and physical pages are mapped
    lazily as positions are written, so reservations make append failure
    impossible (allocated <= reserved <= num_pages).
  * ``"ondemand"`` (vLLM-style; implied by ``prefix_sharing``): admission
    checks only the *prompt's* immediate page need against the free pool
    and decode-time appends are granted at step time. The pool may be
    oversubscribed against worst cases; when it runs dry mid-step the
    engine frees capacity by *capacity preemption* — evict-by-slack
    through the existing ``seal_tail_pages`` / whole-seal machinery.

Prefix sharing (``prefix_sharing=True``). A content index maps the
*cumulative* hash of the token ids up to each aligned page boundary to a
shared physical page with a per-page refcount. ``insert_prefill`` maps an
index hit instead of allocating+writing a copy (prefill KV rows are
bitwise row-count-invariant, so the resident page is exactly what this
request would have computed); the refcount equals the number of live table
mappings. A write into an indexed page (only ever the *tail* page a slot
appends into) triggers copy-on-write when other mappings remain, or simply
unregisters the page when the writer is its sole user.

Sealing. Preemption seals *per page*: each private page of each paged leaf
becomes its own ciphertext+MAC with a nonce derived from
``{prefix}{leaf}/p{ordinal}`` — sealed bytes scale with tokens used, not
capacity reserved. Shared (content-indexed) pages are refcount-aware: a
victim's sealed meta records the page's content key (and refcount) instead
of moving ciphertext, restore *re-links* the resident page, and the page's
data only crosses the boundary when its **last** reference drops — sealed
once, under its content-derived name (same content => same nonce => the
identical ciphertext, so repeated parking can never pair one nonce with two
plaintexts). ``seal_tail_pages``/``restore_tail_pages`` support partial
eviction of the (always private) tail.

Page store (``page_store=``). The persistent tier behind the content index
(:mod:`repro.runtime.pagestore`): parking's content-named ciphertext, but
retained past the last live/sealed reference. Aligned FULL pages publish to
the store whenever their data is already sealed (parking, last-sealed-ref
discard) or when their last mapping drops unsealed (release, sole-user
divergence — one fresh seal, under the same canonical name parking uses,
so the nonce-safety argument is unchanged and re-publishing resident
content is a membership no-op). ``insert_prefill`` index misses and
``restore``'s neither-resident-nor-parked case consult the store:
a hit MAC-verifies and decrypts the blobs *before* any page or refcount
moves, then maps the restored page exactly like a shared one. Store
residency also discounts ``admission_check``'s effective need (the live
index's discount, extended one tier down); entries are namespaced per
sealing-key domain, so another tenant's lookups are clean misses.

Decode modes (``decode=``). ``"gather"`` (default) is the dense-view path
above — bit-identical to slot-dense, any model family, any plan.
``"kernel"`` replaces the gather with ``kernels/paged_attention.py``: a
Pallas kernel walks the page table directly and streams KV pages into
VMEM, so per-step KV traffic is O(tokens attended), not O(max_len) dense
rematerialization. Kernel mode additionally keeps eligible restored pages
*ciphertext-resident*: a whole-slot restore MAC-checks each full private
page (``sealing.verify_mac``) and places the ciphertext bits straight into
the pool with a per-page crypt sidecar (nonce + live flag); the decode
kernel regenerates the ChaCha20 keystream in-VMEM and decrypts on the way
into the attention dot, so the restore never round-trips plaintext KV
through HBM. Any host-side consumer of a ciphertext page (seal, park,
page copy, partial eviction) first calls ``_materialize_page`` — pages a
slot appends into are always plaintext (appends target the partial tail,
which restores through the host path). Kernel mode requires a dense
attention family, a non-sharded plan, and (for the ciphertext-resident
part) a pool dtype/page size the in-kernel XOR supports; ineligible
configs still get the kernel attention path with host-decrypt restores.
"""

from __future__ import annotations

import hashlib
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sealing import (IntegrityError, SealedTensor, SealingKey,
                                ciphertext_page_bytes, nonce_words_for,
                                seal_tensor, shared_page_name, unseal_tensor,
                                verify_mac)
from repro.kernels.ops import INTERPRET
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_unseal,
                                           supports_fused_unseal)
from repro.kernels.ref import chacha20_keystream_bytes_ref
from repro.runtime import sampling
from repro.runtime.kvcache import KVBackend, host_upload, next_pow2
from repro.runtime.plan import ComputePlan

Cache = Any
Params = Any

# cache-leaf names that carry a [.., max_len, ..] sequence axis at dim 2
_LENGTH_LEAVES = ("k", "v", "ckv", "krope")


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def _leaf_key(path) -> Optional[str]:
    return getattr(path[-1], "key", None) if path else None


def prefix_page_keys(tokens: np.ndarray, page_size: int, written_len: int,
                     salt: bytes = b"") -> List[bytes]:
    """Content keys for the pages covering ``tokens[:written_len]``: key j is
    the running hash of every token id up to the end of page j (KV at a
    position depends on *all* earlier tokens, so only a true prefix match
    may share), truncated chains for the final partial page. 16-byte sha256
    prefixes — collisions are negligible against 2^64 pages."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32)[:written_len])
    h = hashlib.sha256(salt)
    keys = []
    for j in range(-(-int(written_len) // page_size)):
        h.update(toks[j * page_size:(j + 1) * page_size].tobytes())
        keys.append(h.digest()[:16])
    return keys


@partial(jax.jit, donate_argnums=(0,))
def _set_pages(pool_leaf, idx, pages):
    """Scatter restored pages into a donated pool leaf in place — restore
    cost stays O(pages moved), not O(pool) rebuilt per leaf."""
    return pool_leaf.at[:, idx].set(pages.astype(pool_leaf.dtype))


@partial(jax.jit, donate_argnums=(0,))
def _set_row(dense_leaf, slot, row):
    start = (jnp.int32(0), slot.astype(jnp.int32)) + \
        (jnp.int32(0),) * (dense_leaf.ndim - 2)
    return jax.lax.dynamic_update_slice(
        dense_leaf, row.astype(dense_leaf.dtype), start)


class PagedKVBackend(KVBackend):
    """See module docstring; constructed via ``Engine(kv_backend="paged")``
    or ``kvcache.make_backend("paged", ...)``."""

    name = "paged"
    supports_partial = True

    def __init__(self, model, max_slots: int, max_len: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 plan: Optional[ComputePlan] = None,
                 prefix_sharing: bool = False, alloc: Optional[str] = None,
                 decode: str = "gather", page_store: Any = None,
                 store_budget_pages: Optional[int] = None):
        super().__init__(model, max_slots, max_len, plan)
        if decode not in ("gather", "kernel"):
            raise ValueError(f"decode must be 'gather' or 'kernel', "
                             f"got {decode!r}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if max_len % page_size != 0:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"page_size={page_size}")
        if alloc is None:
            alloc = "ondemand" if prefix_sharing else "reserve"
        if alloc not in ("reserve", "ondemand"):
            raise ValueError(f"alloc must be 'reserve' or 'ondemand', "
                             f"got {alloc!r}")
        if prefix_sharing and alloc != "ondemand":
            # COW converts a shared mapping into a private page at step
            # time, which no admission-time worst case can cover — sharing
            # therefore runs on step-time grants.
            raise ValueError("prefix_sharing requires alloc='ondemand'")
        self.on_demand = alloc == "ondemand"
        self.prefix_sharing = prefix_sharing
        self.supports_sharing = prefix_sharing
        # persistent sealed-page store (the prefix-cache tier). Accepts a
        # ready SealedPageStore (possibly shared between backends), True, or
        # a policy name; store_budget_pages alone implies an LRU store.
        if page_store is False:
            page_store = None
        if page_store is None and store_budget_pages is not None:
            page_store = "lru"
        if page_store is not None and not prefix_sharing:
            raise ValueError(
                "page_store requires prefix_sharing=True (the store is the "
                "tier behind the content index — without page keys there is "
                "nothing to address it by)")
        if page_store is True:
            page_store = "lru"
        if isinstance(page_store, str):
            from repro.runtime.pagestore import SealedPageStore
            page_store = SealedPageStore(budget_pages=store_budget_pages,
                                         policy=page_store)
        elif page_store is not None and store_budget_pages is not None:
            raise ValueError(
                "store_budget_pages configures a store the backend "
                "constructs; a ready SealedPageStore carries its own budget")
        self.page_store = page_store
        self.store_key: Optional[SealingKey] = None
        self.store_hits = 0             # pages served from the store
        self.store_restored_pages = 0
        self.store_restored_bytes = 0
        self.page_size = page_size
        self.max_pages = max_len // page_size
        if num_pages is None:
            num_pages = max_slots * self.max_pages   # dense-equivalent pool
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        # a pool smaller than max_pages is legal: request_capacity shrinks
        # to num_pages * page_size and submit rejects what cannot ever fit.
        self.num_pages = num_pages
        self._key_salt = f"{model.cfg.name}|{page_size}|{max_len}".encode()

        # classify leaves once; paged leaves move to pool layout
        dense = model.init_cache(max_slots, max_len)
        dense.pop("pos")
        self._paged_paths = set()

        def build(path, leaf):
            if (_leaf_key(path) in _LENGTH_LEAVES and leaf.ndim >= 3
                    and leaf.shape[2] == max_len):
                self._paged_paths.add(_keystr(path))
                shape = (leaf.shape[0], num_pages + 1, page_size) + leaf.shape[3:]
                return jnp.zeros(shape, leaf.dtype)
            return leaf
        self.blocks = jax.tree_util.tree_map_with_path(build, dense)
        if not self._paged_paths:
            raise ValueError(
                f"model {model.cfg.name} has no sequence-length KV leaves to "
                f"page; use kv_backend='slot' for pure-state families")
        # mesh placement: pool leaves replicate (pages are shared), dense
        # recurrent-state leaves shard their batch dim (see kvcache docs)
        self.blocks = self.plan.place_paged_cache(self.blocks,
                                                  self._paged_paths)

        # host-side sequence state
        self.pos = np.zeros(max_slots, np.int32)           # live KV positions
        self.table = np.zeros((max_slots, self.max_pages), np.int32)
        self._free_pages: List[int] = list(range(1, num_pages + 1))
        self._alloc = np.zeros(max_slots, np.int32)        # pages mapped
        self._reserved = np.zeros(max_slots, np.int32)     # pages promised
        self._reserve_free = num_pages
        # on-demand admission promises: pages pledged to an admitted-but-
        # not-yet-prefilled slot so a batched admission group cannot
        # overcommit the free list between acquire() and insert_prefill().
        self._promised = np.zeros(max_slots, np.int32)
        self._promised_total = 0

        # prefix-sharing state. _page_ref counts live table mappings per
        # physical page (private pages hold exactly 1); _index/_page_key is
        # the content index both ways; _sealed_refs counts sealed-out
        # requests whose meta references a content key; _parked holds the
        # content-named ciphertext of pages whose last live reference
        # dropped while sealed references remain.
        self._page_ref = np.zeros(num_pages + 1, np.int32)
        self._index: Dict[bytes, int] = {}
        self._page_key: Dict[int, bytes] = {}
        self._sealed_refs: Dict[bytes, int] = {}
        self._parked: Dict[bytes, Dict[str, SealedTensor]] = {}
        # content keys whose page only part-fills (registered past
        # written_len): never published to the store — store entries are
        # aligned FULL pages only. Partialness is a content property (the
        # chain hash covers the same truncated token run), so the flag is
        # stable across engines sharing a store.
        self._partial_keys: set = set()
        self._seal_key_cache: Optional[SealingKey] = None
        self._events: List[Tuple[str, int, int]] = []  # (kind, nbytes, n)
        self.shared_page_maps = 0     # mappings served by an index hit
        self.cow_copies = 0           # tail-page copy-on-write events
        self.pages_written = 0        # physical pages taken + written

        paged = self._paged_paths

        def _decode(params, tokens, blocks, table, pos, write_phys,
                    write_off, state, kmax):
            def gather(path, leaf):
                if _keystr(path) not in paged:
                    return leaf
                v = jnp.take(leaf, table, axis=1)  # [L, b, max_pages, ps, ..]
                return v.reshape(leaf.shape[0], table.shape[0], max_len,
                                 *leaf.shape[3:])
            view = jax.tree_util.tree_map_with_path(gather, blocks)
            cache = dict(view)
            cache["pos"] = pos
            logits, new_cache = model.decode_step(params, tokens, cache)
            if state is None:
                toks = sampling.greedy(logits)
            else:
                toks = sampling.sample(logits, state, kmax=kmax)
            new_cache.pop("pos")

            def scatter(path, pool, new_leaf):
                if _keystr(path) not in paged:
                    # slot-dense (recurrent-state) leaf: advance ONLY the
                    # rows that actually stepped — a paused (partially
                    # evicted) row's state must stay frozen exactly where
                    # its sealed tail left it. write_phys > 0 is precisely
                    # the stepped-rows mask.
                    mask = (write_phys > 0).reshape(
                        1, -1, *([1] * (new_leaf.ndim - 2)))
                    return jnp.where(mask, new_leaf.astype(pool.dtype), pool)
                # pull the one appended position per sequence out of the
                # dense view and write it to (write_phys, write_off)
                idx = pos.reshape(1, -1, 1, *([1] * (new_leaf.ndim - 3)))
                idx = jnp.broadcast_to(
                    idx, new_leaf.shape[:2] + (1,) + new_leaf.shape[3:])
                written = jnp.take_along_axis(new_leaf, idx, axis=2)[:, :, 0]
                return pool.at[:, write_phys, write_off].set(
                    written.astype(pool.dtype))
            new_blocks = jax.tree_util.tree_map_with_path(
                scatter, blocks, new_cache)
            return toks, new_blocks

        # fused-unseal (ciphertext-resident restore) state. Present in both
        # modes so accounting/stats code stays unconditional: _cipher_pages
        # is the set of physical pages whose pool bits are ciphertext,
        # _crypt maps each paged leaf to its [num_pages+1, 4] uint32 sidecar
        # (nonce words 0-2, live flag word 3), _crypt_key is the key every
        # resident ciphertext page was sealed under.
        self.decode_mode = decode
        self._cipher_pages: set = set()
        self._crypt: Dict[str, np.ndarray] = {}
        self._crypt_key: Optional[SealingKey] = None
        self.supports_fused = False
        self._fused_bpp = 0
        self.fused_restore_pages = 0
        self.fused_restore_bytes = 0

        if decode == "kernel":
            self._init_kernel_decode(model)
        else:
            self._decode_fn = self.plan.compile_decode(
                _decode, donate_argnums=(2,), static_argnums=(8,))

        def _splice(blocks, prefilled, page_rows, page_ord, phys,
                    dense_rows, dense_slots):
            def upd(path, pool, src):
                if _keystr(path) not in paged:
                    return pool.at[:, dense_slots].set(
                        src[:, dense_rows].astype(pool.dtype))
                pages = src.reshape(src.shape[0], src.shape[1],
                                    self.max_pages, page_size, *src.shape[3:])
                picked = pages[:, page_rows, page_ord]   # [L, n, ps, ...]
                return pool.at[:, phys].set(picked.astype(pool.dtype))
            return jax.tree_util.tree_map_with_path(upd, blocks, prefilled)

        self._splice_fn = self.plan.compile(_splice, donate_argnums=(0,))

        def _copy_page(blocks, src, dst):
            def upd(path, pool):
                if _keystr(path) not in paged:
                    return pool
                return pool.at[:, dst].set(pool[:, src])
            return jax.tree_util.tree_map_with_path(upd, blocks)

        self._copy_page_fn = self.plan.compile(_copy_page,
                                               donate_argnums=(0,))

    # -- kernel decode mode ---------------------------------------------------
    def _init_kernel_decode(self, model) -> None:
        """Build the table-walking Pallas decode path (decode='kernel').

        The closure mirrors the dense family's ``decode_step`` math exactly
        (rmsnorm -> _qkv with RoPE -> attention -> wo -> rmsnorm -> swiglu,
        layer scan with the pool slices as carry) but replaces
        gather + sdpa with ``kernels/paged_attention.py`` reading the page
        table directly; when ciphertext-resident pages exist the fused
        variant decrypts them in-kernel against the crypt sidecars.
        """
        from repro.models import layers as model_layers
        from repro.models.transformer import _attn_cfg
        if self.plan.is_sharded:
            raise ValueError(
                "decode='kernel' requires a single-device plan (the paged-"
                "attention kernel reads the local pool; use decode='gather' "
                "on meshes)")
        impl = getattr(model, "_impl", model)   # Model facade -> DecoderLM
        blocks_desc = getattr(impl, "blocks", None)
        if (not blocks_desc or len(blocks_desc) != 1
                or blocks_desc[0][2] != [("attn", "swiglu")]):
            raise ValueError(
                f"decode='kernel' supports the dense attention family only "
                f"(one uniform attn+swiglu block); {model.cfg.name} has "
                f"{blocks_desc!r} — use decode='gather'")
        block_name = blocks_desc[0][0]
        self._k_path = next(p for p in self._paged_paths
                            if p.endswith("['k']"))
        self._v_path = next(p for p in self._paged_paths
                            if p.endswith("['v']"))

        # per-leaf page geometry + fused-unseal eligibility: pages must
        # cover whole ChaCha20 blocks and bitcast to uint words in-kernel,
        # and every leaf must share one blocks-per-page (k and v do).
        shapes: Dict[str, Tuple[tuple, Any]] = {}

        def grab(path, leaf):
            if _keystr(path) in self._paged_paths:
                shapes[_keystr(path)] = (leaf.shape, leaf.dtype)
            return leaf
        jax.tree_util.tree_map_with_path(grab, self.blocks)
        self._page_shape = {p: (s[0], self.page_size) + tuple(s[3:])
                            for p, (s, _) in shapes.items()}
        self._page_dtype = {p: d for p, (_, d) in shapes.items()}
        page_bytes = {p: int(np.prod(s[2:])) * np.dtype(d).itemsize
                      for p, (s, d) in shapes.items()}
        self.supports_fused = (
            len(set(page_bytes.values())) == 1
            and all(supports_fused_unseal(d, page_bytes[p])
                    for p, (_, d) in shapes.items()))
        self._fused_bpp = (next(iter(page_bytes.values())) // 64
                           if self.supports_fused else 0)
        for p in self._paged_paths:
            self._crypt[p] = np.zeros((self.num_pages + 1, 4), np.uint32)

        cfg = model.cfg
        acfg = _attn_cfg(cfg)
        bpp = self._fused_bpp
        mlayers = model_layers

        def _decode_kernel(params, tokens, blocks, table, pos, write_phys,
                           write_off, k_crypt, v_crypt, key_words, state,
                           kmax, use_cipher):
            x = impl._embed(params, tokens)            # [b, 1, d]
            positions = pos[:, None]
            valid = pos + 1
            slot0 = blocks[block_name]["slot_0"]
            kp, vp = slot0["k"], slot0["v"]

            def body(carry, lp):
                x, kp, vp, li = carry
                sl = lp["slot_0"]
                h = mlayers.rmsnorm(sl["pre_norm"], x, cfg.norm_eps)
                q, k, v = mlayers._qkv(sl["attn"], acfg, h, positions)
                kl = jax.lax.dynamic_index_in_dim(kp, li, 0, keepdims=False)
                vl = jax.lax.dynamic_index_in_dim(vp, li, 0, keepdims=False)
                # append this step's k/v before attending (idle rows route
                # to the null page), exactly like the gather path's
                # write-then-attend
                kl = kl.at[write_phys, write_off].set(
                    k[:, 0].astype(kl.dtype))
                vl = vl.at[write_phys, write_off].set(
                    v[:, 0].astype(vl.dtype))
                if use_cipher:
                    out = paged_attention_unseal(
                        q[:, 0], kl, vl, table, valid, li, key_words,
                        k_crypt, v_crypt, blocks_per_page=bpp,
                        interpret=INTERPRET)
                else:
                    out = paged_attention(q[:, 0], kl, vl, table, valid,
                                          interpret=INTERPRET)
                x = x + jnp.einsum("bshk,hkd->bsd",
                                   out.astype(q.dtype)[:, None],
                                   sl["attn"]["wo"])
                h = mlayers.rmsnorm(sl["post_norm"], x, cfg.norm_eps)
                x = x + mlayers.swiglu(sl["ffn"], h)
                kp = jax.lax.dynamic_update_index_in_dim(kp, kl, li, 0)
                vp = jax.lax.dynamic_update_index_in_dim(vp, vl, li, 0)
                return (x, kp, vp, li + 1), None

            (x, kp, vp, _), _ = jax.lax.scan(
                body, (x, kp, vp, jnp.int32(0)), params[block_name])
            logits = impl._unembed(params, x)[:, 0]
            if state is None:
                toks = sampling.greedy(logits)
            else:
                toks = sampling.sample(logits, state, kmax=kmax)
            new_blocks = dict(blocks)
            new_blocks[block_name] = dict(blocks[block_name])
            new_blocks[block_name]["slot_0"] = dict(slot0, k=kp, v=vp)
            return toks, new_blocks

        self._decode_fn = self.plan.compile_decode(
            _decode_kernel, donate_argnums=(2,), static_argnums=(11, 12))

    # -- page accounting ------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size)

    @property
    def free_page_reserve(self) -> int:
        """Pages an admission may still promise: unreserved pages in reserve
        mode, unpromised free physical pages on demand."""
        if self.on_demand:
            return len(self._free_pages) - self._promised_total
        return self._reserve_free

    @property
    def free_physical_pages(self) -> int:
        return len(self._free_pages)

    def allocated_pages(self, slot: int) -> int:
        return int(self._alloc[slot])

    @property
    def request_capacity(self) -> int:
        # the dense decode view is still [*, max_len, *]; a sequence also
        # cannot out-reserve the pool.
        return min(self.max_len, self.num_pages * self.page_size)

    def page_keys(self, tokens: np.ndarray, written_len: int
                  ) -> Optional[List[bytes]]:
        """Content keys for a prompt's prefill pages (None when sharing is
        off — callers pass the result straight back to admission hooks)."""
        if not self.prefix_sharing:
            return None
        return prefix_page_keys(tokens, self.page_size, written_len,
                                self._key_salt)

    def resident_pages(self, page_keys: Optional[Sequence[bytes]]) -> int:
        """How many of these content keys are resident in the LIVE index
        now. Deliberately excludes store residency: admission's page
        promises (:meth:`Engine._admit_need`) size physical takes from this
        count, and a store hit still takes a fresh physical page — only the
        *pricing* discount (:meth:`admission_check`) may see the store."""
        if not page_keys:
            return 0
        return sum(1 for k in page_keys if k in self._index)

    def store_resident_pages(self, page_keys: Optional[Sequence[bytes]]
                             ) -> int:
        """How many of these content keys the persistent store could serve
        beyond the live index — the admission discount's second tier (and
        the fleet's store-affinity placement signal)."""
        if not page_keys or self.page_store is None:
            return 0
        skey = self.store_key or self._seal_key_cache
        if skey is None:
            return 0
        return sum(1 for k in page_keys
                   if k not in self._index
                   and self.page_store.contains(skey, k))

    def admission_check(self, need: int, page_keys: Optional[Sequence[bytes]]
                        = None) -> Tuple[bool, int]:
        """(fits, effective_need). The capacity bound is NOT relaxed by
        sharing: every page of one sequence — shared or private — occupies
        its own simultaneous page-table mapping, so a single request can
        never exceed ``min(max_len, num_pages * page_size)`` however warm
        the index is. What sharing discounts is the *effective demand*
        (``need`` minus resident shared positions): the unit admission
        charges against the pool, which is what lets a RAG request whose
        context prefix is resident admit alongside traffic that would
        otherwise have reserved the pool away. Store-resident prefixes
        discount the same way — a store hit skips the prefill recompute,
        which is the cost effective demand prices — even though the
        restored page still occupies a fresh physical page."""
        resident = (self.resident_pages(page_keys)
                    + self.store_resident_pages(page_keys))
        eff = max(1, int(need) - resident * self.page_size)
        return need <= self.request_capacity, eff

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= self.free_page_reserve

    def can_restore(self, n_tokens: int,
                    n_pages: Optional[int] = None) -> bool:
        if not self.on_demand:
            return self.pages_for(n_tokens) <= self._reserve_free
        pages = n_pages if n_pages is not None else self.pages_for(n_tokens)
        # headroom: leave one page per active slot so the restore is not
        # immediately re-evicted by the next step's appends (thrash damping;
        # with nothing else active the pool is all the restore's).
        return pages + len(self.slots.active) <= len(self._free_pages)

    def _take_pages(self, n: int) -> List[int]:
        assert n <= len(self._free_pages), \
            "page allocation exceeded reservation/grant — accounting bug"
        taken, self._free_pages = self._free_pages[:n], self._free_pages[n:]
        for p in taken:
            self._page_ref[p] = 1
            self._clear_crypt(p)
        return taken

    def _drop_ref(self, phys: int) -> None:
        """One table mapping of ``phys`` goes away; the page is freed (and
        unregistered — parked first if sealed references remain) only when
        the LAST mapping drops."""
        phys = int(phys)
        self._page_ref[phys] -= 1
        assert self._page_ref[phys] >= 0, "double-free — refcount bug"
        if self._page_ref[phys] == 0:
            self._unregister(phys)
            self._clear_crypt(phys)
            self._free_pages.append(phys)

    def bind_store_key(self, key: SealingKey) -> None:
        """Fix the key domain this backend's store traffic lives under (the
        engine binds its TrustDomain sealing key at construction). Store
        entries are namespaced by key id, so two engines sharing one store
        object can never be offered each other's ciphertext — a cross-
        domain lookup is a clean miss, and the independent per-domain MAC
        key would reject the blob even if it were offered."""
        self.store_key = key

    def _content_key(self) -> Optional[SealingKey]:
        """The key content-named blobs (parking AND the store) seal under:
        the bound store key when present, else the last key seen — one
        selection for both tiers, so parked blobs and store entries are
        always interchangeable ciphertext."""
        return self.store_key or self._seal_key_cache

    def _seal_content_page(self, key: SealingKey, key_bytes: bytes,
                           phys: int) -> Dict[str, SealedTensor]:
        """Seal one resident page under its canonical content-derived name
        (same content => same name => same nonce AND plaintext)."""
        pages = self._page_arrays([phys])
        return {kpath: seal_tensor(key, shared_page_name(key_bytes, kpath),
                                   arr[:, 0])
                for kpath, arr in pages.items()}

    def _publish_store(self, skey: SealingKey, key_bytes: bytes,
                       blobs: Dict[str, SealedTensor]) -> None:
        """Hand content-named blobs to the persistent store; evictions the
        budget forces surface as events (no boundary crossing — the host
        simply forgets ciphertext)."""
        for e in self.page_store.publish(skey, key_bytes, blobs,
                                         tokens=self.page_size):
            self._events.append(("store_evict", e.n_bytes, len(e.blobs)))

    def _unregister(self, phys: int) -> None:
        key = self._page_key.pop(phys, None)
        if key is None:
            return
        del self._index[key]
        if self._sealed_refs.get(key, 0) > 0:
            self._park(key, phys)
        if (self.page_store is not None
                and key not in self._partial_keys):
            skey = self._content_key()
            if skey is not None and not self.page_store.contains(skey, key):
                # publish the dying page's content: reuse the parked blobs
                # when parking just sealed them (no second crossing), else
                # seal once here — a fresh "store_publish" boundary event.
                blobs = self._parked.get(key)
                if blobs is None:
                    blobs = self._seal_content_page(skey, key, phys)
                    nb = sum(b.n_bytes for b in blobs.values())
                    self._events.append(("store_publish", nb, len(blobs)))
                self._publish_store(skey, key, blobs)

    def _park(self, key_bytes: bytes, phys: int) -> None:
        """Last reference to a sealed-referenced shared page is dropping:
        move its data across the boundary ONCE, under its content-derived
        name (deterministic: same content => same nonce AND same plaintext,
        so a later identical parking can never violate nonce uniqueness)."""
        key = self._content_key()
        assert key is not None, \
            "sealed refs exist but no sealing key was ever seen"
        if key_bytes in self._parked:
            return
        blobs = self._seal_content_page(key, key_bytes, phys)
        self._parked[key_bytes] = blobs
        nb = sum(b.n_bytes for b in blobs.values())
        self._events.append(("park", nb, len(blobs)))

    def drain_events(self) -> List[Tuple[str, int, int]]:
        """Boundary traffic the backend generated outside an explicit
        seal/restore call (shared-page parking and re-materialization); the
        engine drains this into the TrustDomain accounting."""
        ev, self._events = self._events, []
        return ev

    # -- sequence lifecycle ---------------------------------------------------
    def acquire(self, rid: int, n_tokens: int) -> Optional[int]:
        need = self.pages_for(n_tokens) if n_tokens > 0 else 0
        if need > self.free_page_reserve:
            return None
        slot = self.slots.acquire(rid)
        if slot is None:
            return None
        if self.on_demand:
            # promise only the immediate (prompt) need the engine passed;
            # decode-time pages are granted at step time.
            self._promised[slot] = need
            self._promised_total += need
        else:
            self._reserved[slot] = need
            self._reserve_free -= need
        return slot

    def release(self, slot: int) -> None:
        n = int(self._alloc[slot])
        for j in range(n):
            self._drop_ref(self.table[slot, j])
        self.table[slot] = 0
        self._alloc[slot] = 0
        self._reserve_free += int(self._reserved[slot])
        self._reserved[slot] = 0
        self._promised_total -= int(self._promised[slot])
        self._promised[slot] = 0
        self.pos[slot] = 0
        self.slots.release(slot)

    # -- device compute -------------------------------------------------------
    def insert_prefill(self, prefilled: Cache, slots: List[int],
                       written_len: int,
                       page_keys: Optional[List[Optional[List[bytes]]]] = None
                       ) -> None:
        k = len(slots)
        rows = prefilled["pos"].shape[0]
        n_pages = self.pages_for(written_len)
        skey = self._content_key() if self.page_store is not None else None
        # phase 1: plan every slot's pages with NO state mutation. Index
        # misses consult the persistent store; a store hit's blobs are
        # MAC-verified and decrypted HERE, so a tampered store entry fails
        # the whole group before a single page or refcount moves. `pending`
        # tracks keys an earlier group member will register at commit —
        # later members share its page instead of double-registering.
        plans: List[List[Tuple[str, int, Optional[bytes], Any]]] = []
        pending: set = set()
        for i, slot in enumerate(slots):
            keys = page_keys[i] if page_keys else None
            plan = []
            for j in range(n_pages):
                key = keys[j] if keys else None
                if key is not None and (key in self._index
                                        or key in pending):
                    plan.append(("hit", j, key, None))
                    continue
                if key is not None and skey is not None:
                    blobs = self.page_store.lookup(skey, key)
                    if blobs is not None:
                        plain = {kpath: np.asarray(unseal_tensor(skey, st))
                                 for kpath, st in blobs.items()}
                        nb = sum(st.n_bytes for st in blobs.values())
                        plan.append(("store", j, key, (plain, nb)))
                        pending.add(key)
                        continue
                plan.append(("miss", j, key, None))
                if key is not None:
                    pending.add(key)
            plans.append(plan)
        # phase 2: commit — map hits, take pages for store hits and misses.
        src_rows, page_ord, phys = [], [], []
        store_writes: Dict[int, Dict[str, np.ndarray]] = {}
        for i, slot in enumerate(slots):
            store_js = [pl for pl in plans[i] if pl[0] == "store"]
            misses = [pl for pl in plans[i] if pl[0] == "miss"]
            # one batched take per slot (not one free-list reslice per page)
            taken = self._take_pages(len(store_js) + len(misses))
            for pl in plans[i]:
                kind, j, key = pl[0], pl[1], pl[2]
                if kind == "hit":
                    # shared: map the resident page, write nothing (keys
                    # pending at plan time committed in an earlier slot)
                    hit = self._index[key]
                    self._page_ref[hit] += 1
                    self.table[slot, j] = hit
                    self.shared_page_maps += 1
                elif kind == "store":
                    plain, nb = pl[3]
                    p = taken.pop(0)
                    self.table[slot, j] = p
                    self._index[key] = p
                    self._page_key[p] = key
                    store_writes[p] = plain
                    self.store_hits += 1
                    self.store_restored_pages += 1
                    self.store_restored_bytes += nb
                    self._events.append(("store_hit", nb, len(plain)))
                else:
                    p = taken.pop(0)
                    self.table[slot, j] = p
                    if key is not None:
                        self._index[key] = p
                        self._page_key[p] = key
                        if (j + 1) * self.page_size > written_len:
                            self._partial_keys.add(key)
                    src_rows.append(i)
                    page_ord.append(j)
                    phys.append(p)
            self._alloc[slot] = n_pages
            self.pos[slot] = written_len
            self._promised_total -= int(self._promised[slot])
            self._promised[slot] = 0
        # store-restored pages are intentionally NOT pages_written: that
        # counter is the prefill-write cost the warm epoch is supposed to
        # shrink (store_restored_pages counts the restores).
        self.pages_written += len(phys)
        if not phys:
            # every page of every group member was an index hit: route one
            # dummy write to the null scratch page (the same sink idle rows
            # use) so the splice shape machinery stays uniform.
            src_rows, page_ord, phys = [0], [0], [0]
        # pad the scatter lists to a power of two by repeating the last real
        # entry (an identical duplicate write — harmless) so compiled splice
        # shapes stay bounded; same for the dense-row scatter.
        pad = next_pow2(len(phys))
        src_rows += [src_rows[-1]] * (pad - len(src_rows))
        page_ord += [page_ord[-1]] * (pad - len(page_ord))
        phys += [phys[-1]] * (pad - len(phys))
        dense_rows = list(range(k)) + [k - 1] * (rows - k)
        dense_slots = list(slots) + [slots[-1]] * (rows - k)
        prefilled = dict(prefilled)
        prefilled.pop("pos")
        self.blocks = self._splice_fn(
            self.blocks, prefilled,
            host_upload(src_rows, jnp.int32), host_upload(page_ord, jnp.int32),
            host_upload(phys, jnp.int32), host_upload(dense_rows, jnp.int32),
            host_upload(dense_slots, jnp.int32))
        if store_writes:
            self._scatter_pages(store_writes)

    def step_page_need(self, slot: int) -> int:
        """Physical pages decode() will take for this slot's next append:
        1 for a fresh page when the append crosses a page boundary, 1 for a
        copy-on-write when the append lands in a page other live mappings
        still read. The engine sums this over the step's write slots and
        frees capacity (on-demand mode) before the decode call."""
        ordinal = int(self.pos[slot]) // self.page_size
        if ordinal >= int(self._alloc[slot]):
            return 1
        p = int(self.table[slot, ordinal])
        if p in self._page_key and self._page_ref[p] > 1:
            return 1
        return 0

    def _prepare_write(self, slot: int) -> Tuple[int, int]:
        """Resolve the physical (page, offset) for this slot's append,
        mapping a fresh page at a page boundary and resolving writes into
        indexed pages: copy-on-write while other mappings remain, plain
        unregistration (parking the content for sealed references first)
        when the writer is the sole user."""
        ordinal = int(self.pos[slot]) // self.page_size
        if ordinal >= int(self._alloc[slot]):
            assert ordinal == int(self._alloc[slot])
            assert self.on_demand or ordinal < int(self._reserved[slot])
            self.table[slot, ordinal] = self._take_pages(1)[0]
            self._alloc[slot] = ordinal + 1
            self.pages_written += 1
        p = int(self.table[slot, ordinal])
        if p in self._page_key:
            if self._page_ref[p] > 1:
                new = self._take_pages(1)[0]
                self.blocks = self._copy_page_fn(
                    self.blocks, jnp.int32(p), jnp.int32(new))
                self._page_ref[p] -= 1
                self.table[slot, ordinal] = new
                self.cow_copies += 1
                self.pages_written += 1
                p = new
            else:
                # sole live user about to diverge: the page leaves the
                # index (its registered content is about to change)
                self._unregister(p)
        # backstop: an append must never land in ciphertext. Restore only
        # admits FULL pages as ciphertext-resident (the next append maps a
        # fresh page), so this fires only if that invariant ever breaks.
        if p in self._cipher_pages:
            self._materialize_page(p)
        return p, int(self.pos[slot]) % self.page_size

    def decode(self, params, tokens, state, kmax,
               write_slots: Sequence[int]) -> np.ndarray:
        write_phys = np.zeros(self.max_slots, np.int32)   # default: null page
        write_off = np.zeros(self.max_slots, np.int32)
        for s in write_slots:
            write_phys[s], write_off[s] = self._prepare_write(s)
        if self.decode_mode == "kernel":
            use_cipher = bool(self._cipher_pages)
            key_words = (self._crypt_key.key_words if use_cipher
                         else jnp.zeros(8, jnp.uint32))
            next_tokens, self.blocks = self._decode_fn(
                params, host_upload(tokens[:, None]), self.blocks,
                host_upload(self.table), host_upload(self.pos),
                host_upload(write_phys), host_upload(write_off),
                host_upload(self._crypt[self._k_path]),
                host_upload(self._crypt[self._v_path]),
                key_words, state, kmax, use_cipher)
        else:
            next_tokens, self.blocks = self._decode_fn(
                params, host_upload(tokens[:, None]), self.blocks,
                host_upload(self.table), host_upload(self.pos),
                host_upload(write_phys), host_upload(write_off), state, kmax)
        for s in write_slots:
            self.pos[s] += 1
        return np.asarray(next_tokens)

    def cache_nbytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.blocks))

    # -- ciphertext-resident pages (fused-unseal restore path) ----------------
    def _admit_cipher_page(self, key: SealingKey, phys: int,
                           blobs: Dict[str, SealedTensor]
                           ) -> Dict[str, np.ndarray]:
        """Admit a MAC-verified sealed page into the pool as raw ciphertext
        bits (the linear RFC 8439 stream bit-cast into the page's plaintext
        layout) and arm its crypt sidecar rows (nonce words + live flag) so
        the fused decode kernel decrypts it in VMEM on every read."""
        writes: Dict[str, np.ndarray] = {}
        for kpath, st in blobs.items():
            raw = ciphertext_page_bytes(st)
            writes[kpath] = np.frombuffer(
                raw, self._page_dtype[kpath]).reshape(self._page_shape[kpath])
            self._crypt[kpath][phys, :3] = nonce_words_for(key, st.name)
            self._crypt[kpath][phys, 3] = 1
        self._cipher_pages.add(int(phys))
        self.fused_restore_pages += 1
        self.fused_restore_bytes += sum(st.n_bytes for st in blobs.values())
        return writes

    def _clear_crypt(self, phys: int) -> None:
        if phys in self._cipher_pages:
            self._cipher_pages.discard(phys)
            for kpath in self._crypt:
                self._crypt[kpath][phys] = 0

    def _materialize_page(self, phys: int) -> None:
        """Host-decrypt a ciphertext-resident page in place (XOR with the
        reference keystream regenerated from the crypt sidecar) so host
        consumers — seal, park, copy, append — see plaintext. The decode
        kernel's per-page counter for layer l starts at l*blocks_per_page,
        which is exactly the linear stream from counter 0, so one
        contiguous keystream covers all L layers of the blob."""
        if phys not in self._cipher_pages:
            return
        pages = self._page_arrays([phys], materialize=False)
        writes: Dict[str, np.ndarray] = {}
        nb = 0
        for kpath, arr in pages.items():
            page = np.ascontiguousarray(arr[:, 0])
            nonce = self._crypt[kpath][phys, :3].tobytes()
            ks = chacha20_keystream_bytes_ref(
                self._crypt_key.key, nonce, page.nbytes)
            plain = np.bitwise_xor(
                page.reshape(-1).view(np.uint8),
                np.frombuffer(ks, np.uint8)).view(page.dtype)
            writes[kpath] = plain.reshape(page.shape)
            nb += page.nbytes
        self._clear_crypt(phys)
        self._scatter_pages({phys: writes})
        self._events.append(("materialize", nb, len(writes)))

    # -- sealing --------------------------------------------------------------
    def _page_arrays(self, phys: Sequence[int], *,
                     materialize: bool = True) -> Dict[str, np.ndarray]:
        """Fetch the given physical pages of every paged leaf:
        keystr -> [L, n, page_size, ...].

        By default any ciphertext-resident page among ``phys`` is
        materialized (host-decrypted in place) first, so every host
        consumer — seal, park, copy — sees plaintext bits.
        """
        if materialize and self._cipher_pages:
            for p in phys:
                self._materialize_page(int(p))
        idx = host_upload(list(phys), jnp.int32)
        out = {}

        def pull(path, leaf):
            if _keystr(path) in self._paged_paths:
                out[_keystr(path)] = np.asarray(leaf[:, idx])
            return leaf
        jax.tree_util.tree_map_with_path(pull, self.blocks)
        return out

    def _seal_pages(self, key: SealingKey, prefix: str, ordinals: Sequence[int],
                    phys: Sequence[int],
                    suffix: str = "") -> Dict[str, SealedTensor]:
        sealed: Dict[str, SealedTensor] = {}
        if not ordinals:
            return sealed
        pages = self._page_arrays(phys)
        for kpath, arr in pages.items():
            for j, ordinal in enumerate(ordinals):
                name = f"{prefix}{kpath}/p{ordinal}{suffix}"
                sealed[name] = seal_tensor(key, name, arr[:, j])
        return sealed

    def _split_ordinals(self, slot: int) -> Tuple[List[int], List[int]]:
        """(shared, private) page ordinals of a slot: shared pages are the
        content-indexed ones (sealed by reference), private ones move as
        per-page ciphertext."""
        shared, private = [], []
        for j in range(int(self._alloc[slot])):
            p = int(self.table[slot, j])
            (shared if p in self._page_key else private).append(j)
        return shared, private

    def seal(self, key, slot, prefix, suffix="",
             detach=False) -> Dict[str, SealedTensor]:
        self._seal_key_cache = key
        n_alloc = int(self._alloc[slot])
        if detach:
            # by-VALUE seal for cross-pool migration: shared pages ship as
            # ordinary per-page ciphertext so the blob is self-contained —
            # a destination pool has neither this pool's content index nor
            # its parked blobs to resolve a by-reference entry against. The
            # copies restore as private pages; sharing re-forms (if at all)
            # through the destination's own content index. Source-side
            # residents and refcounts are untouched: co-sharers keep their
            # mappings, and no _sealed_refs entry is minted (there is
            # nothing for discard_sealed to release).
            shared, private = [], list(range(n_alloc))
        else:
            shared, private = self._split_ordinals(slot)
        # meta v2: [pos, n_alloc, n_shared, (ordinal, refcount) per shared
        # page]; the content keys ride in their own sealed blob. The
        # refcount is recorded at seal time (audit/diagnostic — the live
        # count changes legitimately while this request is out).
        meta = [int(self.pos[slot]), n_alloc, len(shared)]
        keys_cat = b""
        for j in shared:
            k = self._page_key[int(self.table[slot, j])]
            meta += [j, int(self._page_ref[int(self.table[slot, j])])]
            keys_cat += k
            self._sealed_refs[k] = self._sealed_refs.get(k, 0) + 1
        meta_name = f"{prefix}/meta{suffix}"
        sealed = {meta_name: seal_tensor(key, meta_name,
                                         np.asarray(meta, np.int32))}
        if shared:
            keys_name = f"{prefix}/sharedkeys{suffix}"
            sealed[keys_name] = seal_tensor(
                key, keys_name, np.frombuffer(keys_cat, np.uint8))
        sealed.update(self._seal_pages(
            key, prefix, private,
            [int(self.table[slot, j]) for j in private], suffix))

        def pull_dense(path, leaf):
            if _keystr(path) not in self._paged_paths:
                name = f"{prefix}{_keystr(path)}{suffix}"
                sealed[name] = seal_tensor(key, name,
                                           np.asarray(leaf[:, slot:slot + 1]))
            return leaf
        jax.tree_util.tree_map_with_path(pull_dense, self.blocks)
        return sealed

    def restore(self, key, sealed, slot, prefix, n_tokens, suffix="") -> None:
        # the reservation was re-made when the engine re-acquired the slot
        # (reserve mode); decrypt-then-commit: every MAC is verified before
        # any accounting state moves, so a tampered blob fails the restore
        # without leaking the slot, pages, or a refcount.
        self._seal_key_cache = key
        meta = np.asarray(unseal_tensor(key, sealed[f"{prefix}/meta{suffix}"]))
        pos, n_alloc, n_shared = int(meta[0]), int(meta[1]), int(meta[2])
        shared_ords = [int(meta[3 + 2 * i]) for i in range(n_shared)]
        keys: List[bytes] = []
        if n_shared:
            cat = bytes(np.asarray(unseal_tensor(
                key, sealed[f"{prefix}/sharedkeys{suffix}"])))
            keys = [cat[16 * i:16 * (i + 1)] for i in range(n_shared)]
        shared_set = set(shared_ords)
        private_ords = [j for j in range(n_alloc) if j not in shared_set]
        # fused-unseal eligibility (decode='kernel' on a fused-capable
        # pool): FULL private pages are MAC-gated here but admitted as
        # ciphertext — the decode kernel regenerates the keystream per page
        # and XORs in VMEM, so their plaintext never round-trips HBM. The
        # partial tail page stays on the host path (the next append writes
        # into it and appends must land in plaintext).
        fused_set = (
            {j for j in private_ords if (j + 1) * self.page_size <= pos}
            if self.decode_mode == "kernel" and self.supports_fused
            else set())
        if fused_set:
            if (self._crypt_key is not None and self._cipher_pages
                    and self._crypt_key.key != key.key):
                # one keystream key rides the decode step: flush residents
                # sealed under the previous key before switching
                for p in list(self._cipher_pages):
                    self._materialize_page(p)
            self._crypt_key = key
        # phase 1: MAC-verify everything this restore will need — fused
        # pages without decrypting (verify_mac), host-path pages by
        # decrypting; resident re-links get no blob to verify (the live
        # pool IS the authority), parked pages are verified below.
        fused_blobs: Dict[int, Dict[str, SealedTensor]] = {}
        private_pages: Dict[int, Dict[str, np.ndarray]] = {}
        for j in private_ords:
            blobs = {kpath: sealed[f"{prefix}{kpath}/p{j}{suffix}"]
                     for kpath in self._paged_paths}
            if j in fused_set:
                for st in blobs.values():
                    verify_mac(key, st)
                fused_blobs[j] = blobs
            else:
                private_pages[j] = {
                    kpath: np.asarray(unseal_tensor(key, st))
                    for kpath, st in blobs.items()}
        plans: List[Tuple[str, int, bytes, Any]] = []
        for j, k in zip(shared_ords, keys):
            if k in self._index:
                plans.append(("relink", j, k, None))
            elif k in self._parked:
                blobs = {kpath: np.asarray(unseal_tensor(key, st))
                         for kpath, st in self._parked[k].items()}
                plans.append(("remat", j, k, blobs))
            elif (self.page_store is not None
                  and self.page_store.contains(key, k)):
                # third tier: the persistent store outlived the parked blob
                # (e.g. a deadline abort discarded the last sealed ref).
                # MAC-gate here, in phase 1, like everything else.
                stored = self.page_store.lookup(key, k)
                blobs = {kpath: np.asarray(unseal_tensor(key, st))
                         for kpath, st in stored.items()}
                nb = sum(st.n_bytes for st in stored.values())
                plans.append(("storehit", j, k, (blobs, nb)))
            else:
                raise IntegrityError(
                    f"shared page (ordinal {j}) is neither resident, "
                    f"parked, nor store-resident — sealed state references "
                    f"lost content")
        dense_rows = {}

        def pull_names(path, leaf):
            kpath = _keystr(path)
            if kpath not in self._paged_paths:
                dense_rows[kpath] = np.asarray(unseal_tensor(
                    key, sealed[f"{prefix}{kpath}{suffix}"]))
            return leaf
        jax.tree_util.tree_map_with_path(pull_names, self.blocks)

        # phase 2: commit — map, write, and account.
        assert self.on_demand or n_alloc <= int(self._reserved[slot]), \
            "restore into a smaller reservation — accounting bug"
        n_fresh = len(private_ords) + sum(1 for p in plans
                                          if p[0] in ("remat", "storehit"))
        taken = self._take_pages(n_fresh)
        it = iter(taken)
        writes: Dict[int, Dict[str, np.ndarray]] = {}
        for j in private_ords:
            p = next(it)
            self.table[slot, j] = p
            if j in fused_set:
                writes[p] = self._admit_cipher_page(key, p, fused_blobs[j])
            else:
                writes[p] = private_pages[j]
        # NOTE: sealed references are NOT consumed here — a whole-slot
        # restore may still fail after this commit (the engine grafts
        # sealed-while-paused tail blobs afterwards), and an under-counted
        # _sealed_refs would let parked ciphertext an innocent co-sharer
        # still needs be deleted. The engine releases the references via
        # discard_sealed only once the entire restore has succeeded; a
        # rolled-back restore leaves refs (and parked blobs) untouched.
        for kind, j, k, blobs in plans:
            if kind == "relink":
                p = self._index[k]
                self._page_ref[p] += 1
                self.table[slot, j] = p
                self.shared_page_maps += 1
            elif kind == "storehit":
                plain, nb = blobs
                p = next(it)
                self.table[slot, j] = p
                self._index[k] = p
                self._page_key[p] = k
                writes[p] = plain
                self.store_hits += 1
                self.store_restored_pages += 1
                self.store_restored_bytes += nb
                self._events.append(("store_hit", nb, len(plain)))
            else:
                p = next(it)
                self.table[slot, j] = p
                self._index[k] = p
                self._page_key[p] = k
                writes[p] = blobs
                nb = sum(st.n_bytes for st in self._parked[k].values())
                self._events.append(("rematerialize", nb,
                                     len(self._parked[k])))
        self._alloc[slot] = n_alloc
        self.pos[slot] = pos
        # store-restored pages stay out of pages_written (same counter
        # contract as insert_prefill: pages_written is prefill/seal-path
        # write cost, store_restored_pages counts the restores)
        self.pages_written += len(writes) - sum(1 for p in plans
                                                if p[0] == "storehit")
        self._scatter_pages(writes)
        self._put_dense_rows(slot, dense_rows)

    def _scatter_pages(self, writes: Dict[int, Dict[str, np.ndarray]]) -> None:
        """Write host page arrays into the pool: one padded donated scatter
        per leaf (see the next_pow2 note on bounded compiled variants)."""
        if not writes:
            return
        phys = list(writes)
        pad = next_pow2(len(phys))
        idx = host_upload(phys + [phys[-1]] * (pad - len(phys)), jnp.int32)

        def put(path, leaf):
            kpath = _keystr(path)
            if kpath not in self._paged_paths:
                return leaf
            pages = np.stack([writes[p][kpath] for p in phys]
                             + [writes[phys[-1]][kpath]] * (pad - len(phys)),
                             axis=1)
            return _set_pages(leaf, idx, host_upload(pages))
        self.blocks = jax.tree_util.tree_map_with_path(put, self.blocks)

    def _put_dense_rows(self, slot: int,
                        rows: Dict[str, np.ndarray]) -> None:
        """Write every dense (recurrent-state) leaf's restored row in ONE
        tree traversal (one jitted row-scatter per dense leaf)."""
        if not rows:
            return

        def put(path, leaf):
            row = rows.get(_keystr(path))
            if row is None:
                return leaf
            return _set_row(leaf, jnp.int32(slot), host_upload(row))
        self.blocks = jax.tree_util.tree_map_with_path(put, self.blocks)

    def discard_sealed(self, key: SealingKey, sealed: Dict[str, SealedTensor],
                       prefix: str, suffix: str = "") -> None:
        """Release a sealed dict's shared-content references — called when
        the dict is spent: after a fully-successful restore, or when a
        sealed-out request is dropped unrestored (deadline abort). Parked
        ciphertext dies with its last reference instead of outliving every
        reader."""
        name = f"{prefix}/sharedkeys{suffix}"
        if name not in sealed:
            return
        cat = bytes(np.asarray(unseal_tensor(key, sealed[name])))
        for i in range(len(cat) // 16):
            k = cat[16 * i:16 * (i + 1)]
            if k in self._sealed_refs:
                self._sealed_refs[k] -= 1
                if self._sealed_refs[k] <= 0:
                    del self._sealed_refs[k]
                    blobs = self._parked.pop(k, None)
                    # store retention: a deadline abort dropping the last
                    # sealed reference must not take the content with it
                    # when a store tier exists — admission may already have
                    # discounted a waiting request against this key. The
                    # dying parked blob IS the store's canonical ciphertext
                    # (same name, same key), so hand it over — a membership
                    # no-op when the release path already published it.
                    if (blobs is not None and self.page_store is not None
                            and k not in self._partial_keys):
                        skey = self._content_key()
                        if skey is not None:
                            self._publish_store(skey, k, blobs)

    # -- partial eviction -----------------------------------------------------
    def evictable_tail_pages(self, slot: int) -> int:
        """How many tail pages ``seal_tail_pages`` may take: trailing
        *private* pages only (a shared page cannot be torn out of other
        readers' tables), and the victim always keeps one resident page."""
        n_alloc = int(self._alloc[slot])
        trailing = 0
        for j in range(n_alloc - 1, -1, -1):
            if int(self.table[slot, j]) in self._page_key:
                break
            trailing += 1
        return max(0, min(trailing, n_alloc - 1))

    def seal_tail_pages(self, key: SealingKey, slot: int, prefix: str,
                        n_pages: int,
                        suffix: str = "") -> Dict[str, SealedTensor]:
        """Seal and free the ``n_pages`` most recent pages of ``slot`` —
        a capacity loan: the pages AND their reservation go back to the
        pool for other traffic, while the victim keeps its slot, sampling
        row, and resident head pages. The victim must not decode until
        :meth:`restore_tail_pages` brings the delta back (the engine parks
        it out of the batch)."""
        self._seal_key_cache = key
        n_alloc = int(self._alloc[slot])
        if not (0 < n_pages < n_alloc):
            raise ValueError(
                f"partial eviction wants 0 < n_pages < allocated "
                f"({n_alloc}), got {n_pages}")
        if n_pages > self.evictable_tail_pages(slot):
            raise ValueError(
                f"partial eviction of {n_pages} pages would cross into the "
                f"shared prefix (only {self.evictable_tail_pages(slot)} "
                f"trailing private pages)")
        ordinals = list(range(n_alloc - n_pages, n_alloc))
        phys = [int(p) for p in self.table[slot, ordinals]]
        meta_name = f"{prefix}/pagemeta{suffix}"
        sealed = {meta_name: seal_tensor(
            key, meta_name, np.asarray([ordinals[0], n_pages], np.int32))}
        sealed.update(self._seal_pages(key, prefix, ordinals, phys, suffix))
        self.table[slot, ordinals] = 0
        self._alloc[slot] = n_alloc - n_pages
        for p in phys:
            self._drop_ref(p)
        if not self.on_demand:
            self._reserved[slot] -= n_pages
            self._reserve_free += n_pages
        return sealed

    def can_restore_tail(self, n_pages: int) -> bool:
        if not self.on_demand:
            return n_pages <= self._reserve_free
        # same thrash damping as can_restore: demand headroom while other
        # slots are live (the resume competes with their next appends).
        headroom = 1 if len(self.slots.active) > 1 else 0
        return n_pages + headroom <= len(self._free_pages)

    def restore_tail_pages(self, key: SealingKey,
                           sealed: Dict[str, SealedTensor], slot: int,
                           prefix: str, reserve: bool = True,
                           suffix: str = "") -> int:
        """Re-map and decrypt a partial eviction's pages; returns the page
        count. Physical placement is fresh — the table indirection makes
        relocation free. ``reserve=False`` skips re-reserving: used when the
        tail rides along a whole-slot restore whose ``acquire`` already
        reserved the sequence's full worst case."""
        self._seal_key_cache = key
        meta = np.asarray(unseal_tensor(
            key, sealed[f"{prefix}/pagemeta{suffix}"]))
        start, n_pages = int(meta[0]), int(meta[1])
        if reserve and not self.on_demand:
            assert self.can_restore_tail(n_pages), \
                "restore_tail without can_restore_tail — accounting bug"
            self._reserved[slot] += n_pages
            self._reserve_free -= n_pages
        ordinals = list(range(start, start + n_pages))
        # decrypt first (MAC gate), then map and write
        pages = {
            j: {kpath: np.asarray(unseal_tensor(
                    key, sealed[f"{prefix}{kpath}/p{j}{suffix}"]))
                for kpath in self._paged_paths}
            for j in ordinals}
        taken = self._take_pages(n_pages)
        writes = {}
        for j, p in zip(ordinals, taken):
            self.table[slot, j] = p
            writes[p] = pages[j]
        self._alloc[slot] = start + n_pages
        self.pages_written += n_pages
        self._scatter_pages(writes)
        return n_pages
