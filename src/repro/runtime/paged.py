"""Paged KV backend: a page-pool + page-table layout behind ``KVBackend``.

Layout. Every cache leaf with a sequence-length axis (attention ``k``/``v``,
MLA ``ckv``/``krope``) is stored as a static pool
``[L, num_pages + 1, page_size, ...]`` — physical page 0 is a reserved
*null page* (scratch for rows that are not appending) and pages
``1..num_pages`` are allocatable. An ``[max_slots, max_pages]`` int32 page
table maps each sequence's logical pages to physical ones (entry 0 =
unmapped). Leaves without a length axis (SSM conv/state, RWKV wkv rows)
stay slot-dense ``[L, max_slots, ...]``; per-sequence positions live
host-side and are threaded into each step.

Decode. One ``jnp.take`` over the page table gathers each sequence's pages
into exactly the dense ``[L, max_slots, max_len, ...]`` view the model's
``decode_step`` already expects — static shapes end to end (TPU/XLA-safe),
no model changes. Positions at or beyond a sequence's live length are
masked inside attention (``kv_valid_len``), so whatever the gather pulls
out of unmapped/null pages never reaches a logit, and outputs are
bit-identical to the slot-dense backend. Only the single appended position
is scattered back per step (``pool.at[:, write_phys, write_off]``); rows
that are not appending route their write to the null page.

Accounting. Admission reserves ``ceil(need / page_size)`` pages — the
request's own worst case, not the engine-wide ``max_len`` a dense slot
implicitly pins — and physical pages are allocated lazily as positions are
actually written, so reservations make append failure impossible
(allocated <= reserved <= num_pages) while admission stays proportional to
the tokens a request can touch.

Sealing. Preemption seals *per page*: each allocated page of each paged
leaf becomes its own ciphertext+MAC with a nonce derived from
``{prefix}{leaf}/p{ordinal}`` — sealed bytes scale with tokens used, not
capacity reserved. ``seal_tail_pages``/``restore_tail_pages`` support
partial eviction: the tail pages (and their reservation) are released for
other traffic while the victim keeps its slot and resident pages, and only
that delta is restored before it resumes.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sealing import (SealedTensor, SealingKey, seal_tensor,
                                unseal_tensor)
from repro.runtime import sampling
from repro.runtime.kvcache import KVBackend, next_pow2
from repro.runtime.plan import ComputePlan

Cache = Any
Params = Any

# cache-leaf names that carry a [.., max_len, ..] sequence axis at dim 2
_LENGTH_LEAVES = ("k", "v", "ckv", "krope")


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def _leaf_key(path) -> Optional[str]:
    return getattr(path[-1], "key", None) if path else None


@partial(jax.jit, donate_argnums=(0,))
def _set_pages(pool_leaf, idx, pages):
    """Scatter restored pages into a donated pool leaf in place — restore
    cost stays O(pages moved), not O(pool) rebuilt per leaf."""
    return pool_leaf.at[:, idx].set(pages.astype(pool_leaf.dtype))


@partial(jax.jit, donate_argnums=(0,))
def _set_row(dense_leaf, slot, row):
    start = (jnp.int32(0), slot.astype(jnp.int32)) + \
        (jnp.int32(0),) * (dense_leaf.ndim - 2)
    return jax.lax.dynamic_update_slice(
        dense_leaf, row.astype(dense_leaf.dtype), start)


class PagedKVBackend(KVBackend):
    """See module docstring; constructed via ``Engine(kv_backend="paged")``
    or ``kvcache.make_backend("paged", ...)``."""

    name = "paged"
    supports_partial = True

    def __init__(self, model, max_slots: int, max_len: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 plan: Optional[ComputePlan] = None):
        super().__init__(model, max_slots, max_len, plan)
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if max_len % page_size != 0:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"page_size={page_size}")
        self.page_size = page_size
        self.max_pages = max_len // page_size
        if num_pages is None:
            num_pages = max_slots * self.max_pages   # dense-equivalent pool
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        # a pool smaller than max_pages is legal: request_capacity shrinks
        # to num_pages * page_size and submit rejects what cannot ever fit.
        self.num_pages = num_pages

        # classify leaves once; paged leaves move to pool layout
        dense = model.init_cache(max_slots, max_len)
        dense.pop("pos")
        self._paged_paths = set()

        def build(path, leaf):
            if (_leaf_key(path) in _LENGTH_LEAVES and leaf.ndim >= 3
                    and leaf.shape[2] == max_len):
                self._paged_paths.add(_keystr(path))
                shape = (leaf.shape[0], num_pages + 1, page_size) + leaf.shape[3:]
                return jnp.zeros(shape, leaf.dtype)
            return leaf
        self.blocks = jax.tree_util.tree_map_with_path(build, dense)
        if not self._paged_paths:
            raise ValueError(
                f"model {model.cfg.name} has no sequence-length KV leaves to "
                f"page; use kv_backend='slot' for pure-state families")
        # mesh placement: pool leaves replicate (pages are shared), dense
        # recurrent-state leaves shard their batch dim (see kvcache docs)
        self.blocks = self.plan.place_paged_cache(self.blocks,
                                                  self._paged_paths)

        # host-side sequence state
        self.pos = np.zeros(max_slots, np.int32)           # live KV positions
        self.table = np.zeros((max_slots, self.max_pages), np.int32)
        self._free_pages: List[int] = list(range(1, num_pages + 1))
        self._alloc = np.zeros(max_slots, np.int32)        # pages mapped
        self._reserved = np.zeros(max_slots, np.int32)     # pages promised
        self._reserve_free = num_pages

        paged = self._paged_paths

        def _decode(params, tokens, blocks, table, pos, write_phys,
                    write_off, state, kmax):
            def gather(path, leaf):
                if _keystr(path) not in paged:
                    return leaf
                v = jnp.take(leaf, table, axis=1)  # [L, b, max_pages, ps, ..]
                return v.reshape(leaf.shape[0], table.shape[0], max_len,
                                 *leaf.shape[3:])
            view = jax.tree_util.tree_map_with_path(gather, blocks)
            cache = dict(view)
            cache["pos"] = pos
            logits, new_cache = model.decode_step(params, tokens, cache)
            if state is None:
                toks = sampling.greedy(logits)
            else:
                toks = sampling.sample(logits, state, kmax=kmax)
            new_cache.pop("pos")

            def scatter(path, pool, new_leaf):
                if _keystr(path) not in paged:
                    # slot-dense (recurrent-state) leaf: advance ONLY the
                    # rows that actually stepped — a paused (partially
                    # evicted) row's state must stay frozen exactly where
                    # its sealed tail left it. write_phys > 0 is precisely
                    # the stepped-rows mask.
                    mask = (write_phys > 0).reshape(
                        1, -1, *([1] * (new_leaf.ndim - 2)))
                    return jnp.where(mask, new_leaf.astype(pool.dtype), pool)
                # pull the one appended position per sequence out of the
                # dense view and write it to (write_phys, write_off)
                idx = pos.reshape(1, -1, 1, *([1] * (new_leaf.ndim - 3)))
                idx = jnp.broadcast_to(
                    idx, new_leaf.shape[:2] + (1,) + new_leaf.shape[3:])
                written = jnp.take_along_axis(new_leaf, idx, axis=2)[:, :, 0]
                return pool.at[:, write_phys, write_off].set(
                    written.astype(pool.dtype))
            new_blocks = jax.tree_util.tree_map_with_path(
                scatter, blocks, new_cache)
            return toks, new_blocks

        self._decode_fn = self.plan.compile_decode(
            _decode, donate_argnums=(2,), static_argnums=(8,))

        def _splice(blocks, prefilled, page_rows, page_ord, phys,
                    dense_rows, dense_slots):
            def upd(path, pool, src):
                if _keystr(path) not in paged:
                    return pool.at[:, dense_slots].set(
                        src[:, dense_rows].astype(pool.dtype))
                pages = src.reshape(src.shape[0], src.shape[1],
                                    self.max_pages, page_size, *src.shape[3:])
                picked = pages[:, page_rows, page_ord]   # [L, n, ps, ...]
                return pool.at[:, phys].set(picked.astype(pool.dtype))
            return jax.tree_util.tree_map_with_path(upd, blocks, prefilled)

        self._splice_fn = self.plan.compile(_splice, donate_argnums=(0,))

    # -- page accounting ------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size)

    @property
    def free_page_reserve(self) -> int:
        return self._reserve_free

    @property
    def free_physical_pages(self) -> int:
        return len(self._free_pages)

    def allocated_pages(self, slot: int) -> int:
        return int(self._alloc[slot])

    @property
    def request_capacity(self) -> int:
        # the dense decode view is still [*, max_len, *]; a sequence also
        # cannot out-reserve the pool.
        return min(self.max_len, self.num_pages * self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= self._reserve_free

    def can_restore(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= self._reserve_free

    def _take_pages(self, n: int) -> List[int]:
        assert n <= len(self._free_pages), \
            "page allocation exceeded reservation — accounting bug"
        taken, self._free_pages = self._free_pages[:n], self._free_pages[n:]
        return taken

    # -- sequence lifecycle ---------------------------------------------------
    def acquire(self, rid: int, n_tokens: int) -> Optional[int]:
        need = self.pages_for(n_tokens)
        if need > self._reserve_free:
            return None
        slot = self.slots.acquire(rid)
        if slot is None:
            return None
        self._reserved[slot] = need
        self._reserve_free -= need
        return slot

    def release(self, slot: int) -> None:
        n = int(self._alloc[slot])
        if n:
            self._free_pages.extend(int(p) for p in self.table[slot, :n])
        self.table[slot] = 0
        self._alloc[slot] = 0
        self._reserve_free += int(self._reserved[slot])
        self._reserved[slot] = 0
        self.pos[slot] = 0
        self.slots.release(slot)

    # -- device compute -------------------------------------------------------
    def insert_prefill(self, prefilled: Cache, slots: List[int],
                       written_len: int) -> None:
        k = len(slots)
        rows = prefilled["pos"].shape[0]
        n_pages = self.pages_for(written_len)
        src_rows, page_ord, phys = [], [], []
        for i, slot in enumerate(slots):
            taken = self._take_pages(n_pages)
            self.table[slot, :n_pages] = taken
            self._alloc[slot] = n_pages
            self.pos[slot] = written_len
            for j, p in enumerate(taken):
                src_rows.append(i)
                page_ord.append(j)
                phys.append(p)
        # pad the scatter lists to a power of two by repeating the last real
        # entry (an identical duplicate write — harmless) so compiled splice
        # shapes stay bounded; same for the dense-row scatter.
        pad = next_pow2(len(phys))
        src_rows += [src_rows[-1]] * (pad - len(src_rows))
        page_ord += [page_ord[-1]] * (pad - len(page_ord))
        phys += [phys[-1]] * (pad - len(phys))
        dense_rows = list(range(k)) + [k - 1] * (rows - k)
        dense_slots = list(slots) + [slots[-1]] * (rows - k)
        prefilled = dict(prefilled)
        prefilled.pop("pos")
        self.blocks = self._splice_fn(
            self.blocks, prefilled,
            jnp.asarray(src_rows, jnp.int32), jnp.asarray(page_ord, jnp.int32),
            jnp.asarray(phys, jnp.int32), jnp.asarray(dense_rows, jnp.int32),
            jnp.asarray(dense_slots, jnp.int32))

    def _ensure_append(self, slot: int) -> None:
        """Map a physical page under position ``pos[slot]`` if the append
        crosses into a new logical page (reservation guarantees success)."""
        ordinal = int(self.pos[slot]) // self.page_size
        if ordinal >= int(self._alloc[slot]):
            assert ordinal == int(self._alloc[slot]) < int(self._reserved[slot])
            self.table[slot, ordinal] = self._take_pages(1)[0]
            self._alloc[slot] = ordinal + 1

    def decode(self, params, tokens, state, kmax,
               write_slots: Sequence[int]) -> np.ndarray:
        write_phys = np.zeros(self.max_slots, np.int32)   # default: null page
        write_off = np.zeros(self.max_slots, np.int32)
        for s in write_slots:
            self._ensure_append(s)
            write_phys[s] = self.table[s, int(self.pos[s]) // self.page_size]
            write_off[s] = int(self.pos[s]) % self.page_size
        next_tokens, self.blocks = self._decode_fn(
            params, jnp.asarray(tokens[:, None]), self.blocks,
            jnp.asarray(self.table), jnp.asarray(self.pos),
            jnp.asarray(write_phys), jnp.asarray(write_off), state, kmax)
        for s in write_slots:
            self.pos[s] += 1
        return np.asarray(next_tokens)

    def cache_nbytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.blocks))

    # -- sealing --------------------------------------------------------------
    def _page_arrays(self, phys: Sequence[int]) -> Dict[str, np.ndarray]:
        """Fetch the given physical pages of every paged leaf:
        keystr -> [L, n, page_size, ...]."""
        idx = jnp.asarray(list(phys), jnp.int32)
        out = {}

        def pull(path, leaf):
            if _keystr(path) in self._paged_paths:
                out[_keystr(path)] = np.asarray(leaf[:, idx])
            return leaf
        jax.tree_util.tree_map_with_path(pull, self.blocks)
        return out

    def _seal_pages(self, key: SealingKey, prefix: str, ordinals: Sequence[int],
                    phys: Sequence[int],
                    suffix: str = "") -> Dict[str, SealedTensor]:
        sealed: Dict[str, SealedTensor] = {}
        pages = self._page_arrays(phys)
        for kpath, arr in pages.items():
            for j, ordinal in enumerate(ordinals):
                name = f"{prefix}{kpath}/p{ordinal}{suffix}"
                sealed[name] = seal_tensor(key, name, arr[:, j])
        return sealed

    def seal(self, key, slot, prefix, suffix="") -> Dict[str, SealedTensor]:
        n_alloc = int(self._alloc[slot])
        phys = [int(p) for p in self.table[slot, :n_alloc]]
        meta_name = f"{prefix}/meta{suffix}"
        sealed = {meta_name: seal_tensor(
            key, meta_name,
            np.asarray([int(self.pos[slot]), n_alloc], np.int32))}
        sealed.update(self._seal_pages(key, prefix, range(n_alloc), phys,
                                       suffix))

        def pull_dense(path, leaf):
            if _keystr(path) not in self._paged_paths:
                name = f"{prefix}{_keystr(path)}{suffix}"
                sealed[name] = seal_tensor(key, name,
                                           np.asarray(leaf[:, slot:slot + 1]))
            return leaf
        jax.tree_util.tree_map_with_path(pull_dense, self.blocks)
        return sealed

    def restore(self, key, sealed, slot, prefix, n_tokens, suffix="") -> None:
        # the reservation was re-made when the engine re-acquired the slot
        # (acquire(rid, n_tokens)); here we only map and decrypt the pages.
        meta = np.asarray(unseal_tensor(key, sealed[f"{prefix}/meta{suffix}"]))
        pos, n_alloc = int(meta[0]), int(meta[1])
        assert n_alloc <= int(self._reserved[slot]), \
            "restore into a smaller reservation — accounting bug"
        taken = self._take_pages(n_alloc)
        self.table[slot, :n_alloc] = taken
        self._alloc[slot] = n_alloc
        self.pos[slot] = pos
        self._write_back(key, sealed, slot, prefix, range(n_alloc), taken,
                         dense_too=True, suffix=suffix)

    def _write_back(self, key, sealed, slot, prefix, ordinals, phys,
                    dense_too: bool, suffix: str = "") -> None:
        ordinals, phys = list(ordinals), list(phys)
        pad_ords, idx = [], None
        if ordinals:
            # pad the scatter to a power of two by repeating the last
            # (ordinal, phys) pair — an identical duplicate write — so the
            # jitted donated scatter compiles O(log max_pages) variants.
            pad = next_pow2(len(phys))
            pad_ords = ordinals + [ordinals[-1]] * (pad - len(ordinals))
            idx = jnp.asarray(phys + [phys[-1]] * (pad - len(phys)), jnp.int32)

        def put(path, leaf):
            kpath = _keystr(path)
            if kpath in self._paged_paths:
                if not ordinals:
                    return leaf
                pages = jnp.stack(
                    [unseal_tensor(key,
                                   sealed[f"{prefix}{kpath}/p{o}{suffix}"])
                     for o in pad_ords], axis=1)
                return _set_pages(leaf, idx, pages)
            if dense_too:
                row = unseal_tensor(key, sealed[f"{prefix}{kpath}{suffix}"])
                return _set_row(leaf, jnp.int32(slot), row)
            return leaf
        self.blocks = jax.tree_util.tree_map_with_path(put, self.blocks)

    # -- partial eviction -----------------------------------------------------
    def seal_tail_pages(self, key: SealingKey, slot: int, prefix: str,
                        n_pages: int,
                        suffix: str = "") -> Dict[str, SealedTensor]:
        """Seal and free the ``n_pages`` most recent pages of ``slot`` —
        a capacity loan: the pages AND their reservation go back to the
        pool for other traffic, while the victim keeps its slot, sampling
        row, and resident head pages. The victim must not decode until
        :meth:`restore_tail_pages` brings the delta back (the engine parks
        it out of the batch)."""
        n_alloc = int(self._alloc[slot])
        if not (0 < n_pages < n_alloc):
            raise ValueError(
                f"partial eviction wants 0 < n_pages < allocated "
                f"({n_alloc}), got {n_pages}")
        ordinals = list(range(n_alloc - n_pages, n_alloc))
        phys = [int(p) for p in self.table[slot, ordinals]]
        meta_name = f"{prefix}/pagemeta{suffix}"
        sealed = {meta_name: seal_tensor(
            key, meta_name, np.asarray([ordinals[0], n_pages], np.int32))}
        sealed.update(self._seal_pages(key, prefix, ordinals, phys, suffix))
        self.table[slot, ordinals] = 0
        self._alloc[slot] = n_alloc - n_pages
        self._free_pages.extend(phys)
        self._reserved[slot] -= n_pages
        self._reserve_free += n_pages
        return sealed

    def can_restore_tail(self, n_pages: int) -> bool:
        return n_pages <= self._reserve_free

    def restore_tail_pages(self, key: SealingKey,
                           sealed: Dict[str, SealedTensor], slot: int,
                           prefix: str, reserve: bool = True,
                           suffix: str = "") -> int:
        """Re-map and decrypt a partial eviction's pages; returns the page
        count. Physical placement is fresh — the table indirection makes
        relocation free. ``reserve=False`` skips re-reserving: used when the
        tail rides along a whole-slot restore whose ``acquire`` already
        reserved the sequence's full worst case."""
        meta = np.asarray(unseal_tensor(
            key, sealed[f"{prefix}/pagemeta{suffix}"]))
        start, n_pages = int(meta[0]), int(meta[1])
        if reserve:
            assert self.can_restore_tail(n_pages), \
                "restore_tail without can_restore_tail — accounting bug"
            self._reserved[slot] += n_pages
            self._reserve_free -= n_pages
        ordinals = list(range(start, start + n_pages))
        taken = self._take_pages(n_pages)
        self.table[slot, ordinals] = taken
        self._alloc[slot] = start + n_pages
        self._write_back(key, sealed, slot, prefix, ordinals, taken,
                         dense_too=False, suffix=suffix)
        return n_pages
