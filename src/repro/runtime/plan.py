"""ComputePlan — the engine's device-facing seam.

Everything the engine does *on devices* goes through one object: parameter
placement, the jitted prefill/decode callables, cache placement, and the
accounting for what the placement costs in cross-device traffic. The engine
and the KV backends speak to the plan; the plan decides whether that compute
lands on one device or spans a mesh.

Three plans:

  * :class:`SingleDevicePlan` — today's behavior, bit for bit: every
    ``compile_*`` is a plain ``jax.jit``, every ``place_*`` is the identity.

  * :class:`PrefillOnlyPlan` — the dedicated prefill stream of a
    disaggregated engine (``Engine(prefill_plan=...)``): it compiles the
    prefill callable only and refuses ``compile_decode`` outright. Finished
    prefill KV rows never stay on this plan — they cross to the decode
    plan through the engine's sealed handoff (a seal/restore pair priced
    in ``ChannelStats`` exactly like a preemption), so the plan boundary
    is a *trust* boundary the paper's Insight 9–12 cost model can account.

  * :class:`ShardedPlan` — one engine spans a ``jax`` mesh built from
    :func:`repro.launch.mesh.make_host_mesh` (axes ``("data", "model")``,
    via the :mod:`repro.distributed.compat` shims):

      - **batch** rows shard over the ``data`` axis (each device decodes
        ``max_slots / dp`` sequences);
      - **params** are placed per
        :func:`repro.distributed.sharding.param_specs` with FSDP forced on:
        sharded at rest over ``data``, all-gathered at use. That gather is
        deliberate — it makes the interconnect carry real per-step traffic
        (the weight-streaming flow a confidential deployment must encrypt),
        and because the gather reconstructs *full* weights before any
        matmul, per-row compute is unchanged and outputs stay
        **byte-identical** to the single-device plan. With ``tp > 1`` the
        TP dims of ``param_specs`` additionally partition over ``model``;
        XLA then all-reduces partial products, which is numerically
        equivalent but (like every TP system) not bitwise — parity tests
        pin ``dp``-only meshes;
      - the **KV cache** is placed per
        :func:`repro.distributed.sharding.cache_specs` (slot-dense layout)
        or batch-sharded dense leaves + a replicated page pool (paged
        layout — per-shard pools are a ROADMAP follow-on).

    The collective path is *instrumented*: the first compiled decode step
    is lowered once more and its SPMD-partitioned HLO parsed with
    :func:`repro.roofline.analysis.parse_collectives` for the bytes each
    device moves per step, and an ``all_gather`` of that volume runs under
    :func:`repro.distributed.compat.shard_map` on the real mesh to
    *measure* the per-step collective time. Both flow into
    ``ChannelStats.collective_bytes`` / ``collective_s`` (per decode step),
    which ``overheads.predict(collective_s=...)`` accepts in place of its
    closed-form estimate — the measured-vs-modeled link_tax comparison
    ``serve_bench.py --mesh`` reports.
"""

from __future__ import annotations

import time
from types import SimpleNamespace
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

Params = Any
Cache = Any


def parse_mesh(spec: str) -> Tuple[int, int]:
    """``"dp=2,tp=1"`` (or just ``"dp=2"``) -> ``(dp, tp)``."""
    if not spec or not spec.strip():
        raise ValueError(
            "empty mesh spec: want 'dp=N' or 'dp=N,tp=M' (omit the mesh "
            "argument entirely for single-device)")
    dp, tp = 1, 1
    try:
        for part in spec.split(","):
            if not part.strip():
                continue
            k, v = part.split("=")
            k = k.strip()
            if k == "dp":
                dp = int(v)
            elif k == "tp":
                tp = int(v)
            else:
                raise ValueError(k)
    except ValueError:
        raise ValueError(
            f"bad mesh spec {spec!r}: want 'dp=N' or 'dp=N,tp=M'")
    if dp < 1 or tp < 1:
        raise ValueError(f"mesh axes must be >= 1, got dp={dp}, tp={tp}")
    return dp, tp


class ComputePlan:
    """Base seam; also the single-device implementation contract."""

    is_sharded = False
    name = "single"

    def __init__(self, model):
        self.model = model
        # per-step collective cost, drained by the engine into ChannelStats
        self._pending_steps = 0
        self.collective_bytes_per_step = 0
        self.collective_s_per_step = 0.0

    # -- placement ----------------------------------------------------------
    def place_params(self, params: Params) -> Params:
        return params

    def place_dense_cache(self, cache: Cache) -> Cache:
        return cache

    def place_paged_cache(self, blocks: Cache, paged_paths) -> Cache:
        return blocks

    # -- compiled callables --------------------------------------------------
    def compile_prefill(self):
        model = self.model

        def _prefill(params, tokens, cache):
            return model.prefill(params, {"tokens": tokens}, cache)

        return jax.jit(_prefill)

    def compile(self, fn, *, donate_argnums=(), static_argnums=()):
        """Non-decode device work (prefill splices, scatters)."""
        return jax.jit(fn, donate_argnums=donate_argnums,
                       static_argnums=static_argnums)

    def compile_decode(self, fn, *, donate_argnums=(), static_argnums=()):
        """The backend's batched decode step. Sharded plans additionally
        count each call's collective cost (see :meth:`drain_collectives`)."""
        return jax.jit(fn, donate_argnums=donate_argnums,
                       static_argnums=static_argnums)

    # -- collective accounting ----------------------------------------------
    def drain_collectives(self) -> Tuple[int, int, float]:
        """(steps, bytes, seconds) of collective cost accrued since the last
        drain. The engine feeds this into TrustDomain.record_collective."""
        n, self._pending_steps = self._pending_steps, 0
        return (n, n * self.collective_bytes_per_step,
                n * self.collective_s_per_step)

    def shard_of_slot(self, slot: int, max_slots: int) -> int:
        return 0


class SingleDevicePlan(ComputePlan):
    """Exactly the pre-plan engine: plain ``jax.jit``, no placement."""


class PrefillOnlyPlan(ComputePlan):
    """A plan compiled for the prefill phase only — the prefill half of a
    disaggregated ``Engine(prefill_plan=...)``. Prompts prefill here
    (asynchronously, via jax's dispatch queue) while the decode plan keeps
    stepping; the finished KV rows leave through the engine's sealed
    plan-to-plan handoff rather than by sharing device state, so this plan
    deliberately has no decode surface at all."""

    name = "prefill-only"

    def compile_decode(self, fn, *, donate_argnums=(), static_argnums=()):
        raise RuntimeError(
            "PrefillOnlyPlan compiles no decode step: it is the dedicated "
            "prefill stream of a disaggregated engine, and finished KV rows "
            "hand off to the decode plan through the sealed channel "
            "(Engine(prefill_plan=...))")


class ShardedPlan(ComputePlan):
    is_sharded = True
    name = "sharded"

    def __init__(self, model, *, dp: int = 1, tp: int = 1,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 probe_iters: int = 16):
        super().__init__(model)
        # imports deferred so a single-device engine never touches the
        # distributed stack (and plan.py stays import-cycle-free).
        from repro.launch.mesh import make_host_mesh

        if mesh is None:
            n = len(jax.devices())
            if dp * tp > n:
                raise ValueError(
                    f"mesh dp={dp},tp={tp} needs {dp * tp} devices but jax "
                    f"sees {n}; set XLA_FLAGS=--xla_force_host_platform_"
                    f"device_count={dp * tp} (before jax initializes) or "
                    f"shrink the mesh")
            mesh = make_host_mesh(data=dp, model=tp)
        self.mesh = mesh
        self.dp = int(mesh.shape["data"])
        self.tp = int(mesh.shape["model"])
        self.probe_iters = probe_iters
        # param_specs with FSDP forced on (see module docstring): the spec
        # table only reads cfg.parallel.{fsdp, dp_over_model}.
        self._spec_cfg = SimpleNamespace(parallel=SimpleNamespace(
            fsdp=True, dp_over_model=model.cfg.parallel.dp_over_model,
            zero1=False))
        self._analyzed = False

    @classmethod
    def from_spec(cls, model, spec: str) -> "ShardedPlan":
        dp, tp = parse_mesh(spec)
        return cls(model, dp=dp, tp=tp)

    def describe(self) -> str:
        return f"dp={self.dp},tp={self.tp} ({self.mesh.size} devices)"

    # -- placement ----------------------------------------------------------
    def _put(self, tree, spec_tree):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            tree, spec_tree, is_leaf=lambda x: isinstance(x, P))

    def place_params(self, params: Params) -> Params:
        from repro.distributed import sharding
        specs = sharding.param_specs(self._spec_cfg,
                                     self.model.abstract_params(), self.mesh)
        return self._put(params, specs)

    def place_dense_cache(self, cache: Cache) -> Cache:
        from repro.distributed import sharding
        specs = sharding.cache_specs(self._spec_cfg, cache, self.mesh)
        return self._put(cache, specs)

    def place_paged_cache(self, blocks: Cache, paged_paths) -> Cache:
        """Pool leaves (pages shared by every sequence) replicate; the
        slot-dense remainder ([L, slots, ...] recurrent state) shards its
        batch dim over ``data`` when it divides. Prefix-sharing state
        (content index, per-page refcounts, parked ciphertext) is
        host-side and engine-global, so a replicated pool shares pages
        across every data-shard's sequences for free; per-shard pools
        (ROADMAP) will need the index keyed per shard. Sealing stays
        nonce-safe either way: per-epoch names carry the ``/s{shard}``
        suffix, and parked shared pages use content-derived names whose
        repeat sealing is deterministic (same plaintext, same ciphertext)."""
        def spec_for(path, leaf):
            if jax.tree_util.keystr(path) in paged_paths:
                return P(*([None] * leaf.ndim))
            if leaf.ndim >= 2 and leaf.shape[1] % self.dp == 0:
                return P(None, "data", *([None] * (leaf.ndim - 2)))
            return P(*([None] * leaf.ndim))

        specs = jax.tree_util.tree_map_with_path(spec_for, blocks)
        return self._put(blocks, specs)

    # -- compiled callables --------------------------------------------------
    def compile_prefill(self):
        model, plan = self.model, self

        def _prefill(params, tokens, cache):
            return model.prefill(params, {"tokens": tokens}, cache)

        jitted = jax.jit(_prefill)

        def run(params, tokens, cache):
            rows = tokens.shape[0]
            if rows % plan.dp == 0:
                tokens = jax.device_put(
                    tokens, NamedSharding(plan.mesh, P("data", None)))
                cache = plan._put(cache, jax.tree_util.tree_map_with_path(
                    plan._prefill_cache_spec, cache))
            return jitted(params, tokens, cache)

        return run

    def _prefill_cache_spec(self, path, leaf):
        if any(getattr(k, "key", None) == "pos" for k in path[:1]):
            return P("data")
        return P(None, "data", *([None] * (leaf.ndim - 2)))

    def compile_decode(self, fn, *, donate_argnums=(), static_argnums=()):
        jitted = jax.jit(fn, donate_argnums=donate_argnums,
                         static_argnums=static_argnums)
        plan = self

        def run(*args):
            if not plan._analyzed:
                plan._analyze(jitted, args)
            out = jitted(*args)
            plan._pending_steps += 1
            return out

        return run

    # -- collective instrumentation ------------------------------------------
    def _analyze(self, jitted, args) -> None:
        """Parse the SPMD-partitioned HLO of the first compiled decode
        variant for per-device collective bytes/step, then *measure* that
        volume's all-gather time on the real mesh. One extra compile, once
        per engine; later sampling variants share the calibration (their
        collective profile is the same param gather)."""
        self._analyzed = True
        try:
            from repro.roofline.analysis import parse_collectives
            hlo = jitted.lower(*args).compile().as_text()
            ops = parse_collectives(hlo)
            self.collective_bytes_per_step = int(
                sum(op.moved_bytes for op in ops))
        except Exception as e:  # pragma: no cover - AOT text is best-effort
            # degrade loudly: a silent zero here would make the measured
            # link-tax report claim "0 B/step" as if it were an observation.
            print(f"[mesh] WARNING: collective HLO analysis failed ({e!r}); "
                  f"collective_bytes/collective_s will read 0")
            self.collective_bytes_per_step = 0
        self.collective_s_per_step = self.measure_collective_s(
            self.collective_bytes_per_step)

    def measure_collective_s(self, nbytes: int, iters: Optional[int] = None
                             ) -> float:
        """Time a real collective of ``nbytes`` (per device) on this mesh:
        an ``all_gather`` under ``shard_map``, the measured stand-in for the
        decode step's gather traffic. Returns seconds per step."""
        if nbytes <= 0 or self.mesh.size < 2:
            return 0.0
        from repro.distributed.compat import shard_map
        iters = iters or self.probe_iters
        n_dev = self.mesh.size
        axes = tuple(self.mesh.axis_names)
        elems = max(nbytes // 4, n_dev)
        elems -= elems % n_dev
        x = jax.device_put(
            jnp.zeros((elems,), jnp.float32),
            NamedSharding(self.mesh, P(axes)))

        def gather(local):
            return jax.lax.all_gather(local, axes, axis=0, tiled=True)

        f = jax.jit(shard_map(gather, mesh=self.mesh, in_specs=P(axes),
                              out_specs=P(None), check_vma=False))
        f(x).block_until_ready()           # compile outside the clock
        t0 = time.perf_counter()
        for _ in range(iters):
            f(x).block_until_ready()
        return (time.perf_counter() - t0) / iters

    def shard_of_slot(self, slot: int, max_slots: int) -> int:
        """Which data-shard (device index along ``data``) holds this slot's
        cache row — the ``/s{shard}`` suffix per-shard sealing records."""
        if max_slots % self.dp != 0:
            return 0               # cache fell back to replication
        return int(slot) // (max_slots // self.dp)
