"""Token sampling: greedy / temperature / top-k, jit-friendly."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits [b, v] -> token ids [b]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, key, temp: float = 1.0,
                top_k: int = 0) -> jax.Array:
    if temp <= 0:
        return greedy(logits)
    scaled = logits.astype(jnp.float32) / temp
    if top_k > 0:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, -1e30)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
