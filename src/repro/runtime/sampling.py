"""Token sampling: greedy / temperature / top-k, jit-friendly.

Two entry points:

  * :func:`temperature` — single distribution, scalar settings (tests,
    offline tools).
  * :func:`sample` — the engine's batched path: every decode step samples
    all slots at once, each with its own temperature/top-k/PRNG key carried
    in a :class:`SamplingState` of ``[slots]``-shaped arrays. Greedy slots
    (``temp <= 0``) and sampled slots coexist in one call.

Top-k uses ``jax.lax.top_k`` (O(v·k) selection) rather than a full
``jnp.sort`` (O(v log v) over the whole vocabulary per step). ``top_k``
must be < vocab_size — a request asking for a full-vocab "restriction"
should say ``top_k=0``; anything >= vocab is an error, not a silent clamp.

Reproducibility: the per-slot key is the request's seed-derived base key;
:func:`sample` folds the output-token index into it each step. The fold-in
depends only on (seed, token index), so a seeded request re-samples
identically after a sealed-KV preemption/restore, regardless of which
engine step the token lands on.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_MASKED = -1e30   # large-negative logit mask (f32-safe, softmax-zero)


class SamplingState(NamedTuple):
    """Per-slot sampling parameters, shaped ``[slots]`` (a pytree the jitted
    decode step takes as one argument; see ``kvcache.SlotState`` for the
    host-side mirror)."""
    temp: jax.Array    # [b] f32; <= 0 selects greedy for that slot
    top_k: jax.Array   # [b] i32; 0 = unrestricted
    key: jax.Array     # [b, 2] u32 per-request base PRNG keys
    step: jax.Array    # [b] i32 output-token index (folded into the key)


def greedy(logits: jax.Array) -> jax.Array:
    """logits [b, v] -> token ids [b]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, key, temp: float = 1.0,
                top_k: int = 0) -> jax.Array:
    """Scalar-setting sampling for a whole batch (one shared distribution
    policy). ``temp <= 0`` is greedy."""
    if temp <= 0:
        return greedy(logits)
    vocab = logits.shape[-1]
    if top_k >= vocab:
        raise ValueError(
            f"top_k={top_k} must be < vocab_size={vocab}; "
            f"use top_k=0 for an unrestricted distribution")
    scaled = logits.astype(jnp.float32) / temp
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][:, -1:]   # [b, 1]
        scaled = jnp.where(scaled >= kth, scaled, _MASKED)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, state: SamplingState, *, kmax: int = 0) -> jax.Array:
    """Batched per-slot sampling: logits [b, v] + state [b] -> tokens [b].

    ``kmax`` is the *static* upper bound on any slot's ``top_k`` this call
    (the engine rounds the active maximum up to a power of two, so compiled
    variants stay bounded by log2(vocab)). ``kmax=0`` compiles the
    no-top-k path. Per-slot behavior:

      * ``temp <= 0``  → argmax (ignores key/top_k),
      * ``top_k == 0`` → full-distribution sampling,
      * else           → restricted to that slot's top_k logits.
    """
    greedy_toks = greedy(logits)
    # guard the divide for greedy rows (their sampled value is discarded)
    scaled = logits.astype(jnp.float32) / jnp.maximum(state.temp, 1e-6)[:, None]
    if kmax > 0:
        kmax = min(int(kmax), logits.shape[-1])
        vals = jax.lax.top_k(scaled, kmax)[0]                    # [b, kmax]
        idx = jnp.clip(state.top_k - 1, 0, kmax - 1)
        kth = jnp.take_along_axis(vals, idx[:, None], axis=-1)   # [b, 1]
        restricted = jnp.where(scaled >= kth, scaled, _MASKED)
        scaled = jnp.where(state.top_k[:, None] > 0, restricted, scaled)
    keys = jax.vmap(jax.random.fold_in)(state.key, state.step)
    sampled = jax.vmap(lambda k, row: jax.random.categorical(k, row))(keys, scaled)
    return jnp.where(state.temp > 0, sampled, greedy_toks).astype(jnp.int32)
