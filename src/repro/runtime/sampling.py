"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly.

Two entry points:

  * :func:`temperature` — single distribution, scalar settings (tests,
    offline tools).
  * :func:`sample` — the engine's batched path: every decode step samples
    all slots at once, each with its own temperature/top-k/top-p/PRNG key
    carried in a :class:`SamplingState` of ``[slots]``-shaped arrays. Greedy
    slots (``temp <= 0``) and sampled slots coexist in one call.

Top-k uses ``jax.lax.top_k`` (O(v·k) selection) rather than a full
``jnp.sort`` (O(v log v) over the whole vocabulary per step). ``top_k``
must be < vocab_size — a request asking for a full-vocab "restriction"
should say ``top_k=0``; anything >= vocab is an error, not a silent clamp.

Repetition and presence penalties are ``[slots]`` rows like top-p:
``rep_pen`` is *frequency-weighted* CTRL — each occurrence compounds, so a
token generated ``c`` times has its positive logits divided (negative
multiplied) by ``rep_pen ** c`` (``c = 0`` gives the exact neutral 1.0, so
no seen-mask is needed); ``presence`` subtracts a flat amount from every
already-generated token regardless of count. Both read the per-slot
generated-token counts in ``hist`` and both are static-``None`` gated so
their math only compiles when some slot uses them. History follows the
*request* (rebuilt from its output list after a sealed restore), so seeded
penalized requests reproduce byte-identically across preemption.

Per-request logit-bias maps ride the same machinery: ``bias`` is a
``[slots, vocab]`` additive row matrix (sparse maps densified host-side,
see ``SlotState``), added to the raw logits before the penalties, and
static-``None`` gated like them. Bias is static per request — rebuilt from
``SamplingParams.logit_bias`` whenever the slot's sampling row is set, so a
sealed restore reproduces it exactly like the penalty history.

Top-p (nucleus) keeps the smallest set of tokens whose cumulative
probability reaches ``top_p`` (the first token is always kept). It needs a
full descending sort, so the engine only threads a ``top_p`` array into the
state when some slot actually restricts (``top_p < 1``) — ``top_p=None``
state compiles the sort-free path, and the all-greedy ``state=None`` fast
path is untouched. Top-k and top-p compose (intersection of supports).

Reproducibility: the per-slot key is the request's seed-derived base key;
:func:`sample` folds the output-token index into it each step. The fold-in
depends only on (seed, token index), so a seeded request re-samples
identically after a sealed-KV preemption/restore, regardless of which
engine step the token lands on.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

_MASKED = -1e30   # large-negative logit mask (f32-safe, softmax-zero)


class SamplingState(NamedTuple):
    """Per-slot sampling parameters, shaped ``[slots]`` (a pytree the jitted
    decode step takes as one argument; see ``kvcache.SlotState`` for the
    host-side mirror). ``top_p=None`` (a static pytree difference) selects
    the nucleus-free compiled variant; the penalty rows (``rep_pen``,
    ``presence``) and the ``hist`` token-count matrix they act on gate the
    same way — an engine that never uses penalties never compiles them."""
    temp: jax.Array    # [b] f32; <= 0 selects greedy for that slot
    top_k: jax.Array   # [b] i32; 0 = unrestricted
    key: jax.Array     # [b, 2] u32 per-request base PRNG keys
    step: jax.Array    # [b] i32 output-token index (folded into the key)
    top_p: Optional[jax.Array] = None   # [b] f32; None/1.0 = unrestricted
    rep_pen: Optional[jax.Array] = None   # [b] f32; None/1.0 = off
    presence: Optional[jax.Array] = None  # [b] f32; None/0.0 = off
    hist: Optional[jax.Array] = None      # [b, v] i32 generated-token counts
    bias: Optional[jax.Array] = None      # [b, v] f32 additive logit bias


def greedy(logits: jax.Array) -> jax.Array:
    """logits [b, v] -> token ids [b]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _nucleus_mask(scaled: jax.Array, top_p: jax.Array) -> jax.Array:
    """Restrict each row of ``scaled`` logits to its nucleus: the smallest
    descending-probability prefix whose cumulative mass reaches that row's
    ``top_p``. Rows with ``top_p >= 1`` pass through unrestricted."""
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep a token while the mass BEFORE it is < top_p: the first token is
    # always kept, and the token that crosses the threshold is included.
    keep = (cum - probs) < top_p[:, None]
    thresh = jnp.min(jnp.where(keep, sorted_desc, jnp.inf),
                     axis=-1, keepdims=True)
    restricted = jnp.where(scaled >= thresh, scaled, _MASKED)
    return jnp.where(top_p[:, None] < 1.0, restricted, scaled)


def temperature(logits: jax.Array, key, temp: float = 1.0,
                top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """Scalar-setting sampling for a whole batch (one shared distribution
    policy). ``temp <= 0`` is greedy."""
    if temp <= 0:
        return greedy(logits)
    vocab = logits.shape[-1]
    if top_k >= vocab:
        raise ValueError(
            f"top_k={top_k} must be < vocab_size={vocab}; "
            f"use top_k=0 for an unrestricted distribution")
    if not (0.0 < top_p <= 1.0):
        raise ValueError(f"top_p must be in (0, 1], got {top_p}")
    scaled = logits.astype(jnp.float32) / temp
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][:, -1:]   # [b, 1]
        scaled = jnp.where(scaled >= kth, scaled, _MASKED)
    if top_p < 1.0:
        scaled = _nucleus_mask(scaled, jnp.full(scaled.shape[0], top_p,
                                                jnp.float32))
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, state: SamplingState, *, kmax: int = 0) -> jax.Array:
    """Batched per-slot sampling: logits [b, v] + state [b] -> tokens [b].

    ``kmax`` is the *static* upper bound on any slot's ``top_k`` this call
    (the engine rounds the active maximum up to a power of two, so compiled
    variants stay bounded by log2(vocab)). ``kmax=0`` compiles the
    no-top-k path. Per-slot behavior:

      * ``temp <= 0``  → argmax (ignores key/top_k/top_p),
      * ``top_k == 0`` → no top-k restriction,
      * ``top_p`` absent or 1 → no nucleus restriction,
      * else the support is the intersection of both restrictions.
    """
    greedy_toks = greedy(logits)
    logits_f = logits.astype(jnp.float32)
    # per-request logit bias lands first: it shifts the raw distribution the
    # penalties then act on, matching the usual "bias, then penalize" order.
    if state.bias is not None:
        logits_f = logits_f + state.bias
    # repetition / presence penalties act on the raw logits (before the
    # temperature divide) over tokens this sequence has already GENERATED
    # (``hist`` counts; the prompt is not penalized). Both are per-slot rows
    # and both no-op at their neutral values, so a fresh slot inherits
    # nothing from a released one.
    if state.rep_pen is not None:
        # frequency-weighted CTRL: each prior occurrence compounds, so a
        # count of c applies rep_pen**c (c=0 gives exactly 1.0 — no seen
        # mask needed). The clip guards rp**c overflow for long sequences.
        rp_pow = jnp.clip(
            jnp.power(state.rep_pen[:, None], state.hist.astype(jnp.float32)),
            1e-30, 1e30)
        logits_f = jnp.where(logits_f > 0, logits_f / rp_pow,
                             logits_f * rp_pow)
    if state.presence is not None:
        logits_f = logits_f - state.presence[:, None] * (state.hist > 0)
    # guard the divide for greedy rows (their sampled value is discarded;
    # greedy rows also ignore penalties — argmax is over the raw logits)
    scaled = logits_f / jnp.maximum(state.temp, 1e-6)[:, None]
    if kmax > 0:
        kmax = min(int(kmax), logits.shape[-1])
        vals = jax.lax.top_k(scaled, kmax)[0]                    # [b, kmax]
        idx = jnp.clip(state.top_k - 1, 0, kmax - 1)
        kth = jnp.take_along_axis(vals, idx[:, None], axis=-1)   # [b, 1]
        restricted = jnp.where(scaled >= kth, scaled, _MASKED)
        scaled = jnp.where(state.top_k[:, None] > 0, restricted, scaled)
    if state.top_p is not None:
        # applied after top-k so the nucleus is measured over the already-
        # restricted distribution (masked logits carry ~0 mass).
        scaled = _nucleus_mask(scaled, state.top_p)
    keys = jax.vmap(jax.random.fold_in)(state.key, state.step)
    sampled = jax.vmap(lambda k, row: jax.random.categorical(k, row))(keys, scaled)
    return jnp.where(state.temp > 0, sampled, greedy_toks).astype(jnp.int32)
