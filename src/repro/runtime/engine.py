"""Inference engine v2: streaming, bucketed batched prefill, sealed preemption.

Dataflow per paper Fig 2's protected stack:
  prompt --(encrypted bounce buffer)--> bucketed batched prefill(slots)
  --> batched decode loop --> each sampled token --(one encrypted frame per
  token through the bounce buffer)--> client, immediately.

Three serving-path upgrades over v1:

  * **Streaming egress** — every sampled token leaves the trust domain the
    moment it exists, as a per-token encrypted frame with a per-request
    stream id and a session-sequenced nonce (``BounceBuffer.device_send_frame``).
    ``ChannelStats`` therefore measures the fixed-cost-dominated boundary
    traffic the paper's cgpu profile models (Insight 10), and clients get
    tokens at next-token latency instead of at request completion.

  * **Bucketed batched prefill** — instead of one static ``prefill_len``
    (which silently truncated longer prompts), prompts are rounded up to a
    small set of power-of-two buckets; same-bucket waiting requests are
    prefixed together in one jitted prefill call (recompilation bounded by
    |buckets| x log2(max_slots) shapes). A prompt longer than its bucket is
    *chunked*: the first ``bucket`` tokens go through prefill, the tail rides
    the batched decode loop one token per step (decode-aligned prefill), so
    nothing is ever dropped.

  * **Priority admission + sealed-KV preemption** — the scheduler pops the
    highest-priority waiting request; when no slot is free, a strictly
    lower-priority running request is evicted through ``seal_slot`` (its KV
    pages leave the domain only as ChaCha20+HMAC ciphertext, paper §V-D3)
    and transparently restored via ``restore_slot`` when capacity returns.

All device compute is jitted once per shape; decode donates the cache to
keep a single in-place buffer. Finished slots are refilled without stopping
decode (continuous batching).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.confidential import TrustDomain
from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.kvcache import (SlotState, extract_slot as kv_extract,
                                   insert_rows, insert_slot)
from repro.runtime.scheduler import Request, Scheduler, ServeStats, TokenCallback

Params = Any


@dataclasses.dataclass
class PreemptedRequest:
    """A sealed-out request waiting for a slot: KV pages as ciphertext only."""
    sealed: Dict[str, Any]
    req: Request


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class Engine:
    def __init__(self, model: Model, params: Params, *, max_slots: int = 4,
                 max_len: int = 512, trust_domain: Optional[TrustDomain] = None,
                 prefill_len: int = 64,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 batch_prefill: bool = True):
        """``prefill_buckets`` supersedes the v1 single static ``prefill_len``
        (kept as the default one-bucket config for compatibility). Buckets
        should be powers of two; each distinct (rows, bucket) prefill shape
        compiles once. ``batch_prefill=False`` restores v1's one-request-per-
        prefill-call behavior (the serve_bench baseline)."""
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        if prefill_buckets is None:
            prefill_buckets = (prefill_len,)
        self.prefill_buckets = tuple(sorted({int(b) for b in prefill_buckets}))
        if not self.prefill_buckets or min(self.prefill_buckets) < 1:
            raise ValueError(f"bad prefill buckets {self.prefill_buckets}")
        if max(self.prefill_buckets) >= max_len:
            raise ValueError("largest prefill bucket must leave decode room "
                             f"({self.prefill_buckets} vs max_len={max_len})")
        self.batch_prefill = batch_prefill
        self.td = trust_domain or TrustDomain("none")
        self.scheduler = Scheduler()
        self.slots = SlotState.create(max_slots)
        self.cache = model.init_cache(max_slots, max_len)
        self._active_mask = np.zeros(max_slots, bool)
        self._last_token = np.zeros(max_slots, np.int32)
        self._preempted: List[PreemptedRequest] = []

        cfg = model.cfg

        def _prefill(params, tokens, cache):
            return model.prefill(params, {"tokens": tokens}, cache)

        def _decode(params, tokens, cache):
            logits, cache = model.decode_step(params, tokens, cache)
            return sampling.greedy(logits), cache

        self._prefill_fn = jax.jit(_prefill)
        self._decode_fn = jax.jit(_decode, donate_argnums=(2,))
        self._vocab = cfg.vocab_size

    # -- request admission ----------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None, *, priority: int = 0,
               on_token: Optional[TokenCallback] = None) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if max_new_tokens < 1:
            # the prefill-produced first token always exists; a request that
            # asked for zero would still emit (and egress) it.
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        # worst-case KV positions: the padded prefill bucket (or the full
        # prompt when chunked past it) plus one per decode *input* — the
        # final sampled token is emitted but never fed back, so it writes no
        # KV. Past max_len, dynamic_update_slice would clamp onto the last
        # cache row and silently corrupt the sequence — reject up front,
        # BEFORE the prompt crosses the boundary (a rejected request must
        # not skew ChannelStats).
        need = (max(self._bucket_for(len(prompt)), len(prompt))
                + max_new_tokens - 1)
        if need > self.max_len:
            raise ValueError(
                f"request needs up to {need} KV positions "
                f"(prompt {len(prompt)} + {max_new_tokens} new) "
                f"but max_len={self.max_len}; shorten the prompt or "
                f"raise max_len")
        prompt = self.td.ingress(prompt)
        req = self.scheduler.submit(prompt, max_new_tokens, eos_id,
                                    priority=priority, on_token=on_token)
        req.stream_id = self.td.open_stream()
        return req

    def prompt_budget(self, max_new_tokens: int) -> int:
        """Longest prompt submit() will accept for ``max_new_tokens``.
        Accounts for bucket padding: a short prompt still occupies its whole
        (left-padded) prefill bucket in the KV cache."""
        cand = self.max_len - max_new_tokens + 1   # last token writes no KV
        if cand >= self.prefill_buckets[-1]:
            return cand
        fits = [b for b in self.prefill_buckets if b <= cand]
        return fits[-1] if fits else 0

    def _bucket_for(self, prompt_len: int) -> int:
        """Smallest bucket that fits the prompt, else the largest bucket
        (the tail past it is chunked through decode steps)."""
        for b in self.prefill_buckets:
            if prompt_len <= b:
                return b
        return self.prefill_buckets[-1]

    def _emit_token(self, slot: int, tok: int) -> bool:
        """Record one sampled token: per-token encrypted egress frame, stream
        callback, termination check. Returns True if the request finished."""
        req = self.scheduler.running[slot]
        tok = self.td.egress_token(req.stream_id, tok)
        self.scheduler.record_token(slot, tok)
        self._last_token[slot] = tok
        if req.done:
            # check immediately after recording: a max_new_tokens=1 request
            # (or EOS as the very first token) releases its slot without
            # paying for a wasted decode step (v1 off-by-one).
            self.scheduler.finish(slot)
            self.slots.release(slot)
            self._active_mask[slot] = False
            self.td.close_stream(req.stream_id)
            return True
        return False

    def _admit_batch(self) -> int:
        """Pop waiting requests sharing the head's prefill bucket (bounded by
        free slots) and prefill them in one jitted call."""
        head = self.scheduler.peek_waiting()
        if head is None or not self.slots.free:
            return 0
        bucket = self._bucket_for(len(head.prompt))
        group: List[Request] = [self.scheduler.next_waiting()]
        if self.batch_prefill:
            # group-mates must not jump the restore queue: a sealed-out
            # request with priority >= theirs gets the free slot first
            # (the head itself already outranked every sealed request, or
            # _admit_ready would have taken the restore branch).
            best_sealed = max((p.req.priority for p in self._preempted),
                              default=None)
            while len(group) < len(self.slots.free):
                nxt = self.scheduler.peek_waiting()
                if nxt is None or self._bucket_for(len(nxt.prompt)) != bucket:
                    break
                if best_sealed is not None and nxt.priority <= best_sealed:
                    break
                group.append(self.scheduler.next_waiting())

        # rows padded to a power of two so compiled prefill shapes stay
        # bounded: |buckets| x log2(max_slots) variants, not one per batch.
        rows = _next_pow2(len(group))
        tokens = np.zeros((rows, bucket), np.int32)
        for i, req in enumerate(group):
            chunk = req.prompt[:bucket]
            tokens[i, bucket - len(chunk):] = chunk   # left-pad short prompts
        fresh = self.model.init_cache(rows, self.max_len)
        logits, prefilled = self._prefill_fn(self.params, jnp.asarray(tokens),
                                             fresh)
        first_np = np.argmax(np.asarray(logits), axis=-1)

        slots = [self.slots.acquire(req.rid) for req in group]
        assert None not in slots, "admission raced free-slot accounting"
        # one donated scatter for the whole group (not k full-cache copies)
        self.cache = insert_rows(self.cache, prefilled,
                                 jnp.asarray(slots, jnp.int32))
        for i, req in enumerate(group):
            slot = slots[i]
            self.scheduler.start(slot, req)
            self._active_mask[slot] = True
            if len(req.prompt) > bucket:
                # chunked prefill: the tail is fed through the decode loop,
                # one token per step, before any sampling counts as output.
                req.pending_input = [int(t) for t in req.prompt[bucket:]]
                self._last_token[slot] = 0   # unused until the tail drains
            else:
                self._emit_token(slot, int(first_np[i]))
        return len(group)

    def _preempt_lowest(self, incoming: Request) -> bool:
        """Seal out the lowest-priority running slot if ``incoming`` strictly
        outranks it. Returns True if a slot was freed."""
        if not self.scheduler.running:
            return False
        victim_slot = min(self.scheduler.running,
                          key=lambda s: (self.scheduler.running[s].priority,
                                         -self.scheduler.running[s].rid))
        victim = self.scheduler.running[victim_slot]
        if victim.priority >= incoming.priority:
            return False
        sealed, vreq = self.seal_slot(victim_slot)
        vreq.n_preemptions += 1
        self._preempted.append(PreemptedRequest(sealed, vreq))
        return True

    def _admit_ready(self) -> None:
        """Admission policy, run at the top of every step:
        1. restore sealed-out requests while no waiting request outranks them,
        2. batch-admit waiting requests into free slots (bucket-grouped),
        3. preempt a strictly lower-priority running request when the waiting
           head cannot get a slot otherwise (preempted requests never trigger
           further preemption — bounded, no thrash)."""
        while True:
            if self._preempted and self.slots.free:
                best = max(self._preempted,
                           key=lambda p: (p.req.priority, -p.req.rid))
                head = self.scheduler.peek_waiting()
                if head is None or head.priority <= best.req.priority:
                    self._preempted.remove(best)
                    self.restore_slot(best.sealed, best.req)
                    continue
            if self.scheduler.queue and self.slots.free:
                self._admit_batch()
                continue
            head = self.scheduler.peek_waiting()
            if (head is not None and not self.slots.free
                    and self._preempt_lowest(head)):
                continue
            return

    # -- serving loop ----------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admission/restoration/preemption, then one
        batched decode step. Returns number of *output* tokens produced
        (prompt-chunk feeding steps count zero)."""
        self._admit_ready()
        if not self.slots.active:
            return 0
        feeding_prompt = {}   # slot -> tail still pending after this step?
        for slot in self.slots.active:
            req = self.scheduler.running.get(slot)
            if req is not None and req.pending_input:
                self._last_token[slot] = req.pending_input.pop(0)
                feeding_prompt[slot] = bool(req.pending_input)
        tokens = jnp.asarray(self._last_token[:, None])
        next_tokens, self.cache = self._decode_fn(self.params, tokens, self.cache)
        next_np = np.asarray(next_tokens)
        produced = 0
        for slot in list(self.slots.active):
            if not self._active_mask[slot]:
                continue
            if feeding_prompt.get(slot, False):
                continue   # mid-prompt chunk: this step's sample is discarded
            self._emit_token(slot, int(next_np[slot]))
            produced += 1
        return produced

    @property
    def idle(self) -> bool:
        return self.scheduler.idle and not self._preempted

    def run(self, max_steps: int = 10_000) -> ServeStats:
        steps = 0
        while not self.idle and steps < max_steps:
            self.step()
            steps += 1
        return self.scheduler.stats()

    # -- sealed KV preemption ----------------------------------------------------
    # The KV cache holds user conversation state; when a slot is preempted
    # (priority eviction, host maintenance) its pages must not land anywhere
    # unencrypted — the at-rest property H100 HBM lacks (paper §V-D3). The
    # slot cache is sealed with the domain key and can be restored later.

    def seal_slot(self, slot: int) -> Tuple[Dict[str, Any], Request]:
        """Evict a running slot: returns (sealed_cache_dict, request). Any
        not-yet-prefilled prompt tail travels on ``request.pending_input``."""
        from repro.core.sealing import seal_tree
        single = kv_extract(self.cache, jnp.int32(slot))
        req = self.scheduler.running.pop(slot)
        # the nonce-deriving name must be unique across every seal the domain
        # ever performs: the channel-global stream id (never reused, unlike
        # per-engine rids) plus a per-request seal epoch — a request
        # preempted twice holds different KV contents each time, and a
        # stream cipher must never encrypt two plaintexts under one nonce.
        sealed = seal_tree(self.td.sealing_key, single,
                           prefix=f"kvslot/{req.stream_id}/{req.seal_epoch}")
        req.seal_epoch += 1
        self.td._log("seal_kv",
                     f"slot={slot} rid={req.rid} stream={req.stream_id} "
                     f"epoch={req.seal_epoch - 1}")
        self.slots.release(slot)
        self._active_mask[slot] = False
        return sealed, req

    def restore_slot(self, sealed, req: Request) -> int:
        """Re-admit a sealed-out request into a free slot."""
        from repro.core.sealing import unseal_tree
        slot = self.slots.acquire(req.rid)
        if slot is None:
            raise RuntimeError("no free slot to restore into")
        single_like = self.model.abstract_cache(1, self.max_len)
        single = unseal_tree(self.td.sealing_key, sealed, single_like,
                             prefix=f"kvslot/{req.stream_id}/{req.seal_epoch - 1}")
        self.cache = insert_slot(self.cache, single, jnp.int32(slot))
        self.scheduler.running[slot] = req
        self._active_mask[slot] = True
        # next decode input: the prompt tail (if chunked prefill was cut
        # short) takes precedence in step(); otherwise the last output token.
        self._last_token[slot] = req.output[-1] if req.output else 0
        self.td._log("restore_kv", f"slot={slot} rid={req.rid}")
        return slot

    # -- convenience -----------------------------------------------------------
    def generate(self, prompt: np.ndarray, max_new_tokens: int = 32,
                 eos_id: Optional[int] = None) -> List[int]:
        req = self.submit(prompt, max_new_tokens, eos_id)
        self.run()
        return req.output

    def stream(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None, *, priority: int = 0,
               max_steps: int = 100_000) -> Iterator[int]:
        """Yields this request's tokens as they cross the trust boundary —
        each already egressed as its own encrypted frame. Other queued
        requests keep advancing in the same decode batch. The request is
        submitted eagerly (before the first token is pulled), so it joins
        the batch even if the caller iterates later."""
        buf: List[int] = []
        req = self.submit(prompt, max_new_tokens, eos_id, priority=priority,
                          on_token=lambda _r, t: buf.append(t))

        def _drain() -> Iterator[int]:
            steps = 0
            while not req.finished:
                if steps >= max_steps:
                    raise RuntimeError(f"stream exceeded {max_steps} steps")
                self.step()
                steps += 1
                while buf:
                    yield buf.pop(0)
            while buf:
                yield buf.pop(0)

        return _drain()
