"""Inference engine v3: request objects, per-request sampling, coalesced
egress, SLO admission — on v2's streaming/bucketed-prefill/preemption core.

Dataflow per paper Fig 2's protected stack:
  prompt --(encrypted bounce buffer)--> bucketed batched prefill(slots)
  --> batched decode loop --> sampled tokens --(encrypted frames through the
  bounce buffer, 1..N tokens each per the request's FramePolicy)--> client.

The serving API is the request-object model in :mod:`repro.runtime.api`:

  * **Per-request sampling** — each :class:`GenerationRequest` carries
    :class:`SamplingParams`; the engine mirrors them into ``[slots]``-shaped
    temperature/top-k/key arrays (``SlotState``) and the jitted decode step
    samples all slots at once via ``sampling.sample`` (``lax.top_k``,
    fold_in-per-token PRNG keys). A seeded request reproduces byte-identical
    output even across a sealed-KV preemption, because the key for token i
    depends only on (seed, i).

  * **Coalesced egress** — ``FramePolicy(coalesce=N)`` buffers N tokens per
    encrypted frame (flush-on-finish). ``coalesce=1`` is v2's per-token
    streaming; larger windows amortize the fixed per-crossing cost the cgpu
    profile models (Insight 10), measurable in ``ChannelStats``
    (messages_out = frames, tokens_out = tokens).

  * **SLO admission** — a queued request whose relative ``deadline_s``
    passes is dropped when it asked to be (``on_deadline="drop"``), and
    per-priority token-rate budgets (``rate_budgets``) hold a class at
    admission once it outruns its tokens/s allowance — preemption and drop
    counts become measurable trade-offs in ``ServeStats``.

v2 core (unchanged underneath): bucketed batched prefill with decode-aligned
chunking for long prompts, priority admission, sealed-KV preemption with
channel-global stream ids and per-request seal epochs, per-frame
replay/reorder rejection. All device compute is jitted once per shape;
decode donates the cache. The v2 kwargs form of ``submit``/``generate``/
``stream`` still works for one release behind a ``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.confidential import TrustDomain
from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.api import (FramePolicy, GenerationRequest, RequestOutput,
                               SamplingParams, TokenCallback)
from repro.runtime.kvcache import (SlotState, extract_slot as kv_extract,
                                   insert_rows, insert_slot)
from repro.runtime.scheduler import Request, Scheduler, ServeStats

Params = Any

_KWARGS_DEPRECATION = (
    "the kwargs serving API is deprecated; pass a GenerationRequest "
    "(repro.runtime.api) instead — it carries sampling, frame and SLO "
    "policies the kwargs form cannot express")


@dataclasses.dataclass
class PreemptedRequest:
    """A sealed-out request waiting for a slot: KV pages as ciphertext only."""
    sealed: Dict[str, Any]
    req: Request


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class _RateBucket:
    """Token bucket for one priority class: refills at ``rate`` tokens/s up
    to ``burst``; admission charges a request's whole ``max_new_tokens`` up
    front (the KV reservation it will hold). A request larger than the burst
    is admitted on a full bucket and overdraws it (level goes negative), so
    nothing starves while the long-run rate still holds."""

    def __init__(self, rate: float, burst: Optional[float] = None):
        if rate <= 0:
            raise ValueError(f"rate budget must be > 0 tokens/s, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(self.rate, 1.0)
        self.level = self.burst
        self._t = time.monotonic()

    def _refill(self) -> None:
        now = time.monotonic()
        self.level = min(self.burst, self.level + (now - self._t) * self.rate)
        self._t = now

    def can(self, n: int) -> bool:
        self._refill()
        return self.level >= min(float(n), self.burst)

    def charge(self, n: int) -> None:
        self.level -= float(n)


class Engine:
    def __init__(self, model: Model, params: Params, *, max_slots: int = 4,
                 max_len: int = 512, trust_domain: Optional[TrustDomain] = None,
                 prefill_len: int = 64,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 batch_prefill: bool = True,
                 rate_budgets: Optional[Dict[int, float]] = None):
        """``prefill_buckets`` supersedes the v1 single static ``prefill_len``
        (kept as the default one-bucket config for compatibility). Buckets
        should be powers of two; each distinct (rows, bucket) prefill shape
        compiles once. ``batch_prefill=False`` restores v1's one-request-per-
        prefill-call behavior (the serve_bench baseline).

        ``rate_budgets`` maps priority -> tokens/s: admission charges each
        request's max_new_tokens against its class's token bucket and holds
        the class back (without starving others) once the budget is spent.
        Priorities absent from the map are unthrottled."""
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        if prefill_buckets is None:
            prefill_buckets = (prefill_len,)
        self.prefill_buckets = tuple(sorted({int(b) for b in prefill_buckets}))
        if not self.prefill_buckets or min(self.prefill_buckets) < 1:
            raise ValueError(f"bad prefill buckets {self.prefill_buckets}")
        if max(self.prefill_buckets) >= max_len:
            raise ValueError("largest prefill bucket must leave decode room "
                             f"({self.prefill_buckets} vs max_len={max_len})")
        self.batch_prefill = batch_prefill
        self.td = trust_domain or TrustDomain("none")
        self.scheduler = Scheduler()
        self.slots = SlotState.create(max_slots)
        self.cache = model.init_cache(max_slots, max_len)
        self._active_mask = np.zeros(max_slots, bool)
        self._last_token = np.zeros(max_slots, np.int32)
        self._preempted: List[PreemptedRequest] = []
        self._buckets: Dict[int, _RateBucket] = {
            prio: _RateBucket(rate) for prio, rate in (rate_budgets or {}).items()}
        self._seed_rng = np.random.default_rng()

        cfg = model.cfg

        def _prefill(params, tokens, cache):
            return model.prefill(params, {"tokens": tokens}, cache)

        def _decode(params, tokens, cache, state, kmax):
            logits, cache = model.decode_step(params, tokens, cache)
            if state is None:     # all-greedy step: identical to the v2 path
                return sampling.greedy(logits), cache
            return sampling.sample(logits, state, kmax=kmax), cache

        self._prefill_fn = jax.jit(_prefill)
        # ``kmax`` is static (pow2-rounded max top_k) and ``state=None`` is a
        # distinct pytree structure, so compiled decode variants stay bounded
        # by 1 + log2(vocab), not one per request mix.
        self._decode_fn = jax.jit(_decode, donate_argnums=(2,),
                                  static_argnums=(4,))
        self._vocab = cfg.vocab_size

    # -- request admission ----------------------------------------------------
    def submit(self, request, max_new_tokens: Optional[int] = None,
               eos_id: Optional[int] = None, *, priority: int = 0,
               on_token: Optional[TokenCallback] = None) -> Request:
        """Admit one :class:`GenerationRequest`; returns the live
        :class:`Request` handle (``.finished``, ``.result()``).

        The legacy ``submit(prompt_array, max_new_tokens, eos_id, ...)``
        kwargs form still works for one release (DeprecationWarning)."""
        gen = self._coerce(request, max_new_tokens, eos_id, priority, on_token)
        gen.validate(self._vocab)
        # worst-case KV positions: the padded prefill bucket (or the full
        # prompt when chunked past it) plus one per decode *input* — the
        # final sampled token is emitted but never fed back, so it writes no
        # KV. Past max_len, dynamic_update_slice would clamp onto the last
        # cache row and silently corrupt the sequence — reject up front,
        # BEFORE the prompt crosses the boundary (a rejected request must
        # not skew ChannelStats).
        need = (max(self._bucket_for(len(gen.prompt)), len(gen.prompt))
                + gen.max_new_tokens - 1)
        if need > self.max_len:
            raise ValueError(
                f"request needs up to {need} KV positions "
                f"(prompt {len(gen.prompt)} + {gen.max_new_tokens} new) "
                f"but max_len={self.max_len}; shorten the prompt or "
                f"raise max_len")
        gen.prompt = self.td.ingress(gen.prompt)
        req = self.scheduler.submit(gen)
        req.ingress_messages = 1 if self.td.confidential else 0
        # resolve the sampling seed NOW so the request is reproducible from
        # this point on (including across seal/restore preemption cycles).
        if not gen.params.is_greedy:
            req.seed = (gen.params.seed if gen.params.seed is not None
                        else int(self._seed_rng.integers(2 ** 31 - 1)))
        req.stream_id = self.td.open_stream()
        return req

    def _coerce(self, request, max_new_tokens, eos_id, priority,
                on_token) -> GenerationRequest:
        if isinstance(request, GenerationRequest):
            if (max_new_tokens is not None or eos_id is not None
                    or priority != 0 or on_token is not None):
                raise TypeError("with a GenerationRequest, sampling/priority/"
                                "callback settings live on the request object")
            return request
        warnings.warn(_KWARGS_DEPRECATION, DeprecationWarning, stacklevel=3)
        return GenerationRequest(
            prompt=np.asarray(request, np.int32),
            max_new_tokens=32 if max_new_tokens is None else int(max_new_tokens),
            eos_id=eos_id, priority=priority, on_token=on_token)

    def prompt_budget(self, max_new_tokens: int) -> int:
        """Longest prompt submit() will accept for ``max_new_tokens``.
        Accounts for bucket padding: a short prompt still occupies its whole
        (left-padded) prefill bucket in the KV cache."""
        cand = self.max_len - max_new_tokens + 1   # last token writes no KV
        if cand >= self.prefill_buckets[-1]:
            return cand
        fits = [b for b in self.prefill_buckets if b <= cand]
        return fits[-1] if fits else 0

    def _bucket_for(self, prompt_len: int) -> int:
        """Smallest bucket that fits the prompt, else the largest bucket
        (the tail past it is chunked through decode steps)."""
        for b in self.prefill_buckets:
            if prompt_len <= b:
                return b
        return self.prefill_buckets[-1]

    # -- sampling plumbing -----------------------------------------------------
    def _base_key(self, req: Request) -> np.ndarray:
        return np.asarray(jax.random.PRNGKey(req.seed or 0), np.uint32)

    def _set_slot_sampling(self, slot: int, req: Request) -> None:
        p = req.gen.params
        if p.is_greedy:
            self.slots.clear_sampling(slot)
        else:
            self.slots.set_sampling(slot, p.temperature, p.top_k,
                                    self._base_key(req))

    def _static_kmax(self) -> int:
        """Pow2-rounded top_k bound → bounded set of compiled decode shapes."""
        k = self.slots.max_top_k
        return min(_next_pow2(k), self._vocab) if k > 0 else 0

    # -- egress ----------------------------------------------------------------
    def _flush_egress(self, req: Request) -> None:
        """Release the request's buffered tokens as ONE encrypted frame (the
        FramePolicy flush); the on_token callback fires per token as it
        becomes visible outside the domain."""
        if not req.egress_buf:
            return
        toks, req.egress_buf = req.egress_buf, []
        if self.td.confidential:
            out = self.td.egress_tokens(req.stream_id, toks)
            req.egress_frames += 1
            req.egress_tokens += len(out)
        else:
            out = toks
        if req.on_token is not None:
            for t in out:
                req.on_token(req, int(t))

    def _emit_token(self, slot: int, tok: int) -> bool:
        """Record one sampled token (in-domain), egress per the request's
        FramePolicy (coalesce window, flush-on-finish), and check
        termination. Returns True if the request finished."""
        req = self.scheduler.running[slot]
        self.scheduler.record_token(slot, int(tok))
        self._last_token[slot] = int(tok)
        done = req.done
        req.egress_buf.append(int(tok))
        if done or not self.td.confidential or len(req.egress_buf) >= req.coalesce:
            self._flush_egress(req)
        if done:
            # check immediately after recording: a max_new_tokens=1 request
            # (or EOS as the very first token) releases its slot without
            # paying for a wasted decode step (v1 off-by-one).
            self.scheduler.finish(slot)
            self.slots.release(slot)
            self._active_mask[slot] = False
            self.td.close_stream(req.stream_id)
            return True
        return False

    # -- SLO admission ---------------------------------------------------------
    @property
    def _admit_filter(self):
        """Admissibility predicate for the scheduler queue — None when no
        rate budgets are configured, keeping the common path on the O(1)
        heap peek instead of a sorted scan."""
        return self._admissible if self._buckets else None

    def _admissible(self, req: Request) -> bool:
        bucket = self._buckets.get(req.priority)
        return bucket is None or bucket.can(req.max_new_tokens)

    def _charge_budget(self, req: Request) -> None:
        bucket = self._buckets.get(req.priority)
        if bucket is not None:
            bucket.charge(req.max_new_tokens)

    def _drop_expired(self) -> None:
        for req in self.scheduler.drop_expired():
            self.td.close_stream(req.stream_id)
            self.td._log("drop_deadline",
                         f"rid={req.rid} deadline={req.gen.deadline_s}s "
                         f"waited={req.t_done - req.t_submit:.3f}s")

    def _admit_batch(self) -> int:
        """Pop waiting requests sharing the head's prefill bucket (bounded by
        free slots and per-priority rate budgets) and prefill them in one
        jitted call."""
        head = self.scheduler.peek_waiting(self._admit_filter)
        if head is None or not self.slots.free:
            return 0
        bucket = self._bucket_for(len(head.prompt))
        first = self.scheduler.next_waiting(self._admit_filter)
        self._charge_budget(first)
        group: List[Request] = [first]
        if self.batch_prefill:
            # group-mates must not jump the restore queue: a sealed-out
            # request with priority >= theirs gets the free slot first
            # (the head itself already outranked every sealed request, or
            # _admit_ready would have taken the restore branch).
            best_sealed = max((p.req.priority for p in self._preempted),
                              default=None)
            while len(group) < len(self.slots.free):
                nxt = self.scheduler.peek_waiting(self._admit_filter)
                if nxt is None or self._bucket_for(len(nxt.prompt)) != bucket:
                    break
                if best_sealed is not None and nxt.priority <= best_sealed:
                    break
                group.append(self.scheduler.next_waiting(self._admit_filter))
                self._charge_budget(group[-1])

        # rows padded to a power of two so compiled prefill shapes stay
        # bounded: |buckets| x log2(max_slots) variants, not one per batch.
        rows = _next_pow2(len(group))
        tokens = np.zeros((rows, bucket), np.int32)
        for i, req in enumerate(group):
            chunk = req.prompt[:bucket]
            tokens[i, bucket - len(chunk):] = chunk   # left-pad short prompts
        fresh = self.model.init_cache(rows, self.max_len)
        logits, prefilled = self._prefill_fn(self.params, jnp.asarray(tokens),
                                             fresh)
        first_np = self._first_tokens(logits, group, rows)

        slots = [self.slots.acquire(req.rid) for req in group]
        assert None not in slots, "admission raced free-slot accounting"
        # one donated scatter for the whole group (not k full-cache copies)
        self.cache = insert_rows(self.cache, prefilled,
                                 jnp.asarray(slots, jnp.int32))
        for i, req in enumerate(group):
            slot = slots[i]
            self.scheduler.start(slot, req)
            self._active_mask[slot] = True
            self._set_slot_sampling(slot, req)
            if len(req.prompt) > bucket:
                # chunked prefill: the tail is fed through the decode loop,
                # one token per step, before any sampling counts as output.
                req.pending_input = [int(t) for t in req.prompt[bucket:]]
                self._last_token[slot] = 0   # unused until the tail drains
            else:
                self._emit_token(slot, int(first_np[i]))
        return len(group)

    def _first_tokens(self, logits, group: List[Request], rows: int) -> np.ndarray:
        """Sample each group member's first token from its prefill logits
        with its own SamplingParams at token index 0 (same fold-in the
        decode loop would use), so prefill- and decode-produced tokens are
        governed by one policy."""
        if all(req.gen.params.is_greedy for req in group):
            return np.argmax(np.asarray(logits), axis=-1)
        temp = np.zeros(rows, np.float32)
        top_k = np.zeros(rows, np.int32)
        key = np.zeros((rows, 2), np.uint32)
        for i, req in enumerate(group):
            p = req.gen.params
            if not p.is_greedy:
                temp[i], top_k[i], key[i] = p.temperature, p.top_k, self._base_key(req)
        kmax = int(top_k.max())
        state = sampling.SamplingState(
            jnp.asarray(temp), jnp.asarray(top_k), jnp.asarray(key),
            jnp.zeros(rows, jnp.int32))
        return np.asarray(sampling.sample(
            logits, state, kmax=min(_next_pow2(kmax), self._vocab) if kmax else 0))

    def _preempt_lowest(self, incoming: Request) -> bool:
        """Seal out the lowest-priority running slot if ``incoming`` strictly
        outranks it. Returns True if a slot was freed."""
        if not self.scheduler.running:
            return False
        victim_slot = min(self.scheduler.running,
                          key=lambda s: (self.scheduler.running[s].priority,
                                         -self.scheduler.running[s].rid))
        victim = self.scheduler.running[victim_slot]
        if victim.priority >= incoming.priority:
            return False
        sealed, vreq = self.seal_slot(victim_slot)
        vreq.n_preemptions += 1
        self._preempted.append(PreemptedRequest(sealed, vreq))
        return True

    def _admit_ready(self) -> None:
        """Admission policy, run at the top of every step:
        1. drop queued requests whose drop-deadline has passed (SLO),
        2. restore sealed-out requests while no waiting request outranks them,
        3. batch-admit waiting requests into free slots (bucket-grouped,
           rate-budget gated — an over-budget priority class is skipped
           without blocking the classes behind it),
        4. preempt a strictly lower-priority running request when the waiting
           head cannot get a slot otherwise (preempted requests never trigger
           further preemption — bounded, no thrash)."""
        while True:
            self._drop_expired()
            if self._preempted and self.slots.free:
                best = max(self._preempted,
                           key=lambda p: (p.req.priority, -p.req.rid))
                head = self.scheduler.peek_waiting(self._admit_filter)
                if head is None or head.priority <= best.req.priority:
                    self._preempted.remove(best)
                    self.restore_slot(best.sealed, best.req)
                    continue
            if (self.scheduler.queue and self.slots.free
                    and self._admit_batch() > 0):
                continue
            head = self.scheduler.peek_waiting(self._admit_filter)
            if (head is not None and not self.slots.free
                    and self._preempt_lowest(head)):
                continue
            return

    # -- serving loop ----------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admission/restoration/preemption, then one
        batched decode step. Returns number of *output* tokens produced
        (prompt-chunk feeding steps count zero)."""
        self._admit_ready()
        if not self.slots.active:
            return 0
        feeding_prompt = {}   # slot -> tail still pending after this step?
        steps = np.zeros(self.max_slots, np.int32)
        for slot in self.slots.active:
            req = self.scheduler.running.get(slot)
            if req is None:
                continue
            steps[slot] = len(req.output)   # fold-in index of the next token
            if req.pending_input:
                self._last_token[slot] = req.pending_input.pop(0)
                feeding_prompt[slot] = bool(req.pending_input)
        tokens = jnp.asarray(self._last_token[:, None])
        if self.slots.any_sampled:
            state = sampling.SamplingState(
                jnp.asarray(self.slots.temp), jnp.asarray(self.slots.top_k),
                jnp.asarray(self.slots.key), jnp.asarray(steps))
            kmax = self._static_kmax()
        else:
            state, kmax = None, 0
        next_tokens, self.cache = self._decode_fn(self.params, tokens,
                                                  self.cache, state, kmax)
        next_np = np.asarray(next_tokens)
        produced = 0
        for slot in list(self.slots.active):
            if not self._active_mask[slot]:
                continue
            if feeding_prompt.get(slot, False):
                continue   # mid-prompt chunk: this step's sample is discarded
            self._emit_token(slot, int(next_np[slot]))
            produced += 1
        return produced

    @property
    def idle(self) -> bool:
        return self.scheduler.idle and not self._preempted

    def run(self, max_steps: int = 10_000) -> ServeStats:
        steps = 0
        while not self.idle and steps < max_steps:
            produced = self.step()
            steps += 1
            if produced == 0 and not self.slots.active and not self.idle:
                # every waiting request is rate-budget gated: yield briefly
                # so the token buckets refill instead of busy-spinning.
                time.sleep(1e-3)
        return self.scheduler.stats()

    # -- sealed KV preemption ----------------------------------------------------
    # The KV cache holds user conversation state; when a slot is preempted
    # (priority eviction, host maintenance) its pages must not land anywhere
    # unencrypted — the at-rest property H100 HBM lacks (paper §V-D3). The
    # slot cache is sealed with the domain key and can be restored later.

    def seal_slot(self, slot: int) -> Tuple[Dict[str, Any], Request]:
        """Evict a running slot: returns (sealed_cache_dict, request). Any
        not-yet-prefilled prompt tail travels on ``request.pending_input``
        and not-yet-flushed egress tokens stay buffered on the request."""
        from repro.core.sealing import seal_tree
        single = kv_extract(self.cache, jnp.int32(slot))
        req = self.scheduler.running.pop(slot)
        # the nonce-deriving name must be unique across every seal the domain
        # ever performs: the channel-global stream id (never reused, unlike
        # per-engine rids) plus a per-request seal epoch — a request
        # preempted twice holds different KV contents each time, and a
        # stream cipher must never encrypt two plaintexts under one nonce.
        sealed = seal_tree(self.td.sealing_key, single,
                           prefix=f"kvslot/{req.stream_id}/{req.seal_epoch}")
        req.seal_epoch += 1
        self.td._log("seal_kv",
                     f"slot={slot} rid={req.rid} stream={req.stream_id} "
                     f"epoch={req.seal_epoch - 1}")
        self.slots.release(slot)
        self._active_mask[slot] = False
        return sealed, req

    def restore_slot(self, sealed, req: Request) -> int:
        """Re-admit a sealed-out request into a free slot."""
        from repro.core.sealing import unseal_tree
        slot = self.slots.acquire(req.rid)
        if slot is None:
            raise RuntimeError("no free slot to restore into")
        single_like = self.model.abstract_cache(1, self.max_len)
        single = unseal_tree(self.td.sealing_key, sealed, single_like,
                             prefix=f"kvslot/{req.stream_id}/{req.seal_epoch - 1}")
        self.cache = insert_slot(self.cache, single, jnp.int32(slot))
        self.scheduler.running[slot] = req
        self._active_mask[slot] = True
        self._set_slot_sampling(slot, req)
        # next decode input: the prompt tail (if chunked prefill was cut
        # short) takes precedence in step(); otherwise the last output token.
        self._last_token[slot] = req.output[-1] if req.output else 0
        self.td._log("restore_kv", f"slot={slot} rid={req.rid}")
        return slot

    # -- convenience -----------------------------------------------------------
    def generate(self, request, max_new_tokens: Optional[int] = None,
                 eos_id: Optional[int] = None):
        """Serve one request to completion.

        New API: ``generate(GenerationRequest) -> RequestOutput``.
        Legacy kwargs form returns the raw token list (deprecated)."""
        if isinstance(request, GenerationRequest):
            req = self.submit(request)
            self.run()
            return req.result()
        req = self.submit(request,
                          32 if max_new_tokens is None else max_new_tokens,
                          eos_id)
        self.run()
        return req.output

    def stream(self, request, max_new_tokens: Optional[int] = None,
               eos_id: Optional[int] = None, *, priority: int = 0,
               max_steps: int = 100_000) -> Iterator[int]:
        """Yields this request's tokens as they cross the trust boundary —
        per token with the default FramePolicy, in bursts of ``coalesce``
        when the request asked for frame coalescing. Other queued requests
        keep advancing in the same decode batch. The request is submitted
        eagerly (before the first token is pulled), so it joins the batch
        even if the caller iterates later. Accepts a GenerationRequest (any
        on_token it carries still fires) or the deprecated kwargs form."""
        gen = self._coerce(request, max_new_tokens, eos_id, priority, None)
        buf: List[int] = []
        inner = gen.on_token

        def _tap(r, t):
            buf.append(t)
            if inner is not None:
                inner(r, t)

        gen.on_token = _tap
        req = self.submit(gen)

        def _drain() -> Iterator[int]:
            steps = 0
            while not req.finished:
                if steps >= max_steps:
                    raise RuntimeError(f"stream exceeded {max_steps} steps")
                produced = self.step()
                steps += 1
                if produced == 0 and not self.slots.active and not self.idle:
                    # rate-budget gated (same as run()): let buckets refill
                    # instead of burning max_steps on empty iterations.
                    time.sleep(1e-3)
                while buf:
                    yield buf.pop(0)
            while buf:
                yield buf.pop(0)

        return _drain()
