"""Inference engine: jitted prefill/decode with continuous batching, under an
optional TrustDomain (the paper's end-to-end confidential inference pipeline).

Dataflow per paper Fig 2's protected stack:
  prompt --(encrypted bounce buffer)--> prefill(slot) --> batched decode loop
  --> sampled tokens --(encrypted bounce buffer)--> client.

All device compute is jitted once; decode donates the cache to keep a single
in-place buffer. Finished slots are refilled without stopping decode
(continuous batching).
"""

from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.confidential import TrustDomain
from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.kvcache import SlotState, extract_slot as kv_extract, insert_slot
from repro.runtime.scheduler import Request, Scheduler, ServeStats

Params = Any


class Engine:
    def __init__(self, model: Model, params: Params, *, max_slots: int = 4,
                 max_len: int = 512, trust_domain: Optional[TrustDomain] = None,
                 prefill_len: int = 64):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        self.td = trust_domain or TrustDomain("none")
        self.scheduler = Scheduler()
        self.slots = SlotState.create(max_slots)
        self.cache = model.init_cache(max_slots, max_len)
        self._active_mask = np.zeros(max_slots, bool)
        self._last_token = np.zeros(max_slots, np.int32)

        cfg = model.cfg

        def _prefill(params, tokens, cache):
            return model.prefill(params, {"tokens": tokens}, cache)

        def _decode(params, tokens, cache):
            logits, cache = model.decode_step(params, tokens, cache)
            return sampling.greedy(logits), cache

        self._prefill_fn = jax.jit(_prefill)
        self._decode_fn = jax.jit(_decode, donate_argnums=(2,))
        self._vocab = cfg.vocab_size

    # -- request admission ----------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> Request:
        prompt = self.td.ingress(np.asarray(prompt, np.int32))
        return self.scheduler.submit(prompt, max_new_tokens, eos_id)

    def _try_admit(self) -> bool:
        req = self.scheduler.next_waiting()
        if req is None:
            return False
        slot = self.slots.acquire(req.rid)
        if slot is None:
            self.scheduler.queue.appendleft(req)
            return False
        # pad/truncate prompt to the static prefill length
        p = req.prompt[-self.prefill_len:]
        pad = self.prefill_len - len(p)
        tokens = np.pad(p, (pad, 0))[None]  # left-pad -> static shape
        single = self.model.init_cache(1, self.max_len)
        logits, single = self._prefill_fn(self.params, jnp.asarray(tokens), single)
        first = int(np.argmax(np.asarray(logits[0])))
        self.cache = insert_slot(self.cache, single, jnp.int32(slot))
        self.scheduler.start(slot, req)
        self.scheduler.record_token(slot, first)
        self._active_mask[slot] = True
        self._last_token[slot] = first
        return True

    # -- serving loop ----------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit if possible, then one decode step.
        Returns number of tokens produced."""
        while self.slots.free and self.scheduler.queue:
            self._try_admit()
        if not self.slots.active:
            return 0
        tokens = jnp.asarray(self._last_token[:, None])
        next_tokens, self.cache = self._decode_fn(self.params, tokens, self.cache)
        next_np = np.asarray(next_tokens)
        produced = 0
        for slot in list(self.slots.active):
            if not self._active_mask[slot]:
                continue
            tok = int(next_np[slot])
            self.scheduler.record_token(slot, tok)
            self._last_token[slot] = tok
            produced += 1
            req = self.scheduler.running[slot]
            if req.done:
                req.output = list(self.td.egress(np.asarray(req.output, np.int32)))
                self.scheduler.finish(slot)
                self.slots.release(slot)
                self._active_mask[slot] = False
        return produced

    def run(self, max_steps: int = 10_000) -> ServeStats:
        steps = 0
        while not self.scheduler.idle and steps < max_steps:
            self.step()
            steps += 1
        return self.scheduler.stats()

    # -- sealed KV preemption ----------------------------------------------------
    # The KV cache holds user conversation state; when a slot is preempted
    # (priority eviction, host maintenance) its pages must not land anywhere
    # unencrypted — the at-rest property H100 HBM lacks (paper §V-D3). The
    # slot cache is sealed with the domain key and can be restored later.

    def seal_slot(self, slot: int):
        """Evict a running slot: returns (sealed_cache_dict, request)."""
        from repro.core.sealing import seal_tree
        single = kv_extract(self.cache, jnp.int32(slot))
        req = self.scheduler.running.pop(slot)
        sealed = seal_tree(self.td.sealing_key, single,
                           prefix=f"kvslot/{req.rid}")
        self.td._log("seal_kv", f"slot={slot} rid={req.rid}")
        self.slots.release(slot)
        self._active_mask[slot] = False
        return sealed, req

    def restore_slot(self, sealed, req) -> int:
        """Re-admit a sealed-out request into a free slot."""
        from repro.core.sealing import unseal_tree
        slot = self.slots.acquire(req.rid)
        if slot is None:
            raise RuntimeError("no free slot to restore into")
        single_like = self.model.abstract_cache(1, self.max_len)
        single = unseal_tree(self.td.sealing_key, sealed, single_like,
                             prefix=f"kvslot/{req.rid}")
        self.cache = insert_slot(self.cache, single, jnp.int32(slot))
        self.scheduler.running[slot] = req
        self._active_mask[slot] = True
        self._last_token[slot] = req.output[-1] if req.output else 0
        self.td._log("restore_kv", f"slot={slot} rid={req.rid}")
        return slot

    # -- convenience -----------------------------------------------------------
    def generate(self, prompt: np.ndarray, max_new_tokens: int = 32) -> List[int]:
        req = self.submit(prompt, max_new_tokens)
        self.run()
        return req.output
