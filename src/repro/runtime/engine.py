"""Inference engine v6: prefill and decode are independently scheduled
phases, optionally on separate ComputePlans.

Dataflow per paper Fig 2's protected stack:
  prompt --(encrypted bounce buffer)--> bucketed batched prefill(slots)
  --> batched decode loop --> sampled tokens --(encrypted frames through the
  bounce buffer, 1..N tokens each per the request's FramePolicy)--> client.

**Two-phase serving.** Generation has two phases with opposite shapes —
prefill is one wide compute-bound call per request, decode is a thin
latency-bound step over every live row — and v6 schedules them
independently instead of letting a burst of long prompts stall every
in-flight decode (the dominant TTFT failure mode at load):

  * **Step-level continuous batching** (``Engine(continuous_batching=True)``,
    single plan): admission no longer fills a whole prefill bucket group
    before decode resumes. Each engine step has a token budget
    (``step_tokens``, default ``largest bucket + max_slots``) split between
    live decode rows (1 each) and prefill admissions (their bucket width);
    the slack/priority scheduler orders the prefill queue, and when the
    head's bucket doesn't fit the remaining budget a smaller queued request
    *backfills* the leftover (``Request.backfilled``) while the head keeps
    first claim on the next step's fresh budget. Chunked prompt tails
    interleave into decode steps exactly as before. Decoded bytes are
    unchanged — only admission timing moves.

  * **Disaggregated prefill** (``Engine(prefill_plan=...)``): prompts
    prefill on a dedicated :class:`~repro.runtime.plan.PrefillOnlyPlan`
    stream, dispatched asynchronously (jax's async dispatch overlaps it
    with the current decode step) and consumed one step later. The finished
    KV rows cross from the prefill plan to the decode plan through a
    **sealed handoff** — a ``seal_tree``/``unseal_tree`` pair under the
    request's ``kvhandoff/{stream}`` nonce namespace, accounted in
    ``TrustDomain``/``ChannelStats`` sealed bytes exactly like a preemption
    crossing. That prices the disaggregation boundary the way the paper's
    Insight 9-12 cost model prices every other data-movement boundary:
    per-request ``n_handoffs``/``handoff_bytes`` roll up into
    ``ServeStats.handoff_bytes``.

The serving API is the request-object model in :mod:`repro.runtime.api`
(per-request sampling — temperature/top-k/top-p, repetition/presence
penalties, logit-bias maps — coalesced egress frames, SLO admission).
Underneath sit three pluggable layers:

  * **ComputePlan** (:mod:`repro.runtime.plan`) — every device-facing
    concern (param placement, the jitted prefill/decode callables,
    host<->device transfer policy, collective accounting) goes through one
    seam. :class:`SingleDevicePlan` reproduces the v4 engine bit for bit;
    :class:`ShardedPlan` (``Engine(mesh="dp=8")``) spans a jax mesh: batch
    rows shard over the data axis, params place FSDP-style per
    ``distributed.sharding.param_specs`` (sharded at rest, all-gathered at
    use — real per-step interconnect traffic), the KV cache shards per
    ``cache_specs``, and outputs stay byte-identical to one device on
    dp-only meshes. The plan *measures* its collective path (HLO-parsed
    bytes/step + a shard_map all-gather probe on the real mesh) into
    ``ChannelStats.collective_bytes``/``collective_s`` — the measured input
    ``overheads.predict(collective_s=...)`` prices link_tax with, instead
    of the closed form the paper's §V-D4 Insight-12 estimate comes from.

  * **Pluggable KV layout** — the engine speaks
    :class:`~repro.runtime.kvcache.KVBackend`
    (``Engine(kv_backend="slot"|"paged")``): dense slots, or a page pool +
    table where admission charges ``ceil(need/page_size)`` pages and sealed
    preemption moves per-page ciphertext (bytes scale with tokens used;
    preemption can be *partial* — just a victim's tail pages). The paged
    layout additionally offers content-indexed **prefix sharing** with
    copy-on-write (``prefix_sharing=True``) and vLLM-style **on-demand**
    page grants with step-time capacity preemption
    (``kv_alloc="ondemand"``) — see the kvcache selection guide. Under a
    mesh the chosen layout is wrapped by
    :class:`~repro.runtime.kvcache.ShardedKVBackend`: seal/restore operate
    per addressable shard (``/s{shard}`` nonce suffixes), so preemption
    round-trips byte-identically however the cache is laid out.

  * **SLO enforcement** — the admission and sealed-restore queues order by
    *slack* (earliest absolute deadline, priority tiebreak) by default, so
    ``on_deadline="abort"`` — which terminates expired mid-flight requests
    and discards expired sealed ones — fires rarely rather than cheaply
    (``Engine(admission_order="priority")`` restores the v4 ordering).

All device compute is jitted once per shape; decode donates the cache.
``submit``/``generate``/``stream`` take a :class:`GenerationRequest`.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.confidential import TrustDomain
from repro.core.sealing import (IntegrityError, seal_tree, sealed_nbytes,
                                unseal_tree)
from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.api import (FINISH_ABORTED, GenerationRequest,
                               RequestOutput)
from repro.runtime.kvcache import (KVBackend, SlotState, host_upload,
                                   make_backend, next_pow2, tail_blob_names)
from repro.runtime.plan import (ComputePlan, PrefillOnlyPlan, ShardedPlan,
                                SingleDevicePlan)
from repro.runtime.scheduler import Request, Scheduler, ServeStats

Params = Any


@dataclasses.dataclass
class PreemptedRequest:
    """A sealed-out request waiting for a slot: KV pages as ciphertext only.

    ``key``/``prefix`` override the engine defaults at restore time — set on
    cross-worker *migrants* (fleet drain/failure), whose blobs are sealed
    under a fleet-shared tenant key domain in a ``kvmigrate/{worker}/...``
    nonce namespace instead of this worker's own key and ``kvslot/`` space.
    ``None`` means the ordinary local-preemption defaults."""
    sealed: Dict[str, Any]
    req: Request
    key: Optional[Any] = None
    prefix: Optional[str] = None


@dataclasses.dataclass
class InflightPrefill:
    """A request prefilling on the dedicated prefill plan: the jitted call
    was dispatched at admission (jax's async dispatch overlaps it with this
    step's decode) but its KV rows have not yet crossed to the decode plan.
    The slot is already reserved; :meth:`Engine._handoff_ready` consumes it
    at the next step through the sealed plan-to-plan handoff."""
    req: Request
    slot: int
    bucket: int
    logits: jax.Array
    cache: Any


@dataclasses.dataclass
class PausedSlot:
    """A partially-evicted running slot (paged backend): its tail pages are
    ciphertext outside the pool, the head pages stay resident, and the slot
    sits out of the decode batch until the delta is restored."""
    sealed: Dict[str, Any]
    prefix: str
    n_pages: int


class _RateBucket:
    """Token bucket for one priority class: refills at ``rate`` tokens/s up
    to ``burst``; admission charges a request's whole ``max_new_tokens`` up
    front (the KV reservation it will hold). A request larger than the burst
    is admitted on a full bucket and overdraws it (level goes negative), so
    nothing starves while the long-run rate still holds."""

    def __init__(self, rate: float, burst: Optional[float] = None):
        if rate <= 0:
            raise ValueError(f"rate budget must be > 0 tokens/s, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(self.rate, 1.0)
        self.level = self.burst
        self._t = time.monotonic()

    def _refill(self) -> None:
        now = time.monotonic()
        self.level = min(self.burst, self.level + (now - self._t) * self.rate)
        self._t = now

    def can(self, n: int) -> bool:
        self._refill()
        return self.level >= min(float(n), self.burst)

    def charge(self, n: int) -> None:
        self.level -= float(n)


class Engine:
    def __init__(self, model: Model, params: Params, *, max_slots: int = 4,
                 max_len: int = 512, trust_domain: Optional[TrustDomain] = None,
                 prefill_len: int = 64,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 batch_prefill: bool = True,
                 rate_budgets: Optional[Dict[int, float]] = None,
                 kv_backend: str = "slot", page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefix_sharing: bool = False,
                 kv_alloc: Optional[str] = None,
                 kv_decode: str = "gather",
                 page_store: Any = None,
                 store_budget_pages: Optional[int] = None,
                 mesh: Optional[str] = None,
                 plan: Optional[ComputePlan] = None,
                 admission_order: str = "slack",
                 continuous_batching: bool = False,
                 step_tokens: Optional[int] = None,
                 prefill_plan: Optional[Any] = None,
                 handoff_batch: int = 1,
                 reject_infeasible: bool = False,
                 step_time_hint_s: Optional[float] = None):
        """``prefill_buckets`` supersedes the v1 single static ``prefill_len``
        (kept as the default one-bucket config for compatibility). Buckets
        should be powers of two; each distinct (rows, bucket) prefill shape
        compiles once. ``batch_prefill=False`` restores v1's one-request-per-
        prefill-call behavior (the serve_bench baseline).

        ``rate_budgets`` maps priority -> tokens/s: admission charges each
        request's max_new_tokens against its class's token bucket and holds
        the class back (without starving others) once the budget is spent.
        Priorities absent from the map are unthrottled.

        ``kv_backend`` selects the KV layout: ``"slot"`` (dense, default) or
        ``"paged"`` (page pool + table; ``page_size``/``num_pages`` size it,
        ``num_pages=None`` matches the dense footprint). See the
        :mod:`repro.runtime.kvcache` docstring for when each wins.

        ``prefix_sharing`` (paged only) turns on content-indexed shared
        prompt pages with copy-on-write; ``kv_alloc`` picks the page
        allocation mode — ``"reserve"`` (worst-case admission reservations,
        the default) or ``"ondemand"`` (step-time grants with capacity
        preemption when the pool runs dry; implied by ``prefix_sharing``).
        Decoded outputs are byte-identical across all of these — only
        memory, sealing traffic, and scheduling change.

        ``page_store`` (paged + sharing only) attaches the persistent
        content-addressed sealed-page store — the prefix-cache tier that
        retains content-named page ciphertext after the last live/sealed
        reference drops (:mod:`repro.runtime.pagestore`). Pass ``True`` or
        a policy name (``"lru"``/``"cost"``), or a ready
        :class:`~repro.runtime.pagestore.SealedPageStore` instance (which
        may be shared between engines — entries are namespaced per sealing
        key, so sharing the object never shares ciphertext across trust
        domains). ``store_budget_pages`` bounds store residency; prefill
        misses restore MAC-verified store pages instead of recomputing,
        admission discounts store-resident prefixes via
        ``effective_kv_need``, and hits/evictions land in
        ``TrustDomain``/``ServeStats`` accounting.

        ``kv_decode`` (paged only) selects the decode attention path:
        ``"gather"`` (default) rematerializes the dense KV view per step;
        ``"kernel"`` runs the table-walking Pallas paged-attention kernel
        (streams valid pages only, decrypts fused-unseal restored pages
        in-VMEM). Kernel outputs are numerically close, not byte-identical
        — see the :mod:`repro.runtime.kvcache` docstring.

        ``mesh`` spans the engine across devices: ``"dp=4"`` shards the
        batch (and FSDP-places params) over 4 devices, ``"dp=4,tp=2"`` adds
        tensor parallelism over 2 more. Equivalently pass a ready
        :class:`~repro.runtime.plan.ComputePlan` as ``plan``. Default: one
        device, bit-identical to v4.

        ``admission_order``: ``"slack"`` (default) serves
        tightest-deadline-first with priority tiebreak; ``"priority"`` is
        the v4 priority-only order.

        ``continuous_batching`` replaces fill-a-bucket-then-decode admission
        with step-level interleaving: each step's token budget
        (``step_tokens``, default ``largest bucket + max_slots`` so a fresh
        step with a free slot can always admit the queue head) splits
        between live decode rows and prefill admissions, with queue-ordered
        backfill when the head's bucket doesn't fit the remainder.

        ``prefill_plan`` disaggregates: prompts prefill on their own plan
        (pass a ready :class:`~repro.runtime.plan.ComputePlan`, or
        ``"dedicated"`` for a fresh
        :class:`~repro.runtime.plan.PrefillOnlyPlan`) and the finished KV
        rows hand off to the decode plan through a sealed seal/restore pair
        priced in ``ChannelStats``. Mutually exclusive with
        ``continuous_batching`` — a dedicated prefill stream already
        decouples prefill from the decode step, so there is no shared
        per-step budget to split. Decoded outputs are byte-identical under
        every mode — admission timing and boundary accounting are all that
        move.

        ``handoff_batch`` (disaggregated engines only) amortizes the sealed
        prefill->decode handoff: up to N finished prefill rows cross the
        plan boundary per sealed crossing (Insight 10 — the fixed
        per-crossing cost divides by N). The default 1 keeps one crossing
        per row, byte- and accounting-identical to v6.

        ``reject_infeasible`` turns on admission-time deadline feasibility:
        a request whose ``deadline_s`` is provably unmeetable — serial
        decode steps it needs plus queued work ahead, priced at the
        *fastest* observed (or ``step_time_hint_s``-modeled, e.g.
        ``overheads.predict(...).t_tee_s``) step time — is refused at
        ingest with ``finish_reason="rejected"`` before its prompt crosses
        the boundary or holds a stream, instead of burning prefill compute
        only to be aborted mid-decode."""
        self.model = model
        if plan is not None and mesh is not None:
            raise ValueError("pass mesh= or plan=, not both")
        if plan is None:
            plan = (ShardedPlan.from_spec(model, mesh) if mesh is not None
                    else SingleDevicePlan(model))
        self.plan = plan
        self.params = self.plan.place_params(params)
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        if prefill_buckets is None:
            prefill_buckets = (prefill_len,)
        self.prefill_buckets = tuple(sorted({int(b) for b in prefill_buckets}))
        if not self.prefill_buckets or min(self.prefill_buckets) < 1:
            raise ValueError(f"bad prefill buckets {self.prefill_buckets}")
        if max(self.prefill_buckets) >= max_len:
            raise ValueError("largest prefill bucket must leave decode room "
                             f"({self.prefill_buckets} vs max_len={max_len})")
        self.batch_prefill = batch_prefill
        self.td = trust_domain or TrustDomain("none")
        self.scheduler = Scheduler(order=admission_order)
        self.kv: KVBackend = make_backend(kv_backend, model,
                                          max_slots=max_slots, max_len=max_len,
                                          page_size=page_size,
                                          num_pages=num_pages, plan=self.plan,
                                          prefix_sharing=prefix_sharing,
                                          alloc=kv_alloc, decode=kv_decode,
                                          page_store=page_store,
                                          store_budget_pages=store_budget_pages)
        if getattr(self.kv, "page_store", None) is not None:
            # fix the store's key domain to this engine's trust domain so
            # lookups/publishes run before any seal ever caches a key
            self.kv.bind_store_key(self.td.sealing_key)
        self._active_mask = np.zeros(max_slots, bool)
        self._last_token = np.zeros(max_slots, np.int32)
        self._preempted: List[PreemptedRequest] = []
        self._paused: Dict[int, PausedSlot] = {}
        self._buckets: Dict[int, _RateBucket] = {
            prio: _RateBucket(rate) for prio, rate in (rate_budgets or {}).items()}
        self._seed_rng = np.random.default_rng()
        self._prefill_fn = self.plan.compile_prefill()
        self._vocab = model.cfg.vocab_size
        # device mirror of slots.hist, maintained incrementally while some
        # slot penalizes (see _hist_device) — the [slots, vocab] matrix must
        # not be re-uploaded on every decode step. Per-token increments are
        # queued in _hist_pending and applied as ONE batched scatter per
        # step (a per-token .at[].add would copy the whole matrix per emit).
        self._hist_dev = None
        self._hist_dev_version = -1
        self._hist_pending: List[Tuple[int, int]] = []
        # device mirror of slots.bias — version-triggered only (bias rows
        # are static per request; there is no per-token increment stream)
        self._bias_dev = None
        self._bias_dev_version = -1
        # -- two-phase serving --------------------------------------------
        if continuous_batching and prefill_plan is not None:
            raise ValueError(
                "continuous_batching applies to single-plan engines; a "
                "dedicated prefill_plan already decouples prefill from the "
                "decode step")
        if step_tokens is not None and not continuous_batching:
            raise ValueError(
                "step_tokens only makes sense with continuous_batching=True")
        if isinstance(prefill_plan, str):
            if prefill_plan != "dedicated":
                raise ValueError(
                    f"prefill_plan must be a ComputePlan or 'dedicated', "
                    f"got {prefill_plan!r}")
            prefill_plan = PrefillOnlyPlan(model)
        self.prefill_plan = prefill_plan
        if prefill_plan is not None:
            self.prefill_params = prefill_plan.place_params(params)
            self._prefill_stream_fn = prefill_plan.compile_prefill()
        else:
            self.prefill_params = None
            self._prefill_stream_fn = None
        if continuous_batching:
            if step_tokens is None:
                step_tokens = self.prefill_buckets[-1] + max_slots
            if step_tokens < self.prefill_buckets[-1]:
                raise ValueError(
                    f"step_tokens={step_tokens} can never admit the largest "
                    f"prefill bucket ({self.prefill_buckets[-1]}) — the "
                    f"queue head would starve")
        self._continuous = continuous_batching or prefill_plan is not None
        self._step_tokens = step_tokens if continuous_batching else None
        self._budget_left: Optional[int] = None
        self._inflight: Dict[int, InflightPrefill] = {}
        self.backfills = 0   # out-of-order budget-backfill admissions
        self._handoff_batch = int(handoff_batch)
        if self._handoff_batch < 1:
            raise ValueError(f"handoff_batch must be >= 1, got {handoff_batch}")
        if self._handoff_batch > 1 and prefill_plan is None:
            raise ValueError(
                "handoff_batch only applies to disaggregated engines "
                "(prefill_plan=...) — there is no plan boundary to amortize")
        self.handoff_crossings = 0   # sealed plan-boundary crossings (each
                                     # carries up to handoff_batch rows)
        # -- admission-time deadline feasibility ---------------------------
        self._reject_infeasible = reject_infeasible
        self._step_time_hint_s = step_time_hint_s
        self._step_floor: Optional[float] = None  # fastest observed step
        # -- fleet migration -----------------------------------------------
        self._draining = False

    @property
    def slots(self) -> SlotState:
        """Per-sequence bookkeeping rows (owned by the KV backend)."""
        return self.kv.slots

    # -- request admission ----------------------------------------------------
    def submit(self, request: GenerationRequest) -> Request:
        """Admit one :class:`GenerationRequest`; returns the live
        :class:`Request` handle (``.finished``, ``.result()``)."""
        if not isinstance(request, GenerationRequest):
            raise TypeError(
                "submit takes a GenerationRequest (repro.runtime.api); the "
                "v2 kwargs form was removed in v4 — build a request object")
        if self._draining:
            raise RuntimeError(
                "engine is draining (drain()/export_sealed_state was "
                "called); route new work to another worker")
        gen = request
        gen.validate(self._vocab)
        # worst-case KV positions: the padded prefill bucket (or the full
        # prompt when chunked past it) plus one per decode *input* — the
        # final sampled token is emitted but never fed back, so it writes no
        # KV. Past the backend's capacity, writes would clamp onto the last
        # cache row and silently corrupt the sequence — reject up front,
        # BEFORE the prompt crosses the boundary (a rejected request must
        # not skew ChannelStats). On a prefix-sharing backend the capacity
        # check (and kv_need) is *effective*: pages whose content is already
        # resident in the index charge nothing against the pool, so a RAG
        # request whose context prefix is resident is not rejected for
        # memory it will never allocate.
        bucket = self._bucket_for(len(gen.prompt))
        need = max(bucket, len(gen.prompt)) + gen.max_new_tokens - 1
        keys = None
        if self.kv.supports_sharing and gen.share_prefix:
            keys = self.kv.page_keys(self._padded_bucket(gen.prompt, bucket),
                                     bucket)
        fits, eff_need = self.kv.admission_check(need, keys)
        if not fits:
            raise ValueError(
                f"request needs up to {need} KV positions "
                f"(prompt {len(gen.prompt)} + {gen.max_new_tokens} new) "
                f"but the {self.kv.name} backend serves at most "
                f"{self.kv.request_capacity} (max_len={self.max_len}); "
                f"shorten the prompt or raise max_len")
        # deadline feasibility, decided BEFORE the prompt crosses the
        # boundary or a stream is held: a rejected request must cost the
        # domain nothing (no ingress message, no egress stream, no slot).
        rejected = self._reject_if_infeasible(gen)
        if rejected is not None:
            return rejected
        gen.prompt = self.td.ingress(gen.prompt)
        req = self.scheduler.submit(gen)
        req.kv_need = eff_need
        req.page_keys = keys
        req.ingress_messages = 1 if self.td.confidential else 0
        # resolve the sampling seed NOW so the request is reproducible from
        # this point on (including across seal/restore preemption cycles).
        if not gen.params.is_greedy:
            req.seed = (gen.params.seed if gen.params.seed is not None
                        else int(self._seed_rng.integers(2 ** 31 - 1)))
        req.stream_id = self.td.open_stream()
        return req

    def prompt_budget(self, max_new_tokens: int) -> int:
        """Longest prompt submit() will accept for ``max_new_tokens``
        (backend-delegated: the slot-dense answer is bounded by ``max_len``
        and bucket padding, the paged one also by the page pool). Prefix
        sharing never raises this bound — a sequence's pages all hold
        simultaneous table mappings, shared or not; what sharing lowers is
        the *effective demand* a request charges at admission, which
        :meth:`effective_kv_need` reports."""
        return self.kv.prompt_budget(max_new_tokens, self.prefill_buckets)

    def effective_kv_need(self, prompt: np.ndarray,
                          max_new_tokens: int) -> Tuple[int, int]:
        """(worst_case, effective) KV positions this prompt would charge at
        admission right now: on a prefix-sharing engine the effective
        figure discounts pages of this prompt already resident in the
        content index — a resident RAG context stops counting against the
        pool, so such requests admit alongside traffic that would
        otherwise have reserved it away."""
        prompt = np.asarray(prompt, np.int32)
        bucket = self._bucket_for(len(prompt))
        need = max(bucket, len(prompt)) + max_new_tokens - 1
        keys = None
        if self.kv.supports_sharing:
            keys = self.kv.page_keys(self._padded_bucket(prompt, bucket),
                                     bucket)
        _, eff = self.kv.admission_check(need, keys)
        return need, eff

    def _bucket_for(self, prompt_len: int) -> int:
        """Smallest bucket that fits the prompt, else the largest bucket
        (the tail past it is chunked through decode steps)."""
        for b in self.prefill_buckets:
            if prompt_len <= b:
                return b
        return self.prefill_buckets[-1]

    @staticmethod
    def _padded_bucket(prompt: np.ndarray, bucket: int) -> np.ndarray:
        """The token content the prefill writes into the bucket region —
        left-padded exactly as _admit_batch lays it out (content keys must
        hash what the cache will actually hold)."""
        chunk = np.asarray(prompt[:bucket], np.int32)
        padded = np.zeros(bucket, np.int32)
        padded[bucket - len(chunk):] = chunk
        return padded

    def _admit_need(self, req: Request) -> int:
        """KV positions admission must cover *now*: the full effective worst
        case under reservation accounting; on demand, the prefill's own
        page demand (net of currently-resident shared pages) plus one page
        of append/CoW headroom — without it a fully-resident-prompt request
        admits into a dry pool only for its first decode append to evict it
        straight back out (admission churn, no forward progress)."""
        if not self.kv.on_demand:
            return req.kv_need
        bucket = self._bucket_for(len(req.prompt))
        resident = self.kv.resident_pages(req.page_keys)
        return max(0, bucket - resident * self.kv.page_size) \
            + self.kv.page_size

    # -- admission-time deadline feasibility -----------------------------------
    def _step_time_lower(self) -> Optional[float]:
        """A defensible lower bound on one engine step's wall time: the
        fastest step observed so far (first-step compile time can only
        *raise* individual samples, never lower the min) and/or the modeled
        hint (``overheads.predict(...).t_tee_s``), whichever is smaller.
        None until either exists — feasibility then never rejects."""
        cands = [c for c in (self._step_floor, self._step_time_hint_s)
                 if c is not None]
        return min(cands) if cands else None

    def _reject_if_infeasible(self, gen: GenerationRequest
                              ) -> Optional[Request]:
        """Refuse ``gen`` at ingest when its deadline is provably unmeetable:
        even at the fastest step time, the serial steps it needs (one
        prefill dispatch, chunked prompt-tail feeds, one decode step per
        output token after the prefill-produced first) plus the queue ahead
        of it (optimistically packed across all slots — a lower bound)
        already exceed ``deadline_s``. Returns the finished rejected
        :class:`Request` (``finish_reason="rejected"``), or None to admit
        normally. Estimation is deliberately one-sided: a request this
        rejects would have been aborted mid-decode after consuming prefill
        compute and sealed-KV bandwidth."""
        if not self._reject_infeasible or gen.deadline_s is None:
            return None
        lo = self._step_time_lower()
        if lo is None:
            return None
        bucket = self._bucket_for(len(gen.prompt))
        tail = max(0, len(gen.prompt) - bucket)
        own_steps = 1 + tail + (gen.max_new_tokens - 1)
        ahead = sum(r.max_new_tokens for _, _, r in self.scheduler.queue)
        ahead += sum(max(0, r.max_new_tokens - len(r.output))
                     for r in self.scheduler.running.values())
        ahead += sum(max(0, p.req.max_new_tokens - len(p.req.output))
                     for p in self._preempted)
        est = lo * (own_steps + ahead / max(1, self.max_slots))
        if est <= gen.deadline_s:
            return None
        req = self.scheduler.reject(gen)
        self.td._log("reject_infeasible",
                     f"rid={req.rid} est>={est:.4f}s "
                     f"deadline={gen.deadline_s}s step_lo={lo:.6f}s")
        return req

    # -- sampling plumbing -----------------------------------------------------
    def _base_key(self, req: Request) -> np.ndarray:
        return np.asarray(jax.random.PRNGKey(req.seed or 0), np.uint32)

    def _set_slot_sampling(self, slot: int, req: Request) -> None:
        p = req.gen.params
        if p.is_greedy:
            self.slots.clear_sampling(slot)
        else:
            self.slots.set_sampling(slot, p.temperature, p.top_k, p.top_p,
                                    self._base_key(req),
                                    p.repetition_penalty, p.presence_penalty,
                                    logit_bias=p.logit_bias)
            # penalty history follows the request, not the cache: rebuilt
            # from its output list (empty at first admission; the generated
            # prefix after a sealed restore), so a seeded penalized request
            # re-samples byte-identically across preemption.
            self.slots.set_hist(slot, req.output)

    def _static_kmax(self) -> int:
        """Pow2-rounded top_k bound → bounded set of compiled decode shapes."""
        k = self.slots.max_top_k
        return min(next_pow2(k), self._vocab) if k > 0 else 0

    def _sampling_state(self, steps: np.ndarray
                        ) -> Tuple[Optional[sampling.SamplingState], int]:
        """The per-step (state, kmax) pair for the jitted decode: ``None``
        state on all-greedy steps, and a ``top_p`` row only when some slot
        actually restricts (both are static pytree differences, so the
        nucleus sort and the sampling math compile only when used)."""
        s = self.slots
        rep = host_upload(s.rep_pen) if s.any_rep_pen else None
        pres = host_upload(s.presence) if s.any_presence else None
        if rep is None and pres is None:
            # no live penalties: drop the device mirror and its queue (also
            # on the all-greedy path below — _emit_token must not keep
            # feeding a queue nothing will ever drain).
            hist = None
            self._hist_dev = None
            self._hist_pending.clear()
        else:
            hist = self._hist_device()
        if not s.any_bias:
            bias = None
            self._bias_dev = None
            self._bias_dev_version = -1
        else:
            bias = self._bias_device()
        if not s.any_sampled:
            return None, 0
        top_p = host_upload(s.top_p) if s.any_top_p else None
        state = sampling.SamplingState(
            host_upload(s.temp), host_upload(s.top_k), host_upload(s.key),
            host_upload(steps), top_p=top_p, rep_pen=rep, presence=pres,
            hist=hist, bias=bias)
        return state, self._static_kmax()

    def _hist_device(self):
        """Device copy of the penalty history, kept in sync cheaply: bulk
        host mutations (row rebuild/clear — admission, restore, release)
        bump ``hist_version`` and trigger a full upload (which subsumes any
        queued increments — the host matrix is always authoritative);
        otherwise the per-token increments queued since the last step are
        applied as one batched scatter, so the decode hot path ships a few
        ints per step instead of [slots, vocab]."""
        if (self._hist_dev is None
                or self._hist_dev_version != self.slots.hist_version):
            self._hist_dev = host_upload(self.slots.hist)
            self._hist_dev_version = self.slots.hist_version
            self._hist_pending.clear()
        elif self._hist_pending:
            rows = host_upload([s for s, _ in self._hist_pending], jnp.int32)
            toks = host_upload([t for _, t in self._hist_pending], jnp.int32)
            self._hist_dev = self._hist_dev.at[rows, toks].add(1)
            self._hist_pending.clear()
        return self._hist_dev

    def _bias_device(self):
        """Device copy of the logit-bias rows. Unlike ``hist`` there is no
        incremental stream — bias is static per request — so a version check
        alone decides when the matrix re-uploads (admission/release of a
        biased request bumps ``bias_version``)."""
        if (self._bias_dev is None
                or self._bias_dev_version != self.slots.bias_version):
            self._bias_dev = host_upload(self.slots.bias)
            self._bias_dev_version = self.slots.bias_version
        return self._bias_dev

    # -- egress ----------------------------------------------------------------
    def _flush_egress(self, req: Request) -> None:
        """Release the request's buffered tokens as ONE encrypted frame (the
        FramePolicy flush); the on_token callback fires per token as it
        becomes visible outside the domain."""
        if not req.egress_buf:
            return
        toks, req.egress_buf = req.egress_buf, []
        if self.td.confidential:
            out = self.td.egress_tokens(req.stream_id, toks)
            req.egress_frames += 1
            req.egress_tokens += len(out)
        else:
            out = toks
        if req.on_token is not None:
            for t in out:
                req.on_token(req, int(t))

    def _emit_token(self, slot: int, tok: int) -> bool:
        """Record one sampled token (in-domain), egress per the request's
        FramePolicy (coalesce window, flush-on-finish), and check
        termination. Returns True if the request finished."""
        req = self.scheduler.running[slot]
        self.scheduler.record_token(slot, int(tok))
        # penalty history (host), counted only for penalized slots; a
        # counted token is queued for the device mirror so both sides agree
        if (self.slots.note_token(slot, int(tok))
                and self._hist_dev is not None):
            self._hist_pending.append((slot, int(tok)))
        self._last_token[slot] = int(tok)
        done = req.done
        req.egress_buf.append(int(tok))
        if done or not self.td.confidential or len(req.egress_buf) >= req.coalesce:
            self._flush_egress(req)
        if done:
            # check immediately after recording: a max_new_tokens=1 request
            # (or EOS as the very first token) releases its slot without
            # paying for a wasted decode step (v1 off-by-one).
            self.scheduler.finish(slot)
            self.kv.release(slot)
            self._active_mask[slot] = False
            self.td.close_stream(req.stream_id)
            return True
        return False

    # -- SLO admission ---------------------------------------------------------
    @property
    def _admit_filter(self):
        """Admissibility predicate for the scheduler queue — None when no
        rate budgets are configured, keeping the common path on the O(1)
        heap peek instead of a sorted scan."""
        return self._admissible if self._buckets else None

    def _admissible(self, req: Request) -> bool:
        bucket = self._buckets.get(req.priority)
        return bucket is None or bucket.can(req.max_new_tokens)

    def _charge_budget(self, req: Request) -> None:
        bucket = self._buckets.get(req.priority)
        if bucket is not None:
            bucket.charge(req.max_new_tokens)

    def _drop_expired(self) -> None:
        for req in self.scheduler.drop_expired():
            self.td.close_stream(req.stream_id)
            self.td._log("drop_deadline",
                         f"rid={req.rid} deadline={req.gen.deadline_s}s "
                         f"waited={req.t_done - req.t_submit:.3f}s")

    def _enforce_aborts(self) -> None:
        """``on_deadline="abort"``: terminate expired mid-flight requests.
        A running one flushes its partial tokens and frees its slot/pages; a
        sealed-out (preempted) one is discarded instead of restored — its
        ciphertext is simply dropped, which is what makes abort cheap: no
        boundary crossing, no decode steps, just bookkeeping."""
        now = time.monotonic()
        for slot in list(self.scheduler.running):
            req = self.scheduler.running[slot]
            if not req.abort_expired(now):
                continue
            self._flush_egress(req)
            req.finish_reason = FINISH_ABORTED
            self.scheduler.finish(slot)
            self.kv.release(slot)
            self._active_mask[slot] = False
            self._paused.pop(slot, None)   # a paused victim's sealed tail
            self.td.close_stream(req.stream_id)
            self.td._log("abort_deadline",
                         f"rid={req.rid} deadline={req.gen.deadline_s}s "
                         f"tokens={len(req.output)}")
        for p in list(self._preempted):
            if not p.req.abort_expired(now):
                continue
            self._preempted.remove(p)
            self._flush_egress(p.req)   # coalesced tokens sealed with it must
            p.req.finish_reason = FINISH_ABORTED     # still reach the client
            # its sealed state may reference shared pages: release those
            # refs so parked ciphertext does not outlive every reader (the
            # blob itself is just dropped — that is what makes abort cheap).
            # Only tampered/garbled blobs are tolerated here; accounting
            # bugs (asserts, refcount underflows) must still surface.
            try:
                self.kv.discard_sealed(
                    p.key or self.td.sealing_key, p.sealed,
                    p.prefix
                    or f"kvslot/{p.req.stream_id}/{p.req.seal_epoch - 1}")
            except (IntegrityError, ValueError):
                pass
            self.scheduler.finish_detached(p.req)
            self.td.close_stream(p.req.stream_id)
            self.td._log("abort_deadline",
                         f"rid={p.req.rid} sealed KV discarded unrestored")

    def _admit_batch(self) -> int:
        """Pop waiting requests sharing the head's prefill bucket (bounded by
        free slots, the backend's KV capacity, and per-priority rate budgets)
        and prefill them in one jitted call."""
        head = self.scheduler.peek_waiting(self._admit_filter)
        if (head is None or not self.slots.free
                or not self.kv.can_admit(self._admit_need(head))):
            return 0
        bucket = self._bucket_for(len(head.prompt))
        first = self.scheduler.next_waiting(self._admit_filter)
        self._charge_budget(first)
        slots = [self.kv.acquire(first.rid, self._admit_need(first))]
        assert slots[0] is not None, "admission raced KV accounting"
        group: List[Request] = [first]
        if self.batch_prefill:
            # group-mates must not jump the restore queue: a sealed-out
            # request with priority >= theirs gets the free slot first
            # (the head itself already outranked every sealed request, or
            # _admit_ready would have taken the restore branch).
            best_sealed = max((p.req.priority for p in self._preempted),
                              default=None)
            while self.slots.free:
                nxt = self.scheduler.peek_waiting(self._admit_filter)
                if nxt is None or self._bucket_for(len(nxt.prompt)) != bucket:
                    break
                if best_sealed is not None and nxt.priority <= best_sealed:
                    break
                if not self.kv.can_admit(self._admit_need(nxt)):
                    break
                nxt = self.scheduler.next_waiting(self._admit_filter)
                self._charge_budget(nxt)
                slot = self.kv.acquire(nxt.rid, self._admit_need(nxt))
                assert slot is not None, "admission raced KV accounting"
                group.append(nxt)
                slots.append(slot)

        # rows padded to a power of two so compiled prefill shapes stay
        # bounded: |buckets| x log2(max_slots) variants, not one per batch.
        rows = next_pow2(len(group))
        tokens = np.zeros((rows, bucket), np.int32)
        for i, req in enumerate(group):
            chunk = req.prompt[:bucket]
            tokens[i, bucket - len(chunk):] = chunk   # left-pad short prompts
        fresh = self.kv.fresh_prefill_cache(rows)
        logits, prefilled = self._prefill_fn(self.params, host_upload(tokens),
                                             fresh)
        first_np = self._first_tokens(logits, group, rows)

        group_keys = None
        if self.kv.supports_sharing:
            group_keys = [req.page_keys for req in group]
        self.kv.insert_prefill(prefilled, slots, bucket,
                               page_keys=group_keys)
        for i, req in enumerate(group):
            self._start_decode(slots[i], req, int(first_np[i]), bucket)
        return len(group)

    def _start_decode(self, slot: int, req: Request, first_tok: int,
                      bucket: int) -> None:
        """Common post-prefill setup: the request enters the decode phase —
        it joins the scheduler's running set, its sampling row is set, and
        either its chunked prompt tail starts feeding through decode steps
        or its first sampled token is emitted."""
        self.scheduler.start(slot, req)
        req.phase = "decode"
        self._active_mask[slot] = True
        self._set_slot_sampling(slot, req)
        if len(req.prompt) > bucket:
            # chunked prefill: the tail is fed through the decode loop,
            # one token per step, before any sampling counts as output.
            req.pending_input = [int(t) for t in req.prompt[bucket:]]
            self._last_token[slot] = 0   # unused until the tail drains
        else:
            self._emit_token(slot, first_tok)

    def _first_tokens(self, logits, group: List[Request], rows: int) -> np.ndarray:
        """Sample each group member's first token from its prefill logits
        with its own SamplingParams at token index 0 (same fold-in the
        decode loop would use), so prefill- and decode-produced tokens are
        governed by one policy."""
        if all(req.gen.params.is_greedy for req in group):
            return np.argmax(np.asarray(logits), axis=-1)
        temp = np.zeros(rows, np.float32)
        top_k = np.zeros(rows, np.int32)
        top_p = np.ones(rows, np.float32)
        key = np.zeros((rows, 2), np.uint32)
        bias = None
        for i, req in enumerate(group):
            p = req.gen.params
            if not p.is_greedy:
                temp[i], top_k[i], top_p[i] = p.temperature, p.top_k, p.top_p
                key[i] = self._base_key(req)
            if p.logit_bias:
                if bias is None:
                    bias = np.zeros((rows, self._vocab), np.float32)
                for tok, val in p.logit_bias.items():
                    bias[i, int(tok)] = np.float32(val)
        kmax = int(top_k.max())
        state = sampling.SamplingState(
            host_upload(temp), host_upload(top_k), host_upload(key),
            jnp.zeros(rows, jnp.int32),
            top_p=host_upload(top_p) if (top_p < 1.0).any() else None,
            bias=host_upload(bias) if bias is not None else None)
        return np.asarray(sampling.sample(
            logits, state, kmax=min(next_pow2(kmax), self._vocab) if kmax else 0))

    # -- two-phase admission (continuous batching / disaggregated prefill) ----
    def _admit_one(self, req: Request, bucket: int) -> None:
        """Admit a single request (rows=1 prefill, left-padded into its
        bucket exactly like a batch of one — the differential harness pins
        rows=1 and rows=N prefills bitwise identical). On a disaggregated
        engine the prefill is *dispatched* on the dedicated plan and parked
        in ``_inflight``; the sealed handoff consumes it next step."""
        self._charge_budget(req)
        slot = self.kv.acquire(req.rid, self._admit_need(req))
        assert slot is not None, "admission raced KV accounting"
        tokens = np.zeros((1, bucket), np.int32)
        chunk = req.prompt[:bucket]
        tokens[0, bucket - len(chunk):] = chunk   # left-pad short prompts
        if self._prefill_stream_fn is not None:
            # jax dispatches this call asynchronously: the decode step the
            # engine runs next overlaps with it, and _handoff_ready blocks
            # on the result only when it crosses to the decode plan.
            fresh = self.model.init_cache(1, self.max_len)
            logits, cache = self._prefill_stream_fn(
                self.prefill_params, host_upload(tokens), fresh)
            req.phase = "prefill"
            self._inflight[slot] = InflightPrefill(req, slot, bucket,
                                                   logits, cache)
            return
        fresh = self.kv.fresh_prefill_cache(1)
        logits, prefilled = self._prefill_fn(self.params, host_upload(tokens),
                                             fresh)
        first_np = self._first_tokens(logits, [req], 1)
        keys = [req.page_keys] if self.kv.supports_sharing else None
        self.kv.insert_prefill(prefilled, [slot], bucket, page_keys=keys)
        self._start_decode(slot, req, int(first_np[0]), bucket)

    def _admit_continuous(self) -> int:
        """Step-level admission: pop waiting requests one at a time into
        free slots while the step-token budget (single-plan mode) and KV
        capacity allow. When the head's bucket doesn't fit the remaining
        budget, the best-ordered queued request that *does* fit backfills
        the leftover — the head keeps first claim on the next step's fresh
        budget, so nothing starves. Mirrors ``_admit_batch``'s group-mate
        guard: admissions beyond the first must outrank every sealed-out
        request, or they would jump the restore queue."""
        admitted = 0
        best_sealed = max((p.req.priority for p in self._preempted),
                          default=None)
        while self.slots.free:
            head = self.scheduler.peek_waiting(self._admit_filter)
            if head is None:
                break
            if (admitted and best_sealed is not None
                    and head.priority <= best_sealed):
                break
            bucket = self._bucket_for(len(head.prompt))
            fits_budget = (self._budget_left is None
                           or bucket <= self._budget_left)
            if fits_budget and self.kv.can_admit(self._admit_need(head)):
                req = self.scheduler.next_waiting(self._admit_filter)
                self._admit_one(req, bucket)
                if self._budget_left is not None:
                    self._budget_left -= bucket
                admitted += 1
                continue
            if self._budget_left is None:
                break   # KV-blocked without a budget: nothing to backfill on

            def fits(r, head_rid=head.rid):
                if r.rid == head_rid:
                    return False   # the head keeps next step's fresh budget
                if best_sealed is not None and r.priority <= best_sealed:
                    return False   # must not jump the restore queue
                if self._buckets and not self._admissible(r):
                    return False
                b = self._bucket_for(len(r.prompt))
                return (b <= self._budget_left
                        and self.kv.can_admit(self._admit_need(r)))

            cand = self.scheduler.next_backfill(fits)
            if cand is None:
                break
            cand.backfilled = True
            self.backfills += 1
            b = self._bucket_for(len(cand.prompt))
            self._admit_one(cand, b)
            self._budget_left -= b
            admitted += 1
        return admitted

    def _handoff_key(self, inf: InflightPrefill) -> tuple:
        """Handoff consumption order mirrors the admission queue's: tightest
        slack first (static absolute deadline, priority tiebreak) under the
        default order, pure priority otherwise — NOT slot order, which is an
        arrival-order artifact. A tight-deadline request admitted one slot
        later still gets its first token (and its decode phase) first."""
        r = inf.req
        if self.scheduler.order == "slack":
            return (r.abs_deadline, -r.priority, r.rid)
        return (-r.priority, r.rid)

    def _handoff_ready(self) -> None:
        """Consume prefill-stream work dispatched at the previous step:
        finished requests' KV rows cross from the prefill plan to the decode
        plan as seal/unseal pairs — the disaggregation boundary, accounted
        in ``ChannelStats`` sealed bytes exactly like a preemption — and the
        requests enter the decode phase. Up to ``handoff_batch`` rows ride
        each sealed crossing (slack-ordered groups), so the fixed
        per-crossing cost amortizes across the group (Insight 10)."""
        order = sorted(self._inflight.values(), key=self._handoff_key)
        self._inflight.clear()
        for i in range(0, len(order), self._handoff_batch):
            self._complete_handoff(order[i:i + self._handoff_batch])

    def _complete_handoff(self, group: List[InflightPrefill]) -> None:
        # Each row seals under its own kvhandoff/{stream} namespace (one
        # handoff per stream, ever — restores after preemption use kvslot/ —
        # so the stream id alone keeps nonces fresh), but the whole group
        # crosses the plan boundary as ONE message: one seal event and one
        # restore event carry the group's total payload.
        sealed_rows = []
        total_nb = total_tensors = 0
        for inf in group:
            prefix = f"kvhandoff/{inf.req.stream_id}"
            sealed = seal_tree(self.td.sealing_key, inf.cache, prefix=prefix)
            nb = sealed_nbytes(sealed)
            inf.req.n_handoffs += 1
            inf.req.handoff_bytes += nb
            total_nb += nb
            total_tensors += len(sealed)
            sealed_rows.append((inf, prefix, sealed))
        self.handoff_crossings += 1
        rids = ",".join(str(inf.req.rid) for inf in group)
        self.td.record_seal(total_nb, total_tensors,
                            f"handoff x{len(group)} rids={rids}")
        self.td.record_restore(total_nb, total_tensors,
                               f"handoff x{len(group)} rids={rids}")
        for inf, prefix, sealed in sealed_rows:
            req, slot, bucket = inf.req, inf.slot, inf.bucket
            restored = unseal_tree(self.td.sealing_key, sealed,
                                   self.model.abstract_cache(1, self.max_len),
                                   prefix=prefix)
            keys = [req.page_keys] if self.kv.supports_sharing else None
            self.kv.insert_prefill(restored, [slot], bucket, page_keys=keys)
            first_np = self._first_tokens(inf.logits, [req], 1)
            self._start_decode(slot, req, int(first_np[0]), bucket)

    def _preempt_for(self, incoming: Request) -> bool:
        """Free capacity for ``incoming`` by preempting the lowest-priority
        running slot it strictly outranks. On the paged backend, when only
        *pages* are short (a slot is free but the pool is not), a partial
        eviction seals just the shortfall off the victim's tail — the victim
        keeps its slot and resident pages and resumes via a delta restore.
        Otherwise the whole victim is sealed out. Returns True if capacity
        was freed."""
        if not self.scheduler.running:
            return False
        victim_slot = min(self.scheduler.running,
                          key=lambda s: (self.scheduler.running[s].priority,
                                         -self.scheduler.running[s].rid))
        victim = self.scheduler.running[victim_slot]
        if victim.priority >= incoming.priority:
            return False
        if (self.slots.free and victim_slot not in self._paused
                and self.kv.supports_partial):
            shortfall = (self.kv.pages_for(self._admit_need(incoming))
                         - self.kv.free_page_reserve)
            spare = self.kv.evictable_tail_pages(victim_slot)
            if 0 < shortfall <= spare:
                self.partial_preempt(victim_slot, shortfall)
                return True
        sealed, vreq = self.seal_slot(victim_slot)
        vreq.n_preemptions += 1
        self._preempted.append(PreemptedRequest(sealed, vreq))
        return True

    def _resume_paused(self) -> bool:
        """Restore a partially-evicted slot's sealed tail once the pool has
        room again — unless a strictly higher-priority request is still
        waiting for the pages (the reason the tail was sealed)."""
        for slot, paused in list(self._paused.items()):
            # every path that removes a paused slot from running (abort,
            # whole-seal) also pops self._paused, so the victim is live here.
            # The gate is the strongest waiting PRIORITY (not the slack-
            # ordered queue head — see Scheduler.peek_priority).
            victim = self.scheduler.running[slot]
            rival = self.scheduler.peek_priority(self._admit_filter)
            if rival is not None and rival.priority > victim.priority:
                continue
            if not self.kv.can_restore_tail(paused.n_pages):
                continue
            self.kv.restore_tail_pages(self.td.sealing_key, paused.sealed,
                                       slot, paused.prefix)
            self.td.record_restore(sealed_nbytes(paused.sealed),
                                   len(paused.sealed),
                                   f"slot={slot} rid={victim.rid} partial")
            del self._paused[slot]
            return True
        return False

    def _admit_ready(self) -> None:
        """Admission policy, run at the top of every step:
        1. drop queued requests whose drop-deadline has passed and abort
           mid-flight ones whose abort-deadline has (SLO),
        2. resume partially-evicted slots when the pool has room again,
        3. restore sealed-out requests while no waiting request outranks
           them (and the backend has KV room),
        4. batch-admit waiting requests into free slots (bucket-grouped,
           rate-budget and KV-capacity gated — an over-budget priority class
           is skipped without blocking the classes behind it),
        5. preempt a strictly lower-priority running request when the
           waiting head cannot get capacity otherwise — wholly, or just the
           page shortfall on the paged backend (preempted requests never
           trigger further preemption — bounded, no thrash)."""
        while True:
            self._drop_expired()
            self._enforce_aborts()
            if self._paused and self._resume_paused():
                continue
            if self._preempted and self.slots.free:
                # restore-vs-admit: only sealed requests that the strongest
                # WAITING PRIORITY does not outrank are restorable
                # (restoring one a waiting request outranks would just be
                # preempted right back — livelock; gating on the slack-
                # ordered queue head instead would let a deadline-bearing
                # low-priority head unlock restores a waiting high-priority
                # request should block). AMONG the eligible, the restore
                # queue orders like the waiting queue: tightest slack first
                # (static absolute deadlines), then priority — a sealed-out
                # deadline-bound victim gets back in while its deadline is
                # still meetable. Priority-only engines keep the v4
                # selection.
                rival = self.scheduler.peek_priority(self._admit_filter)
                eligible = [p for p in self._preempted
                            if rival is None
                            or p.req.priority >= rival.priority]
                if eligible:
                    if self.scheduler.order == "slack":
                        best = min(eligible,
                                   key=lambda p: (p.req.abs_deadline,
                                                  -p.req.priority,
                                                  p.req.rid))
                    else:
                        best = max(eligible,
                                   key=lambda p: (p.req.priority,
                                                  -p.req.rid))
                    if self.kv.can_restore(
                            best.req.kv_need,
                            n_pages=best.req.sealed_pages or None):
                        self._preempted.remove(best)
                        self.restore_slot(best.sealed, best.req,
                                          key=best.key, prefix=best.prefix)
                        continue
            if (self.scheduler.queue and self.slots.free
                    and (self._admit_continuous() if self._continuous
                         else self._admit_batch()) > 0):
                continue
            # preemption is a PRIORITY right, independent of queue order:
            # the strongest waiting request may evict strictly weaker
            # running work even when a tighter-deadline (lower-priority)
            # request holds the slack-ordered queue head.
            cand = self.scheduler.peek_priority(self._admit_filter)
            if (cand is not None
                    and (not self.slots.free
                         or not self.kv.can_admit(self._admit_need(cand)))
                    and self._preempt_for(cand)):
                continue
            return

    def _drain_kv_events(self) -> None:
        """Account boundary traffic the backend generated on its own:
        shared-page parking (a last live reference dropped while sealed
        references remain — the page crosses out once, content-named),
        re-materialization (the first restore that needed it brings it
        back), and the persistent store's publish/hit/evict traffic."""
        for kind, nb, n in self.kv.drain_events():
            if kind == "park":
                self.td.record_seal(nb, n, "shared page parked (last ref)")
            elif kind == "store_publish":
                self.td.record_seal(nb, n, "page published to sealed store")
            elif kind == "store_hit":
                self.td.record_store_hit(nb, n)
            elif kind == "store_evict":
                self.td.record_store_evict(nb, n)
            else:
                self.td.record_restore(nb, n, "shared page rematerialized")

    def _grant_step_pages(self, live: List[int]) -> List[int]:
        """On-demand allocation: make sure the pool can grant every live
        slot's append (and copy-on-write) page this step. When it runs dry,
        free capacity by *evict-by-slack*: the laxest running victim
        (latest absolute deadline, weakest priority; pure weakest-priority
        under ``admission_order="priority"``) loses just its private tail
        pages through ``seal_tail_pages`` when that covers the shortfall,
        else its whole slot. Terminates: every round either frees pages or
        removes a victim from the batch, and a lone survivor's demand
        always fits (its pages are bounded by request_capacity <= pool).
        Returns the live set minus evicted/paused victims."""
        while True:
            live = [s for s in live if s in self.scheduler.running
                    and s not in self._paused]
            demand = sum(self.kv.step_page_need(s) for s in live)
            free = self.kv.free_physical_pages
            if demand <= free:
                return live
            # paused slots are eviction candidates too: a lone live slot
            # must be able to reclaim pages a paused victim still holds
            # (whole-seal grafts the paused tail blob along — tested).
            candidates = list(self.scheduler.running)
            assert len(candidates) > 1, \
                "single-slot page demand exceeded the pool — capacity bug"

            def laxness(slot):
                r = self.scheduler.running[slot]
                if self.scheduler.order == "slack":
                    return (r.abs_deadline, -r.priority, r.rid)
                return (-r.priority, r.rid)
            victim = max(candidates, key=laxness)
            shortfall = demand - free
            spare = self.kv.evictable_tail_pages(victim)
            if victim not in self._paused and shortfall <= spare:
                self.partial_preempt(victim, shortfall)
            else:
                sealed, vreq = self.seal_slot(victim)
                vreq.n_preemptions += 1
                self._preempted.append(PreemptedRequest(sealed, vreq))

    # -- serving loop ----------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: prefill-stream handoffs, then
        admission/restoration/preemption, then one batched decode step.
        Returns number of *output* tokens produced (prompt-chunk feeding
        steps count zero)."""
        t0 = time.monotonic()
        if self._inflight:
            self._handoff_ready()
        if self._step_tokens is not None:
            # fresh per-step budget: every live decode row (including slots
            # still feeding a chunked prompt tail) costs 1; admissions then
            # charge their prefill bucket against the remainder.
            live_now = sum(1 for s in self.slots.active
                           if s not in self._paused)
            self._budget_left = max(0, self._step_tokens - live_now)
        self._admit_ready()
        live = [s for s in self.slots.active
                if s not in self._paused and s not in self._inflight]
        if live and self.kv.on_demand:
            live = self._grant_step_pages(live)
        if not live:
            self._drain_kv_events()
            return 0
        feeding_prompt = {}   # slot -> tail still pending after this step?
        steps = np.zeros(self.max_slots, np.int32)
        for slot in live:
            req = self.scheduler.running.get(slot)
            if req is None:
                continue
            steps[slot] = len(req.output)   # fold-in index of the next token
            if req.pending_input:
                self._last_token[slot] = req.pending_input.pop(0)
                feeding_prompt[slot] = bool(req.pending_input)
        state, kmax = self._sampling_state(steps)
        next_np = self.kv.decode(self.params, self._last_token, state, kmax,
                                 write_slots=live)
        if self.plan.is_sharded:
            # account the step's cross-device collective traffic (bytes from
            # the compiled HLO, seconds from the plan's measured probe)
            n, cb, cs = self.plan.drain_collectives()
            if n:
                self.td.record_collective(cb, cs, steps=n)
        produced = 0
        for slot in list(live):
            if not self._active_mask[slot] or slot in self._paused:
                continue
            if feeding_prompt.get(slot, False):
                continue   # mid-prompt chunk: this step's sample is discarded
            self._emit_token(slot, int(next_np[slot]))
            produced += 1
        self._drain_kv_events()
        # feasibility floor: only steps that actually decoded count (an
        # empty tick would fake an impossibly fast step and over-reject)
        dt = time.monotonic() - t0
        if self._step_floor is None or dt < self._step_floor:
            self._step_floor = dt
        return produced

    @property
    def idle(self) -> bool:
        return (self.scheduler.idle and not self._preempted
                and not self._inflight)

    def run(self, max_steps: int = 10_000) -> ServeStats:
        steps = 0
        while not self.idle and steps < max_steps:
            produced = self.step()
            steps += 1
            if produced == 0 and not self.slots.active and not self.idle:
                # every waiting request is rate-budget gated: yield briefly
                # so the token buckets refill instead of busy-spinning.
                time.sleep(1e-3)
        stats = self.scheduler.stats()
        stats.shared_pages = getattr(self.kv, "shared_page_maps", 0)
        stats.cow_copies = getattr(self.kv, "cow_copies", 0)
        stats.store_hits = getattr(self.kv, "store_hits", 0)
        stats.store_restored_bytes = getattr(self.kv, "store_restored_bytes", 0)
        # evictions come from the channel (event-accounted), not the store
        # object — a store shared between engines counts fleet-wide there
        stats.store_evictions = self.td.channel.stats.store_evictions
        return stats

    # -- sealed KV preemption ----------------------------------------------------
    # The KV cache holds user conversation state; when a slot is preempted
    # (priority eviction, host maintenance) its pages must not land anywhere
    # unencrypted — the at-rest property H100 HBM lacks (paper §V-D3). The
    # slot cache is sealed with the domain key and can be restored later.
    # The sealing *granularity* is the backend's: slot-dense moves the whole
    # [L, max_len, ...] extent, paged moves ceil(tokens/page_size) pages.

    def _seal_prefix(self, req: Request) -> str:
        # the nonce-deriving name must be unique across every seal the domain
        # ever performs: the channel-global stream id (never reused, unlike
        # per-engine rids) plus a per-request seal epoch — a request
        # preempted twice holds different KV contents each time, and a
        # stream cipher must never encrypt two plaintexts under one nonce.
        return f"kvslot/{req.stream_id}/{req.seal_epoch}"

    def seal_slot(self, slot: int, *, key=None,
                  prefix: Optional[str] = None) -> Tuple[Dict[str, Any],
                                                         Request]:
        """Evict a running slot: returns (sealed_cache_dict, request). Any
        not-yet-prefilled prompt tail travels on ``request.pending_input``
        and not-yet-flushed egress tokens stay buffered on the request.

        A partially-evicted (paused) slot can be whole-sealed too: only its
        resident remainder is encrypted now, and the already-sealed tail
        blob rides along in the returned dict (its distinct epoch prefix
        keeps the nonce namespaces apart); ``restore_slot`` reassembles
        both.

        ``key``/``prefix`` override the worker defaults for cross-worker
        migration: the blob seals under a fleet-shared tenant key domain in
        a caller-supplied (worker-name-embedding) nonce namespace. Callers
        overriding the key must not have a paused tail on the slot — that
        earlier blob is under THIS worker's key and cannot cross
        (``export_sealed_state`` reunites it first)."""
        paused = self._paused.pop(slot, None)
        req = self.scheduler.running.pop(slot)
        assert paused is None or key is None, \
            "cannot migration-seal a paused slot: its tail blob is local"
        # a key override means the blob leaves this worker: shared pages
        # must seal by VALUE (detach) — a by-reference entry resolves
        # against THIS pool's content index / parked blobs, which the
        # destination does not have
        detach = key is not None and getattr(self.kv, "supports_sharing",
                                             False)
        key = key if key is not None else self.td.sealing_key
        prefix = prefix if prefix is not None else self._seal_prefix(req)
        if self.kv.supports_partial:
            # what an on-demand restore must find free: the resident pages
            # plus any earlier-sealed tail riding along (shared pages may
            # re-link for less — this is the conservative bound).
            req.sealed_pages = (self.kv.allocated_pages(slot)
                                + (paused.n_pages if paused else 0))
        sealed = (self.kv.seal(key, slot, prefix, detach=True) if detach
                  else self.kv.seal(key, slot, prefix))
        req.seal_epoch += 1
        nb = sealed_nbytes(sealed)   # the paused tail was recorded at its seal
        req.sealed_bytes += nb
        self.td.record_seal(nb, len(sealed),
                            f"slot={slot} rid={req.rid} stream={req.stream_id} "
                            f"epoch={req.seal_epoch - 1}")
        if paused is not None:
            sealed.update(paused.sealed)
        self.kv.release(slot)
        self._active_mask[slot] = False
        self._drain_kv_events()
        return sealed, req

    def restore_slot(self, sealed, req: Request, *, key=None,
                     prefix: Optional[str] = None) -> int:
        """Re-admit a sealed-out request into a free slot. On-demand pools
        acquire without a pledge (the restore's page takes were gated by
        ``can_restore(n_pages=...)``); reservation pools re-reserve the
        effective worst case. ``key``/``prefix`` override the worker
        defaults when the blob is a cross-worker migrant (sealed under a
        fleet-shared tenant domain in a ``kvmigrate/`` namespace)."""
        slot = self.kv.acquire(req.rid,
                               0 if self.kv.on_demand else req.kv_need)
        if slot is None:
            raise RuntimeError("no free slot/KV room to restore into")
        key = key if key is not None else self.td.sealing_key
        if prefix is None:
            prefix = f"kvslot/{req.stream_id}/{req.seal_epoch - 1}"
        try:
            self.kv.restore(key, sealed, slot, prefix, req.kv_need)
            # a sealed-while-paused eviction carries its earlier tail blob
            # under an older epoch prefix (and, under a mesh, shard suffix);
            # graft it back on top of the remainder (acquire() above already
            # reserved the full need).
            for gprefix, gsuffix in tail_blob_names(sealed):
                self.kv.restore_tail_pages(
                    key, sealed, slot, gprefix,
                    reserve=False, suffix=gsuffix)
        except Exception:
            self.kv.release(slot)   # a failed (e.g. tampered) restore must
            raise                   # not leak the slot or its reservation
        # the WHOLE restore succeeded: only now are this sealed dict's
        # shared-page references spent (a rolled-back restore must leave
        # _sealed_refs and parked ciphertext intact for co-sharers)
        self.kv.discard_sealed(key, sealed, prefix)
        self.scheduler.running[slot] = req
        self._active_mask[slot] = True
        self._set_slot_sampling(slot, req)
        # next decode input: the prompt tail (if chunked prefill was cut
        # short) takes precedence in step(); otherwise the last output token.
        self._last_token[slot] = req.output[-1] if req.output else 0
        self.td.record_restore(sealed_nbytes(sealed), len(sealed),
                               f"slot={slot} rid={req.rid}")
        self._drain_kv_events()
        return slot

    def partial_preempt(self, slot: int, n_pages: int) -> None:
        """Page-granular preemption (paged backend only): seal the victim's
        ``n_pages`` tail pages and hand them (and their reservation) back to
        the pool. The victim stays admitted — slot, sampling row, and head
        pages intact — but sits out of the decode batch until
        ``_resume_paused`` restores the delta."""
        if not self.kv.supports_partial:
            raise RuntimeError(
                f"the {self.kv.name} backend cannot seal at page granularity;"
                f" use kv_backend='paged'")
        if slot in self._paused:
            raise RuntimeError(f"slot {slot} is already partially evicted")
        req = self.scheduler.running[slot]
        prefix = self._seal_prefix(req)
        sealed = self.kv.seal_tail_pages(self.td.sealing_key, slot, prefix,
                                         n_pages)
        req.seal_epoch += 1
        req.n_preemptions += 1
        nb = sealed_nbytes(sealed)
        req.sealed_bytes += nb
        self.td.record_seal(nb, len(sealed),
                            f"slot={slot} rid={req.rid} partial "
                            f"pages={n_pages}")
        self._paused[slot] = PausedSlot(sealed, prefix, n_pages)

    # -- fleet: drain + sealed-state migration ---------------------------------
    def drain(self) -> None:
        """Stop taking new work (subsequent ``submit`` raises); everything
        already accepted keeps stepping. Pair with
        :meth:`export_sealed_state` to move the remaining state to another
        worker instead of finishing it here."""
        self._draining = True

    def export_sealed_state(
            self, *,
            key_for: Optional[Callable[[Request], Any]] = None,
            namespace: str = "kvmigrate",
    ) -> Tuple[List[PreemptedRequest], List[Request]]:
        """Seal EVERY piece of live state out of this engine for adoption by
        another — the fleet drain/failure path. Returns ``(migrants,
        queued)``: migrants are :class:`PreemptedRequest` blobs sealed under
        ``key_for(req)`` (the fleet passes the request's *tenant* key
        domain, identical on every attested worker) in the
        ``{namespace}/{stream}/{epoch}`` nonce space — the caller's
        namespace must embed this worker's fleet-unique name, because the
        tenant key is shared and two workers' stream ids are not distinct
        from each other; queued requests carry no KV and move as-is.

        The export is staged so the pool always has room: pending prefill
        handoffs complete first (they become running rows), plain running
        slots migration-seal directly, a paused slot round-trips through
        this worker's own seal/restore to reunite its resident head with
        its locally-sealed tail before migrating whole, and already-
        preempted blobs restore into the (by then free) slots and re-seal
        under the export key. Every crossing is priced in ``ChannelStats``
        like any other seal/restore; per-request
        ``n_migrations``/``migrated_bytes`` roll up into
        ``ServeStats.migrations``/``migrated_bytes``."""
        self._draining = True
        if key_for is None:
            key_for = lambda req: self.td.sealing_key  # noqa: E731
        if self._inflight:
            self._handoff_ready()
        migrants: List[PreemptedRequest] = []

        def _migrate(slot: int) -> None:
            req = self.scheduler.running[slot]
            key = key_for(req)
            prefix = f"{namespace}/{req.stream_id}/{req.seal_epoch}"
            sealed, req = self.seal_slot(slot, key=key, prefix=prefix)
            nb = sealed_nbytes(sealed)
            req.n_migrations += 1
            req.migrated_bytes += nb
            self.td._log("migrate_out", f"rid={req.rid} {nb}B {prefix}")
            self.td.close_stream(req.stream_id)
            migrants.append(PreemptedRequest(sealed, req, key=key,
                                             prefix=prefix))

        while self.scheduler.running:
            slot = next((s for s in self.scheduler.running
                         if s not in self._paused), None)
            if slot is None:
                # every survivor is paused: its sealed tail is under THIS
                # worker's key and cannot cross. Whole-seal (grafts the
                # tail along) then restore — the standard reassembly path —
                # and migrate the reunited slot.
                slot = next(iter(self._paused))
                sealed, req = self.seal_slot(slot)
                slot = self.restore_slot(sealed, req)
            _migrate(slot)
        # already-sealed preempted blobs: local key/namespace — bring each
        # back through a now-free slot and re-seal under the export key
        while self._preempted:
            p = self._preempted.pop(0)
            slot = self.restore_slot(p.sealed, p.req, key=p.key,
                                     prefix=p.prefix)
            _migrate(slot)
        queued = [req for _, _, req in sorted(self.scheduler.queue)]
        self.scheduler.queue.clear()
        for req in queued:
            self.td.close_stream(req.stream_id)
        return migrants, queued

    def import_sealed_state(self, migrants: Sequence[PreemptedRequest],
                            queued: Sequence[Request] = ()) -> None:
        """Adopt another worker's exported state. Requests keep their object
        identity — the caller's handle finishes here, byte-identically
        (seeded sampling; output/penalty history travel on the request) —
        but get fresh rids (this scheduler's numbering) and fresh egress
        streams on this engine's channel. Migrants join the sealed-restore
        queue and re-enter through the ordinary slack/priority admission
        gates; their first local re-seal (if any) falls back to this
        worker's own key and ``kvslot/`` namespace."""
        for p in migrants:
            p.req.rid = self.scheduler._next_rid
            self.scheduler._next_rid += 1
            p.req.stream_id = self.td.open_stream()
            self.td._log("migrate_in", f"rid={p.req.rid} {p.prefix}")
            self._preempted.append(p)
        for req in queued:
            req.rid = self.scheduler._next_rid
            self.scheduler._next_rid += 1
            req.stream_id = self.td.open_stream()
            heapq.heappush(self.scheduler.queue,
                           (self.scheduler._key(req), req.rid, req))

    # -- convenience -----------------------------------------------------------
    def generate(self, request: GenerationRequest) -> RequestOutput:
        """Serve one request to completion: ``generate(GenerationRequest)
        -> RequestOutput``."""
        req = self.submit(request)
        self.run()
        return req.result()

    def stream(self, request: GenerationRequest, *,
               max_steps: int = 100_000) -> Iterator[int]:
        """Yields this request's tokens as they cross the trust boundary —
        per token with the default FramePolicy, in bursts of ``coalesce``
        when the request asked for frame coalescing. Other queued requests
        keep advancing in the same decode batch. The request is submitted
        eagerly (before the first token is pulled), so it joins the batch
        even if the caller iterates later. Any on_token the request carries
        still fires."""
        if not isinstance(request, GenerationRequest):
            raise TypeError(
                "stream takes a GenerationRequest (repro.runtime.api); the "
                "v2 kwargs form was removed in v4 — build a request object")
        buf: List[int] = []
        inner = request.on_token

        def _tap(r, t):
            buf.append(t)
            if inner is not None:
                inner(r, t)

        request.on_token = _tap
        req = self.submit(request)

        def _drain() -> Iterator[int]:
            steps = 0
            while not req.finished:
                if steps >= max_steps:
                    raise RuntimeError(f"stream exceeded {max_steps} steps")
                produced = self.step()
                steps += 1
                if produced == 0 and not self.slots.active and not self.idle:
                    # rate-budget gated (same as run()): let buckets refill
                    # instead of burning max_steps on empty iterations.
                    time.sleep(1e-3)
                while buf:
                    yield buf.pop(0)
            while buf:
                yield buf.pop(0)

        return _drain()
